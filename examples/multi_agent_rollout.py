"""Multi-agent shared-world rollouts on the num_env ladder.

K agents drive one articulated chain world (``envs/multi_agent.py``):
agent k owns joint block [k*J, (k+1)*J), the chain coupling links
neighboring agents' boundary joints, and the world's done resets all K
agents together.  Because per-agent obs/action dims match the
single-agent family, the SAME policy network serves any K — the
controller's num_env ladder just sees K times more envs.

Run:  PYTHONPATH=src python examples/multi_agent_rollout.py
"""
import jax
import jax.numpy as jnp

from repro.envs import make_env, make_multi_agent_env
from repro.models.policy import init_policy
from repro.rl.rollout import collect


def main():
    K = 4
    env = make_multi_agent_env("Ant", num_agents=K)
    num_envs = 32                       # 8 worlds x 4 agents
    params = init_policy(jax.random.key(0), env.spec.policy_dims)

    state, obs = env.reset(jax.random.PRNGKey(0), num_envs=num_envs)
    traj, state, obs, last_value, _ = collect(
        params, env, state, obs, jax.random.PRNGKey(1), num_steps=8)
    print(f"{K}-agent Ant: obs {traj.obs.shape} actions "
          f"{traj.actions.shape} rewards {traj.rewards.shape}")
    print(f"mean reward/agent: {float(traj.rewards.mean()):+.3f}")

    # world-shared done: all K agents of a world terminate together
    d = traj.dones.reshape(8, num_envs // K, K)
    assert bool(jnp.all(d == d[:, :, :1])), "agents of a world share done"

    # the same policy serves the single-agent family — one ladder, K x
    # the rungs
    env1 = make_env("Ant")
    s1, o1 = env1.reset(jax.random.PRNGKey(0), num_envs=8)
    t1, *_ = collect(params, env1, s1, o1, jax.random.PRNGKey(1),
                     num_steps=8)
    print(f"same policy on single-agent Ant: obs {t1.obs.shape}")


if __name__ == "__main__":
    main()
