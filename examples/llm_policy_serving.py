"""Serve a (reduced) assigned architecture with batched requests — a thin
client of the ``repro.serve`` continuous-batching engine, across the
architecture zoo: prefill + decode with KV/state caches, including SSM
and hybrid caches.

    PYTHONPATH=src python examples/llm_policy_serving.py --arch zamba2-7b
"""
import argparse

import jax

from repro.configs import ARCHS, get_reduced, shape_skips
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    if "decode_32k" in shape_skips(args.arch):
        raise SystemExit(f"{args.arch}: " +
                         shape_skips(args.arch)["decode_32k"])
    cfg = get_reduced(args.arch)
    print(f"serving {args.arch} (reduced: {cfg.d_model}d) — "
          f"family={cfg.family}")
    params = T.init_model(jax.random.key(0), cfg)
    B, P = args.batch, args.prompt_len
    toks = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)

    engine = ServeEngine(cfg, params, max_slots=B, max_seq=P + args.gen + 4)
    done = engine.serve([Request(tokens=toks[i], max_new_tokens=args.gen)
                         for i in range(B)])

    tel = engine.telemetry
    print(f"prefill {B}x{P}: {1e3 * tel.total_prefill_s:.1f} ms")
    gen_tokens = tel.total_tokens - B          # first tokens came from prefill
    print(f"decode {tel.total_decode_steps} steps: "
          f"{1e3 * tel.total_decode_s:.1f} ms "
          f"({gen_tokens/max(tel.total_decode_s, 1e-9):,.0f} tok/s batched)")
    first = min(done, key=lambda c: c.rid)
    print("first sequence token ids:", first.tokens[:12], "...")


if __name__ == "__main__":
    main()
