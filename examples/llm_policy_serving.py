"""Serve a (reduced) assigned architecture with batched requests — the
framework's serving path across the architecture zoo: prefill + decode
with KV/state caches, including SSM and hybrid caches.

    PYTHONPATH=src python examples/llm_policy_serving.py --arch zamba2-7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_reduced, shape_skips
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-1.3b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    if "decode_32k" in shape_skips(args.arch):
        raise SystemExit(f"{args.arch}: " +
                         shape_skips(args.arch)["decode_32k"])
    cfg = get_reduced(args.arch)
    print(f"serving {args.arch} (reduced: {cfg.d_model}d) — "
          f"family={cfg.family}")
    key = jax.random.key(0)
    params = T.init_model(key, cfg)
    B, P = args.batch, args.prompt_len
    toks = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    max_seq = P + args.gen + 4

    prefill = jax.jit(
        lambda p, b: T.prefill(p, cfg, b, max_seq))
    decode = jax.jit(
        lambda p, t, pos, c: T.decode_step(p, cfg, t, pos, c))

    t0 = time.time()
    logits, caches = prefill(params, {"tokens": toks})
    jax.block_until_ready(logits)
    print(f"prefill {B}x{P}: {1e3*(time.time()-t0):.1f} ms")

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    seq = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((B,), P + i, jnp.int32)
        logits, caches = decode(params, tok, pos, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        seq.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"decode {args.gen-1} steps: {1e3*dt:.1f} ms "
          f"({B*(args.gen-1)/dt:,.0f} tok/s batched)")
    print("first sequence token ids:",
          [int(t[0]) for t in seq][:12], "...")


if __name__ == "__main__":
    main()
