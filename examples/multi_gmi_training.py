"""End-to-end GMI-DRL driver (the paper's headline workload): synchronized
PPO training across multiple holistic GMIs with

  1. workload-aware selection (Algorithm 2) of (num_env, GMIperGPU),
  2. task-aware TCG_EX layout (holistic serving+training instances),
  3. Algorithm-1 choice of the gradient-reduction schedule,
  4. a few hundred training iterations with global policy sync.

    PYTHONPATH=src python examples/multi_gmi_training.py --iters 200
"""
import argparse
import time

import jax
import numpy as np

from repro.core.placement import plan_tcg_ex_training
from repro.core.selection import explore, make_ppo_profiler
from repro.envs import make_env
from repro.rl.ppo import PPOConfig, init_train, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="Ant")
    ap.add_argument("--num-gpus", type=int, default=2)
    ap.add_argument("--iters", type=int, default=200)
    args = ap.parse_args()

    # 1) Algorithm 2: profile-driven configuration search (reduced sweep)
    print("== Algorithm 2: workload-aware GMI selection ==")
    profile = make_ppo_profiler(iters=1)
    trace = explore(profile, args.env, num_gpu=args.num_gpus,
                    gmi_per_gpu_range=(2, 1), num_env_sweep=(128, 256, 512))
    num_env, gmi_per_gpu = trace.best_config
    print(f"selected num_env={num_env} GMIperGPU={gmi_per_gpu} "
          f"(projected {trace.best_throughput:,.0f} steps/s, "
          f"{len(trace.points)} profile points)")

    # 2) TCG_EX layout + 3) Algorithm 1 strategy, owned by the layout's
    #    Communicator (repro.comm): mesh + strategy + grad-sync in one
    #    object, re-selectable online from measured reduce times
    layout = plan_tcg_ex_training(
        args.num_gpus, gmi_per_gpu,
        devices=list(range(args.num_gpus * gmi_per_gpu)),
        devices_per_gpu=gmi_per_gpu)
    comm = layout.communicator()
    strat = comm.strategy
    print(layout.manager.summary())
    print(f"Algorithm 1 gradient-reduction strategy: {strat.upper()} "
          f"(grid {comm.grid})")

    # 4) train
    env = make_env(args.env)
    cfg = PPOConfig(num_steps=16, num_epochs=2, num_minibatches=2, lr=1e-3)
    n_inst = len(layout.trainer_gmis)
    step = make_train_step(env, cfg)
    states = []
    for i in range(n_inst):
        p, o, es, ob = init_train(jax.random.key(i), env,
                                  env.spec.policy_dims,
                                  num_envs=num_env // n_inst)
        states.append([p, o, es, ob, jax.random.PRNGKey(i)])

    t0 = time.time()
    total = 0
    for it in range(args.iters):
        rws = []
        for s in states:
            s[0], s[1], s[2], s[3], s[4], m = step(*s)
            rws.append(float(m["reward_mean"]))
        # stage (iii): global policy synchronization across GMIs
        mean_p = jax.tree.map(lambda *xs: sum(xs) / n_inst,
                              *[s[0] for s in states])
        for s in states:
            s[0] = mean_p
        total += cfg.num_steps * num_env
        if it % max(args.iters // 10, 1) == 0:
            print(f"iter {it:4d} reward={np.mean(rws):8.3f} "
                  f"steps/s={total / (time.time() - t0):,.0f}")
    print(f"\ntrained {total:,} env-steps on {n_inst} GMIs "
          f"({strat.upper()} sync) in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
