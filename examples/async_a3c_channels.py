"""Asynchronized DRL training (A3C) over the channel-based experience
pipeline (paper §4.2 + Fig 6b): serving GMIs on one device group collect
experience, the dispenser→compressor→migrator→batcher pipeline ships it,
trainer GMIs update the policy, and actors run on a stale snapshot.

The experience flow is device-resident end to end and OVERLAPPED (paper
§4.1): with ``overlap=True`` a flush is a double-buffer swap — trainers
consume the previous round's back generation while serving keeps staging
the front one — and the attached online controller (runtime Algorithm 2)
re-plans the serving:training split and num_env between epochs from
measured throughput and ring occupancy.

    PYTHONPATH=src python examples/async_a3c_channels.py
"""
import time

import numpy as np

from repro.core.placement import plan_async
from repro.envs import make_env
from repro.launch.steps import make_async_runner


def main():
    env = make_env("Anymal")
    layout = plan_async(num_gpus=2, serving_gpus=1, gmis_per_gpu=2,
                        devices=list(range(4)), devices_per_gpu=2)
    print(layout.manager.summary())
    from repro.core.controller import ControllerConfig
    runner = make_async_runner(env, layout, num_envs=64, num_steps=16,
                               overlap=True, online_controller=True,
                               controller_cfg=ControllerConfig(
                                   num_env_sweep=(64, 128, 256)))

    t0 = time.time()
    for rnd in range(30):
        # serve -> stage -> swap-flush -> migrate -> train (round r-1)
        losses, stale = runner.round()
        if rnd % 5 == 0 and losses:
            dt = time.time() - t0
            print(f"round {rnd:3d} loss={np.mean(losses):8.4f} "
                  f"staleness={max(stale)} PPS={runner.predictions/dt:,.0f} "
                  f"TTOP={runner.trained_samples/dt:,.0f}")
    runner.finish()            # train on the in-flight tail
    s = runner.pipe.stats
    print(f"\nchannel pipeline: {s.num_transfers} transfers, "
          f"{s.bytes_per_transfer:,.0f} B/transfer "
          f"({s.total_bytes/2**20:.1f} MiB total); "
          f"delivered == predicted: "
          f"{runner.trained_samples == runner.predictions}")
    print(runner.controller.summary())
    for d in runner.controller.decisions:
        print(f"  re-plan: {d.reason} -> serving_gpus={d.serving_gpus}, "
              f"gmi_per_gpu={d.gmi_per_gpu}, num_env={d.num_env}")


if __name__ == "__main__":
    main()
