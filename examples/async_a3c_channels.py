"""Asynchronized DRL training (A3C) over the channel-based experience
pipeline (paper §4.2 + Fig 6b): serving GMIs on one device group collect
experience, the dispenser→compressor→migrator→batcher pipeline ships it,
trainer GMIs update the policy, and actors run on a stale snapshot.

    PYTHONPATH=src python examples/async_a3c_channels.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channels import MultiChannelPipeline
from repro.core.placement import plan_async
from repro.envs import make_env
from repro.models.policy import init_policy
from repro.optim import adam_init
from repro.rl.a3c import actor_collect, staleness, trainer_update


def main():
    env = make_env("Anymal")
    layout = plan_async(num_gpus=2, serving_gpus=1, gmis_per_gpu=2,
                        devices=list(range(4)), devices_per_gpu=2)
    print(layout.manager.summary())
    pipe = MultiChannelPipeline(layout.serving_gmis, layout.trainer_gmis,
                                gmi_gpu={g.gmi_id: g.gpu_id for g in
                                         layout.manager.gmis.values()})

    params = init_policy(jax.random.key(0), env.spec.policy_dims)
    opt = adam_init(params)
    actors = {}
    for a in layout.serving_gmis:
        es, obs = env.reset(jax.random.PRNGKey(a), num_envs=64)
        actors[a] = [es, obs, jax.random.PRNGKey(100 + a)]

    version = jnp.int32(0)
    actor_params = params
    t0 = time.time()
    preds = trained = 0
    for rnd in range(30):
        # serving phase: all agent GMIs collect with the (stale) snapshot
        for a in layout.serving_gmis:
            es, obs, k = actors[a]
            exp, es, obs, k = actor_collect(actor_params, version, env, es,
                                            obs, k, num_steps=16)
            actors[a] = [es, obs, k]
            preds += 16 * 64
            pipe.push(a, exp)
        # channel pipeline: dispense -> compress -> migrate -> batch
        losses, stale = [], []
        for dst, batches in pipe.flush().items():
            for exp in batches:
                stale.append(int(staleness(version, exp)))
                params, opt, loss = trainer_update(params, opt, exp)
                losses.append(float(loss))
                trained += exp.rewards.size
                version = version + 1
        # async model push: actors receive the update AFTER acting
        actor_params = params
        if rnd % 5 == 0:
            dt = time.time() - t0
            print(f"round {rnd:3d} loss={np.mean(losses):8.4f} "
                  f"staleness={max(stale)} PPS={preds/dt:,.0f} "
                  f"TTOP={trained/dt:,.0f}")
    s = pipe.stats
    print(f"\nchannel pipeline: {s.num_transfers} transfers, "
          f"{s.bytes_per_transfer:,.0f} B/transfer "
          f"({s.total_bytes/2**20:.1f} MiB total)")


if __name__ == "__main__":
    main()
