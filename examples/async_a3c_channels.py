"""Asynchronized DRL training (A3C) over the channel-based experience
pipeline (paper §4.2 + Fig 6b): serving GMIs on one device group collect
experience, the dispenser→compressor→migrator→batcher pipeline ships it,
trainer GMIs update the policy, and actors run on a stale snapshot.

The experience flow is device-resident end to end: pushes pack in place
into per-group ring buffers (Pallas ``pack_channels`` on TPU, jitted
donated XLA elsewhere) and a flush is a pointer-bump slice per channel.

    PYTHONPATH=src python examples/async_a3c_channels.py
"""
import time

import numpy as np

from repro.core.placement import plan_async
from repro.envs import make_env
from repro.launch.steps import make_async_runner


def main():
    env = make_env("Anymal")
    layout = plan_async(num_gpus=2, serving_gpus=1, gmis_per_gpu=2,
                        devices=list(range(4)), devices_per_gpu=2)
    print(layout.manager.summary())
    runner = make_async_runner(env, layout, num_envs=64, num_steps=16)

    t0 = time.time()
    for rnd in range(30):
        # serve -> ring-pack -> pointer-bump flush -> migrate -> train
        losses, stale = runner.round()
        if rnd % 5 == 0:
            dt = time.time() - t0
            print(f"round {rnd:3d} loss={np.mean(losses):8.4f} "
                  f"staleness={max(stale)} PPS={runner.predictions/dt:,.0f} "
                  f"TTOP={runner.trained_samples/dt:,.0f}")
    s = runner.pipe.stats
    print(f"\nchannel pipeline: {s.num_transfers} transfers, "
          f"{s.bytes_per_transfer:,.0f} B/transfer "
          f"({s.total_bytes/2**20:.1f} MiB total)")


if __name__ == "__main__":
    main()
