"""Quickstart: train a PPO policy on one of the paper's benchmarks.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.envs import make_env
from repro.rl.ppo import PPOConfig, init_train, make_train_step


def main():
    env = make_env("BallBalance")          # paper Table 6: 24-dim obs, 3 act
    cfg = PPOConfig(num_steps=16, num_epochs=2, num_minibatches=2, lr=1e-3)
    params, opt, env_state, obs = init_train(
        jax.random.key(0), env, env.spec.policy_dims, num_envs=256)
    step = make_train_step(env, cfg)

    key = jax.random.PRNGKey(0)
    t0 = time.time()
    for it in range(40):
        params, opt, env_state, obs, key, m = step(params, opt, env_state,
                                                   obs, key)
        if it % 5 == 0:
            sps = cfg.num_steps * 256 * (it + 1) / (time.time() - t0)
            print(f"iter {it:3d}  reward_mean={float(m['reward_mean']):7.3f}"
                  f"  steps/s={sps:,.0f}")
    print("done — the reward should have gone up.")


if __name__ == "__main__":
    main()
