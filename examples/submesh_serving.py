"""MIG-style serving: models co-located on ONE device pool, each GMI
owning a hard-isolated sub-mesh (paper §3: MIG backend for serving;
DESIGN.md §2 maps MIG → disjoint Mesh objects) — now through the
``repro.serve`` subsystem.

Each GMI gets its own devices, its own model, its own compiled program —
no collectives can cross the boundary; requests/results route through the
host exactly as MIG forces on GPU.  Part 1 runs two heterogeneous
``ServingRole`` instances (paper Listing 1's serving GMI) side by side;
part 2 puts a ``RequestRouter`` front over two same-model GMIs and routes
an open-loop request trace by queue depth, printing per-GMI latency and
throughput stats.

Run with multiple CPU devices to see real isolation:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/submesh_serving.py
"""
import time

import numpy as np

import jax

from repro.configs import get_reduced
from repro.core.gmi import GMIManager
from repro.models import transformer as T
from repro.serve import Request, RequestRouter, ServingRole


def main():
    devs = jax.devices()
    per_gpu = max(len(devs) // 2, 1)

    # ---- part 1: two hard-isolated serving GMIs, different models --------
    mgr = GMIManager(devices=devs, devices_per_gpu=per_gpu,
                     backend="submesh")
    archs = ["internlm2-1.8b", "xlstm-1.3b"]
    roles = []
    for gmi_id, arch in zip([0, 1], archs):
        cfg = get_reduced(arch)
        params = T.init_model(jax.random.key(gmi_id), cfg)
        gpu = min(gmi_id, len(devs) // per_gpu - 1)
        role = ServingRole(mgr, gmi_id, gpu, cfg, params,
                           max_slots=4, max_seq=48)
        roles.append((role, arch, cfg))
        mesh = role.engine.mesh
        print(f"GMI {gmi_id}: {arch} on devices "
              f"{[d.id for d in mesh.devices.flatten()]}")
    print(mgr.summary())

    for role, arch, cfg in roles:
        B, plen = 4, 24
        toks = np.asarray(jax.random.randint(jax.random.key(7), (B, plen),
                                             0, cfg.vocab_size))
        t0 = time.time()
        done = role.gmi_run([Request(tokens=toks[i], max_new_tokens=13)
                             for i in range(B)])
        # results left the instance through the host (the MIG barrier)
        print(f"GMI {role.gmi_id} [{arch}] served {B} reqs x "
              f"{len(done[0].tokens)} tokens in "
              f"{1e3 * (time.time() - t0):.0f} ms; "
              f"sample: {done[0].tokens[:8]}")

    # ---- part 2: a router front over two same-model serving GMIs --------
    arch = "internlm2-1.8b"
    cfg = get_reduced(arch)
    params = T.init_model(jax.random.key(0), cfg)
    mgr2 = GMIManager(devices=devs, devices_per_gpu=per_gpu,
                      backend="submesh")
    front = []
    for gmi_id in (0, 1):
        gpu = min(gmi_id, len(devs) // per_gpu - 1)
        front.append(ServingRole(mgr2, gmi_id, gpu, cfg, params,
                                 max_slots=2, max_seq=48))
    router = RequestRouter([r.engine for r in front])
    rng = np.random.default_rng(0)
    print(f"\nrouter front: {router.num_engines} x {arch} GMIs")
    # open-loop trace: 2 arrivals per decode round, 10 rounds
    for _ in range(10):
        for _ in range(2):
            router.submit(Request(
                tokens=rng.integers(0, cfg.vocab_size, 12),
                max_new_tokens=8))
        router.step()
    router.drain()
    for role, stats in zip(front, router.per_gmi_stats()):
        print(f"GMI {role.gmi_id}: {stats.requests} reqs, "
              f"{stats.tokens} tokens, {stats.tok_s:,.0f} tok/s, "
              f"p50={stats.p50_s*1e3:.1f}ms p95={stats.p95_s*1e3:.1f}ms")


if __name__ == "__main__":
    main()
