"""MIG-style serving: two models co-located on ONE device pool, each owning
a hard-isolated sub-mesh (paper §3: MIG backend for serving; DESIGN.md §2
maps MIG → disjoint Mesh objects).

Each GMI gets its own devices, its own model, its own compiled program —
no collectives can cross the boundary; experience/requests route through
the host exactly as MIG forces on GPU.

Run with multiple CPU devices to see real isolation:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/submesh_serving.py
"""
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.core.gmi import GMIManager
from repro.models import transformer as T


def main():
    devs = jax.devices()
    per_gpu = max(len(devs) // 2, 1)
    mgr = GMIManager(devices=devs, devices_per_gpu=per_gpu, backend="submesh")
    # two serving instances, each on its own slice ("MIG" partition)
    mgr.add_gmi(0, role="serving", resource_fraction=1.0)
    mgr.set_gpu(0, 0)
    mgr.add_gmi(1, role="serving", resource_fraction=1.0)
    mgr.set_gpu(1, min(1, len(devs) - 1) if len(devs) > per_gpu else 0)
    print(mgr.summary())

    archs = ["internlm2-1.8b", "xlstm-1.3b"]
    instances = []
    for gmi_id, arch in zip([0, 1], archs):
        mesh = mgr.submesh(gmi_id)
        cfg = get_reduced(arch)
        params = T.init_model(jax.random.key(gmi_id), cfg)
        # place the replica entirely inside the instance's sub-mesh
        sharding = NamedSharding(mesh, P())
        params = jax.device_put(params, sharding)
        step = jax.jit(
            lambda p, t, pos, c, cfg=cfg: T.decode_step(p, cfg, t, pos, c))
        prefill = jax.jit(
            lambda p, b, cfg=cfg: T.prefill(p, cfg, b, max_seq=48))
        instances.append((gmi_id, arch, cfg, params, prefill, step, mesh))
        print(f"GMI {gmi_id}: {arch} on devices "
              f"{[d.id for d in mesh.devices.flatten()]}")

    # batched requests served round-robin across isolated instances
    for gmi_id, arch, cfg, params, prefill, step, mesh in instances:
        B, Plen = 4, 24
        toks = jax.random.randint(jax.random.key(7), (B, Plen), 0,
                                  cfg.vocab_size)
        toks = jax.device_put(toks, NamedSharding(mesh, P()))
        t0 = time.time()
        logits, caches = prefill(params, {"tokens": toks})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [tok]
        for i in range(12):
            pos = jnp.full((B,), Plen + i, jnp.int32)
            pos = jax.device_put(pos, NamedSharding(mesh, P()))
            logits, caches = step(params, tok, pos, caches)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(tok)
        jax.block_until_ready(tok)
        # the result leaves the instance through the host (MIG barrier)
        host_tokens = np.stack([np.asarray(t) for t in outs], 1)
        print(f"GMI {gmi_id} [{arch}] served {B} reqs x 13 tokens in "
              f"{1e3 * (time.time() - t0):.0f} ms; "
              f"sample: {host_tokens[0][:8].tolist()}")


if __name__ == "__main__":
    main()
