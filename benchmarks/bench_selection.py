"""Algorithm 2 in action: the profiling-based (GMIperGPU, num_env) search
with the real PPO profiler (reduced sweep for CPU budget)."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.selection import explore, make_ppo_profiler


def run(bench: str = "Ant"):
    profile = make_ppo_profiler(iters=1)
    t0 = time.perf_counter()
    trace = explore(profile, bench, num_gpu=4,
                    gmi_per_gpu_range=(4, 2, 1),
                    num_env_sweep=(128, 256, 512, 1024))
    dt = time.perf_counter() - t0
    ne, gpg = trace.best_config
    emit(f"selection_{bench}", dt * 1e6,
         f"best_num_env={ne}_best_GMIperGPU={gpg}"
         f"_proj_steps_per_s={trace.best_throughput:.0f}"
         f"_points={len(trace.points)}")
