"""Render §Dry-run and §Roofline tables from artifacts into EXPERIMENTS.md
(replaces the <!-- DRYRUN_TABLE --> / <!-- ROOFLINE_TABLE --> markers).

  PYTHONPATH=src:. python benchmarks/make_report.py
"""
from __future__ import annotations

import re

from benchmarks.roofline import analyze_record, load_records

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table() -> str:
    rows = ["### Baseline compile records (lgr=har, act=dmodel)",
            "",
            "| arch | shape | mesh | compile s | mem/dev GiB | dot TF/dev |"
            " coll GiB/dev | cross-pod GiB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    recs = load_records(lgr="har", act="dmodel")
    recs = [r for r in recs if r.get("cache_layout", "heads") == "heads"
            and not r.get("cfg_overrides")]
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r["mesh"]))
    for r in recs:
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']} | {r['mem_per_device_bytes']/2**30:.2f} | "
            f"{r['hlo_dot_flops']/1e12:.2f} | "
            f"{r['collective_bytes']/2**30:.2f} | "
            f"{r.get('cross_pod_bytes', 0)/2**30:.3f} |")
    return "\n".join(rows)


def roofline_table() -> str:
    rows = ["### Per-chip roofline terms, single-pod 16×16 "
            "(v5e: 197 TF bf16, 819 GB/s HBM, 50 GB/s ICI)",
            "",
            "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
            "dominant | MODEL/HLO FLOPs | mem GiB (16 GiB HBM) | "
            "what would move the dominant term |",
            "|---|---|---|---|---|---|---|---|---|"]
    recs = [r for r in load_records(lgr="har", act="dmodel")
            if r["mesh"] == "16x16"
            and r.get("cache_layout", "heads") == "heads"
            and not r.get("cfg_overrides")]
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    for r in recs:
        a = analyze_record(r)
        over = " **(OOM)**" if a["mem_gib"] > 16 else ""
        rows.append(
            f"| {r['arch']} | {r['shape']} | {a['t_compute']:.2e} | "
            f"{a['t_memory']:.2e} | {a['t_collective']:.2e} | "
            f"{a['dominant']} | {a['useful_ratio']:.2f} | "
            f"{a['mem_gib']:.1f}{over} | {a['advice']} |")
    return "\n".join(rows)


def main():
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = re.sub(r"<!-- DRYRUN_TABLE -->(.|\n)*?(?=## §Roofline)",
                  "<!-- DRYRUN_TABLE -->\n" + dryrun_table() + "\n\n",
                  text) if "<!-- DRYRUN_TABLE -->" in text else text
    text = re.sub(r"<!-- ROOFLINE_TABLE -->(.|\n)*?(?=## §Perf)",
                  "<!-- ROOFLINE_TABLE -->\n" + roofline_table() + "\n\n",
                  text) if "<!-- ROOFLINE_TABLE -->" in text else text
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
