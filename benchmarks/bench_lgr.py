"""Table 7: LGR vs the MPR baseline — now per-strategy, including the
3-axis (gpu, inst, dev) mesh of multi-device GMIs.

Layouts (8 fake host devices): 2G2T, 2G3T, 4G2T and the multi-device
2G2T2D grid; policy sizes AT ~1.1e5, HM ~2.9e5, SH ~1.5e6 parameters (the
Table-7/8 gradient sizes).  Every feasible in-SPMD schedule is timed per
layout (one row per strategy) against the host-staged mpr baseline, with
the Table-2 model's predicted speedup alongside.

Runs in a subprocess with 8 host devices so the main process keeps one.
Under ``benchmarks/run.py --quick`` these rows land in BENCH_lgr.json and
sit behind the standard >2x regression gate.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CHILD = textwrap.dedent("""
    import json, sys, time
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    sys.path.insert(0, "src")
    from repro.comm import (ReduceCostModel, lgr_allreduce, mpr_host,
                            select_reduction_strategy)

    SIZES = {"AT": 110_000, "HM": 290_000, "SH": 1_500_000}
    LAYOUTS = {"2G2T": (2, 2), "2G3T": (2, 3), "4G2T": (4, 2),
               "2G2T2D": (2, 2, 2)}
    AXES = ("gpu", "inst", "dev")
    CM = ReduceCostModel()
    out = {}
    for lname, shape in LAYOUTS.items():
        n = int(np.prod(shape))
        devs = np.array(jax.devices()[:n]).reshape(shape)
        mesh = Mesh(devs, AXES[:len(shape)])
        g, t = shape[0], shape[1]
        mpl = [[gi*t + i for i in range(t)] for gi in range(g)]
        alg1 = select_reduction_strategy(mpl)
        strategies = [s for s in CM.candidates(shape) if s != "mpr"]
        for bench, nparam in SIZES.items():
            grads = {"w": jax.random.normal(jax.random.key(0),
                                            shape + (nparam,))}
            per_inst = [jax.tree.map(lambda x, i=i: x[i], grads)
                        for i in np.ndindex(*shape)]
            def best_of(fn, reps):
                best = float("inf")
                for _ in range(reps):
                    t0 = time.perf_counter()
                    jax.block_until_ready(fn())
                    best = min(best, time.perf_counter() - t0)
                return best * 1e6
            us_mpr = best_of(lambda: mpr_host(per_inst), 3)
            for strat in strategies:
                def run_lgr():
                    return lgr_allreduce(grads, mesh, strat)
                jax.block_until_ready(run_lgr())     # compile
                # best-of-N: scheduler noise on emulated host collectives
                # dwarfs the mean; the min is the honest trajectory row
                us_lgr = best_of(run_lgr, 7)
                out[f"{lname}_{bench}_{strat}"] = {
                    "strategy": strat, "us_lgr": us_lgr, "us_mpr": us_mpr,
                    "alg1": alg1, "shape": list(shape)}
    print(json.dumps(out))
""")


def run():
    from repro.comm import ReduceCostModel

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    if proc.returncode != 0:
        emit("lgr_table7", 0.0, f"FAILED:{proc.stderr[-200:]}")
        return
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    sizes = {"AT": 110_000, "HM": 290_000, "SH": 1_500_000}
    cm = ReduceCostModel()
    for key, rec in data.items():
        lname, bench, strat = key.split("_")
        shape = tuple(rec["shape"])
        nbytes = sizes[bench] * 4
        # ReduceCostModel.time reads the dev axis straight off the grid
        pred_mpr = cm.time("mpr", shape, nbytes) * 1e6
        pred = cm.time(strat, shape, nbytes) * 1e6
        mark = "alg1" if strat == rec["alg1"] else "alt"
        emit(f"lgr_{key}", rec["us_lgr"],
             f"{mark}_mpr_us={rec['us_mpr']:.0f}_speedup="
             f"{rec['us_mpr'] / rec['us_lgr']:.2f}x_model_speedup="
             f"{pred_mpr / pred:.2f}x")
