"""Table 7: LGR vs the MPR baseline on the paper's three layouts
(2G2T, 2G3T, 4G2T here — 8 fake host devices) and three policy sizes
(AT ~1.1e5, HM ~2.9e5, SH ~1.5e6 parameters).

Runs in a subprocess with 8 host devices so the main process keeps one.
Reports measured reduction wall time and the Table-2 model's prediction.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit
from repro.core.cost_model import LGR_TIMES

_CHILD = textwrap.dedent("""
    import json, sys, time
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import Mesh
    sys.path.insert(0, "src")
    from repro.core.lgr import lgr_allreduce, mpr_host
    from repro.core.placement import select_reduction_strategy

    SIZES = {"AT": 110_000, "HM": 290_000, "SH": 1_500_000}
    LAYOUTS = {"2G2T": (2, 2), "2G3T": (2, 3), "4G2T": (4, 2)}
    out = {}
    for lname, (g, t) in LAYOUTS.items():
        devs = np.array(jax.devices()[:g*t]).reshape(g, t)
        mesh = Mesh(devs, ("gpu", "inst"))
        mpl = [[gi*t + i for i in range(t)] for gi in range(g)]
        strat = select_reduction_strategy(mpl)
        for bench, n in SIZES.items():
            grads = {"w": jax.random.normal(jax.random.key(0), (g, t, n))}
            def run_lgr():
                return lgr_allreduce(grads, mesh, strat)
            r = run_lgr(); jax.block_until_ready(r)
            t0 = time.perf_counter()
            for _ in range(5):
                r = run_lgr()
            jax.block_until_ready(r)
            us_lgr = (time.perf_counter() - t0) / 5 * 1e6
            per_inst = [jax.tree.map(lambda x: x[i, j], grads)
                        for i in range(g) for j in range(t)]
            t0 = time.perf_counter()
            for _ in range(3):
                mpr_host(per_inst)
            us_mpr = (time.perf_counter() - t0) / 3 * 1e6
            out[f"{lname}_{bench}"] = {
                "strategy": strat, "us_lgr": us_lgr, "us_mpr": us_mpr}
    print(json.dumps(out))
""")


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    if proc.returncode != 0:
        emit("lgr_table7", 0.0, f"FAILED:{proc.stderr[-200:]}")
        return
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    B1, B2 = 5e9, 200e9
    for key, rec in data.items():
        lname, bench = key.split("_")
        g, t = int(lname[0]), int(lname[2])
        n = {"AT": 110_000, "HM": 290_000, "SH": 1_500_000}[bench] * 4
        pred = {s: LGR_TIMES[s](g, t, n, B1, B2) * 1e6
                for s in ("mpr", rec["strategy"])}
        emit(f"lgr_{key}_{rec['strategy']}", rec["us_lgr"],
             f"mpr_us={rec['us_mpr']:.0f}_speedup="
             f"{rec['us_mpr'] / rec['us_lgr']:.2f}x_model_speedup="
             f"{pred['mpr'] / pred[rec['strategy']]:.2f}x")
