"""Fig 7(b)(c): synchronized DRL training throughput — the holistic-GMI
pipeline (TCG_EX: collect + train in one compiled program) vs the
dedicated-trainer baseline (TDG_EX: experience crosses the instance
barrier to a separate trainer step every iteration).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.cost_model import training_speedup_tcg_over_tdg
from repro.envs import make_env
from repro.rl.ppo import PPOConfig, init_train, make_train_step, ppo_loss
from repro.rl.rollout import collect, gae
from repro.optim import adam_update


def run(num_env: int = 256, benches=("Ant", "ShadowHand")):
    cfg = PPOConfig(num_steps=16, num_epochs=1, num_minibatches=2)
    for bench in benches:
        env = make_env(bench)
        params, opt, est, obs = init_train(jax.random.key(0), env,
                                           env.spec.policy_dims, num_env)
        # ---- TCG_EX: one fused iteration ---------------------------------
        step = make_train_step(env, cfg)
        k = jax.random.PRNGKey(0)
        params, opt, est, obs, k, _ = step(params, opt, est, obs, k)  # warm

        def tcg_iter():
            nonlocal params, opt, est, obs, k
            params, opt, est, obs, k, m = step(params, opt, est, obs, k)
            return m["loss"]

        us_tcg = timeit(tcg_iter, warmup=0, iters=3)

        # ---- TDG_EX: collection instance -> barrier -> trainer instance --
        collect_j = jax.jit(lambda p, e, o, key: collect(p, env, e, o, key,
                                                         cfg.num_steps))
        grad_j = jax.jit(jax.value_and_grad(
            lambda p, b: ppo_loss(p, b, cfg.clip_eps, cfg.vf_coef,
                                  cfg.ent_coef)[0]))

        def tdg_iter():
            nonlocal params, opt, est, obs, k
            traj, est, obs, lastv, k = collect_j(params, est, obs, k)
            # experience crosses the GMI barrier: m*(S+A+W) through host
            host = jax.tree.map(np.asarray, traj)
            traj = jax.tree.map(jnp.asarray, host)
            advs, rets = gae(traj.rewards, traj.values, traj.dones, lastv)
            T, N = traj.rewards.shape
            flat = jax.tree.map(
                lambda x: x.reshape((T * N,) + x.shape[2:]),
                (traj.obs, traj.actions, traj.log_probs, advs, rets))
            loss, grads = grad_j(params, flat)
            params, opt = adam_update(grads, opt, params, lr=cfg.lr)
            return loss

        us_tdg = timeit(tdg_iter, warmup=1, iters=3)
        sps_tcg = cfg.num_steps * num_env / (us_tcg / 1e6)
        sps_tdg = cfg.num_steps * num_env / (us_tdg / 1e6)
        emit(f"sync_train_tcgex_{bench}", us_tcg,
             f"steps_per_s={sps_tcg:.0f}")
        emit(f"sync_train_tdgex_{bench}", us_tdg,
             f"steps_per_s={sps_tdg:.0f}")
        emit(f"sync_train_speedup_{bench}", 0.0,
             f"tcgex_over_tdgex={sps_tcg / max(sps_tdg, 1e-9):.2f}x_"
             f"(cost_model={training_speedup_tcg_over_tdg():.2f}x_"
             f"paper~5x)")
