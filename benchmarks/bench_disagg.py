"""Disaggregated prefill/decode serving (ROADMAP item 2): the migrated
path measured end to end — prefill-specialist GMIs shipping packed cache
payloads over the ``CacheChannel`` into continuous-batching decode GMIs —
against the aggregated local-prefill path, under the same synthetic
open-loop arrival trace ``bench_serving.run_engine`` uses.

Rows:

* ``disagg_migrated_tok``  — us per generated token through the migrated
  path (every prompt prefilled on a specialist and spliced remotely).
* ``disagg_local_tok``     — the same trace kept entirely local
  (aggregated serving; the planner forced to keep_local).
* ``disagg_p50``/``p95``   — open-loop request latency through the
  migrated path.
* ``disagg_prefill_rate``/``decode_rate`` — tok/s per ROLE: measured
  prompt tok/s of the prefill specialists, generated tok/s of the decode
  engines' batched loop.
* ``disagg_crossover``     — the migrate-vs-local crossover in prompt
  tokens, computed from the MEASURED channel bandwidth, payload size, and
  prefill rate via the Table-2 migration terms — the prompt length above
  which ``MigrationPlanner`` starts shipping caches on this host.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.cost_model import migration_time


def run(arch: str = "internlm2-1.8b", slots: int = 4, n_requests: int = 12,
        arrivals_per_step: int = 1, prompt_len: int = 16, gen: int = 12):
    from repro.configs import get_reduced
    from repro.launch.steps import make_disagg_front
    from repro.models import transformer as T
    from repro.serve import Request

    cfg = get_reduced(arch)
    params = T.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    def request():
        return Request(tokens=rng.integers(0, cfg.vocab_size, prompt_len),
                       max_new_tokens=gen)

    def open_loop(front):
        submitted = 0
        while submitted < n_requests or front.busy:
            for _ in range(arrivals_per_step):
                if submitted < n_requests:
                    front.submit(request())
                    submitted += 1
            front.step()
        return front.take_epoch()

    front = make_disagg_front(cfg, params, decode_engines=2,
                              prefill_gmis=1, max_slots=slots,
                              max_seq=prompt_len + gen + 4)
    # migrated path: force every prompt through prefill GMI -> channel ->
    # decode GMI, which also measures channel bandwidth, payload size,
    # and specialist prefill rate for the crossover row below
    front.planner.static_bandwidth = 1e15
    front.planner._prefill_tok_s = 1e-6
    front.serve([request(), request()])          # compile both roles
    front.take_epoch()
    mig = open_loop(front)
    us_mig = mig.dt / max(mig.tokens, 1) * 1e6
    emit(f"disagg_migrated_tok_{arch}", us_mig,
         f"tok_s={mig.tok_s:.0f}_migrations={mig.migrations}")
    emit(f"disagg_p50_{arch}", mig.p50_s * 1e6,
         f"p50_ms={mig.p50_s*1e3:.1f}")
    emit(f"disagg_p95_{arch}", mig.p95_s * 1e6,
         f"p95_ms={mig.p95_s*1e3:.1f}")

    # per-role rates off the migrated run's measurements
    pl = front.planner
    prefill_rate = pl.prefill_tok_s
    decode_rate = mig.tokens / max(mig.decode_s, 1e-9)
    emit(f"disagg_prefill_rate_{arch}", 1e6 / max(prefill_rate, 1e-9),
         f"prompt_tok_s={prefill_rate:.0f}")
    emit(f"disagg_decode_rate_{arch}", 1e6 / max(decode_rate, 1e-9),
         f"gen_tok_s={decode_rate:.0f}")

    # migrate-vs-local crossover from the MEASURED terms: prompts longer
    # than min_gain * migration_time * prefill_rate migrate on this host
    nbytes = front.payload_bytes
    bw = pl.bandwidth
    crossover = pl.min_gain * migration_time(nbytes, bw, pl.latency_s) \
        * prefill_rate
    emit(f"disagg_crossover_{arch}", 0.0,
         f"prompt_tokens={crossover:.2f}_payload_MB={nbytes/1e6:.2f}_"
         f"bw_GBs={bw/1e9:.2f}")

    # local baseline: the SAME trace with the planner keeping every
    # prompt on the decode side (aggregated serving)
    local_front = make_disagg_front(cfg, params, decode_engines=2,
                                    prefill_gmis=1, max_slots=slots,
                                    max_seq=prompt_len + gen + 4)
    local_front.planner.static_bandwidth = 1e-3   # migration never wins
    local_front.planner.latency_s = 10.0
    rng = np.random.default_rng(0)                # identical arrivals
    local_front.serve([request(), request()])
    local_front.take_epoch()
    loc = open_loop(local_front)
    assert loc.migrations == 0
    us_loc = loc.dt / max(loc.tokens, 1) * 1e6
    emit(f"disagg_local_tok_{arch}", us_loc,
         f"tok_s={loc.tok_s:.0f}_migrations=0")
    emit(f"disagg_migrate_over_local_{arch}", 0.0,
         f"ratio={us_loc / max(us_mig, 1e-9):.2f}x")


def run_paged(arch: str = "internlm2-1.8b", prompt_len: int = 16,
              gen: int = 8):
    """Paged-wire rows: what page-granular migration costs and saves.

    * ``disagg_page_migrate``   — measured per-page transfer cost (the
      unit the planner's ``request_bytes`` pricing scales with).
    * ``disagg_page_crossover`` — partial-migration crossover in prompt
      tokens from ``cost_model.migration_crossover_tokens`` under the
      measured per-page bytes, bandwidth, and prefill rate.
    * ``disagg_prefix_saved``   — shared-prefix dedup over the wire:
      bytes NOT shipped because the decode engine's prefix index already
      held the prompt-head pages (asserted > 0 for a common-head trace).
    """
    from repro.configs import get_reduced
    from repro.core.cost_model import (migration_crossover_tokens,
                                       migration_time)
    from repro.launch.steps import make_disagg_front
    from repro.models import transformer as T
    from repro.serve import Request

    cfg = get_reduced(arch)
    params = T.init_model(jax.random.key(0), cfg)
    front = make_disagg_front(cfg, params, decode_engines=1,
                              prefill_gmis=1, max_slots=4,
                              max_seq=prompt_len + gen + 40)
    front.planner.static_bandwidth = 1e15        # force migration
    front.planner._prefill_tok_s = 1e-6
    rng = np.random.default_rng(0)
    eng = front.router.engines[0]
    P = eng.page_size

    # a common 2-page prompt head across the trace
    head = rng.integers(0, cfg.vocab_size, 2 * P)

    def request():
        tail = rng.integers(0, cfg.vocab_size, prompt_len)
        return Request(tokens=np.concatenate([head, tail]),
                       max_new_tokens=gen)

    front.serve([request()])                     # compile + promote head
    for _ in range(3):                           # sequential: index is warm
        front.serve([request()])
    pl = front.planner

    page_bytes = front._page_bytes or 0.0
    assert page_bytes > 0, "no paged payload crossed the channel"
    bw = max(pl.bandwidth, 1e-9)
    per_page_us = migration_time(page_bytes, bw, pl.latency_s) * 1e6
    emit(f"disagg_page_migrate_{arch}", per_page_us,
         f"page_bytes={page_bytes:.0f}_page_tokens={P}")

    crossover = migration_crossover_tokens(
        P, page_bytes, bw, max(pl.prefill_tok_s, 1e-9), pl.latency_s,
        pl.min_gain)
    emit(f"disagg_page_crossover_{arch}", 0.0,
         f"prompt_tokens={crossover}")

    saved_bytes = front.prefix_pages_saved * page_bytes
    assert front.prefix_pages_saved > 0, \
        "common-head trace shipped every page — prefix dedup inactive"
    emit(f"disagg_prefix_saved_{arch}", 0.0,
         f"pages={front.prefix_pages_saved}_MB={saved_bytes/1e6:.3f}")
