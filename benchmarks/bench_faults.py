"""Fault-tolerance microbench: what a failure costs and what survives.

Timing rows (gated by the >2x regression check in ``run.py --quick``):

* ``faults_round_baseline``        — one fault-free supervised round.
* ``faults_serving_kill_recovery`` — the round in which a serving GMI
  dies: classify + quarantine + lossless drain-train re-plan onto the
  reduced pool.
* ``faults_trainer_kill_recovery`` — same for a trainer GMI (includes
  the spill-not-drop re-queue of its unconsumed batches).
* ``faults_engine_fail_recovery``  — ``RequestRouter.fail_engine``:
  requeue + capped-retry restart after an engine dies mid-decode.
* ``faults_ckpt_save`` / ``faults_ckpt_restore`` — one atomic
  params/opt/version checkpoint round-trip through ``repro.checkpoint``.

Ratio rows (``us_per_call=0`` — informational, skipped by the gate):

* ``faults_goodput_retention`` — trained samples under a two-kill fault
  plan as a fraction of the fault-free run (same rounds, same seed).
* ``faults_lossless``          — trained+poisoned == predictions after
  recovery (1.0 = the spill-not-drop guarantee held).
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

import jax

from benchmarks.common import emit
from repro.core.placement import plan_async
from repro.envs import make_env
from repro.fault import FaultEvent, FaultPlan
from repro.launch.steps import make_fleet_supervisor

ROUNDS = 5
NUM_ENVS = 16
NUM_STEPS = 4


def _build(env, plan=None, **kw):
    layout = plan_async(3, 2, 2, devices=list(range(6)), devices_per_gpu=2)
    return make_fleet_supervisor(env, layout, plan=plan, num_envs=NUM_ENVS,
                                 num_steps=NUM_STEPS, probation=ROUNDS + 1,
                                 **kw)


def run():
    env = make_env("Ant")

    # warm the jit caches so recovery timings measure recovery, not
    # first-trace compilation
    warm = _build(env)
    warm.run(1)

    # ---- baseline: fault-free rounds -----------------------------------
    sup0 = _build(env)
    t0 = time.perf_counter()
    for _ in range(ROUNDS):
        sup0.round()
    base_round_us = (time.perf_counter() - t0) / ROUNDS * 1e6
    sup0.runner.finish()
    base_trained = sup0.runner.trained_samples
    emit("faults_round_baseline", base_round_us,
         f"trained={base_trained}")

    # ---- serving + trainer GMI kills mid-epoch (one run, two faults) ---
    plan2 = FaultPlan([FaultEvent("kill_serving", round=1),
                       FaultEvent("kill_trainer", round=3)])
    sup2 = _build(env, plan=plan2)
    round_us = []
    for r in range(ROUNDS):
        t0 = time.perf_counter()
        sup2.round()
        round_us.append((time.perf_counter() - t0) * 1e6)
    sup2.runner.finish()
    r2 = sup2.runner
    lossless = (r2.trained_samples + r2.poisoned_samples == r2.predictions)
    emit("faults_serving_kill_recovery", round_us[1],
         f"lossless={lossless} replans={r2.replans}")
    emit("faults_trainer_kill_recovery", round_us[3],
         f"lossless={lossless}")
    retention = r2.trained_samples / max(base_trained, 1)
    emit("faults_goodput_retention", 0.0, f"{retention:.3f}x_of_faultfree")
    emit("faults_lossless", 0.0, f"{1.0 if lossless else 0.0}")

    # ---- engine fail: requeue + restart on survivors -------------------
    from repro.configs.base import ModelConfig
    from repro.models import transformer as T
    from repro.serve import Request, RequestRouter, ServeEngine
    cfg = ModelConfig(name="bench", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64)
    params = T.init_model(jax.random.key(0), cfg)

    def engine(i):
        return ServeEngine(cfg, params, max_slots=2, max_seq=32,
                           name=f"e{i}")

    router = RequestRouter([engine(0), engine(1), engine(2)])
    rng = np.random.default_rng(0)
    reqs = [Request(tokens=rng.integers(0, 64, 6), max_new_tokens=5)
            for _ in range(9)]
    for q in reqs:
        router.submit(q)
    router.step()                      # admit + one decode everywhere
    victim = router.engines[1]
    victim.dead = True
    t0 = time.perf_counter()
    router.fail_engine(victim, max_retries=2)
    fail_us = (time.perf_counter() - t0) * 1e6
    done = router.drain()
    every = {c.rid for c in router.completions} >= {q.rid for q in reqs}
    emit("faults_engine_fail_recovery", fail_us,
         f"all_rids_complete={every} survivors={router.num_engines}")

    # ---- checkpoint save / restore round-trip --------------------------
    d = tempfile.mkdtemp(prefix="bench_faults_ckpt_")
    try:
        runner = sup0.runner
        t0 = time.perf_counter()
        runner.checkpoint(d, step=1)
        save_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        step = runner.restore(d)
        restore_us = (time.perf_counter() - t0) * 1e6
        emit("faults_ckpt_save", save_us, f"step={step}")
        emit("faults_ckpt_restore", restore_us, "params+opt+version")
    finally:
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
