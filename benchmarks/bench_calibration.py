"""Synthetic-bandwidth recovery for the Table-2 calibration loop.

Plant known B1/B2/B3 (chosen this-host-like: the instance-level
host-staged domain FAST, the cross-GPU interconnect slow — the regime
where the static ``ReduceCostModel`` defaults mis-rank strategies and the
host-staged mpr baseline actually wins, exactly what BENCH_lgr.json
measures on this machine), generate noisy Table-2 timings for every
feasible strategy on the 2x2 and 2x2x2 grids, feed them through the
``Communicator.observe()`` -> ``BandwidthCalibrator`` path, and assert

* the fit recovers all three planted bandwidths within 10%, and
* selection under the calibrated model flips to the truly-best strategy
  (mpr) on the 2x2x2 grid where the static defaults pick har3.

Rows ride in the ``lgr`` suite (BENCH_lgr.json) under the standard >2x
regression gate: ``calib_fit_us`` tracks the cost of one least-squares
inversion (it sits on the controller's per-epoch path), the ratio rows
carry recovery error and the selection flip.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit

# planted ground truth: host-staged domain fast, cross-GPU slow
PLANT = dict(bw_intra=400e9, bw_gpu=5e9, bw_dev=50e9)
MP = 6e6                      # SH policy gradient bytes (Table 7/8)
NOISE = 0.02                  # +-2% multiplicative timing jitter
SAMPLES = 4                   # per (strategy, grid); first is discarded


def run():
    from repro.comm import Communicator, ReduceCostModel

    truth = ReduceCostModel(bytes_per_round=MP, dev_per_inst=2, **PLANT)
    base = ReduceCostModel(bytes_per_round=MP, dev_per_inst=2)
    rng = np.random.default_rng(0)

    comm = Communicator("har3", grid=(2, 2, 2), cost_model=base,
                        calibrate=True)
    for grid in ((2, 2), (2, 2, 2)):
        for strat in truth.candidates(grid):
            for k in range(SAMPLES):
                sec = truth.time(strat, grid) \
                    * (1.0 + NOISE * rng.standard_normal())
                if grid == comm.grid:
                    # the live path: observe() discards the first sample
                    # per strategy and forwards the rest to the fit
                    comm.observe(sec, MP, strategy=strat)
                elif k > 0:   # pre-rebind history: steady samples only
                    comm.calibrator.add(strat, grid, sec, MP)

    reps = 50
    best_us = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(reps):
            fit = comm.calibrator.fit()       # uncached: full inversion
        best_us = min(best_us, (time.perf_counter() - t0) / reps * 1e6)
    assert fit is not None, "calibration fit refused well-conditioned data"

    errs = {axis: abs(fit.bandwidth(axis) - bw) / bw * 100.0
            for axis, bw in
            (("B1", PLANT["bw_intra"]), ("B2", PLANT["bw_gpu"]),
             ("B3", PLANT["bw_dev"]))}
    max_err = max(errs.values())
    assert max_err < 10.0, f"bandwidth recovery off by {max_err:.1f}% > 10%"

    grid = (2, 2, 2)
    default_pick = base.best(grid)
    planted_best = truth.best(grid)
    calibrated_pick = comm.effective_cost_model.best(grid)
    assert default_pick != planted_best, \
        "bench premise broken: static defaults already pick the planted best"
    assert calibrated_pick == planted_best, \
        f"calibrated model picked {calibrated_pick}, planted {planted_best}"
    # the live proposal agrees: measured evidence says switch to mpr
    assert comm.propose_switch(1.05) == planted_best

    emit("calib_fit_us", best_us,
         f"n_obs={fit.n_obs}_resid={fit.rel_residual:.1e}")
    emit("calib_recover_maxerr", 0.0,
         "_".join(f"{a}err={e:.2f}pct" for a, e in sorted(errs.items()))
         + "_tol=10pct")
    emit("calib_selection_flip", 0.0,
         f"default={default_pick}_calibrated={calibrated_pick}_"
         f"planted={planted_best}_flip=ok")
