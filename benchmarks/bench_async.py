"""Fig 11: async DRL training — PPS (predictions/s during serving) and
TTOP (training samples/s) for the GMI design (decoupled serving/training
instances + MCC channels) vs the non-GMI baseline (alternating monolith
with uni-channel transfers)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.channels import MultiChannelPipeline, UniChannelPipeline
from repro.core.placement import plan_async
from repro.envs import make_env
from repro.models.policy import init_policy
from repro.optim import adam_init
from repro.rl.a3c import actor_collect, trainer_update


def run(bench: str = "Anymal", rounds: int = 4, num_env: int = 128,
        steps: int = 16):
    env = make_env(bench)
    layout = plan_async(2, 1, 2, devices=list(range(4)), devices_per_gpu=2)

    def drive(pipeline_kind: str):
        params = init_policy(jax.random.key(0), env.spec.policy_dims)
        opt = adam_init(params)
        actors = {}
        for a in layout.serving_gmis:
            es, obs = env.reset(jax.random.PRNGKey(a), num_envs=num_env)
            actors[a] = [es, obs, jax.random.PRNGKey(a + 10)]
        mcc = MultiChannelPipeline(layout.serving_gmis, layout.trainer_gmis)
        ucc = UniChannelPipeline(layout.trainer_gmis)
        version = jnp.int32(0)
        preds = trained = 0
        t0 = time.perf_counter()
        for r in range(rounds):
            batches = []
            for a in layout.serving_gmis:
                es, obs, k = actors[a]
                exp, es, obs, k = actor_collect(params, version, env, es,
                                                obs, k, steps)
                actors[a] = [es, obs, k]
                preds += steps * num_env
                if pipeline_kind == "mcc":
                    mcc.push(a, exp)
                else:
                    ucc.send(exp)
                    # fine-grained field-by-field materialization
                    jax.block_until_ready([exp.obs, exp.actions,
                                           exp.rewards])
                    batches.append(exp)
            if pipeline_kind == "mcc":
                for dst, bs in mcc.flush().items():
                    batches = bs
            for exp in batches:
                params, opt, loss = trainer_update(params, opt, exp)
                jax.block_until_ready(loss)
                trained += exp.rewards.size
                version = version + 1
        dt = time.perf_counter() - t0
        return preds / dt, trained / dt, dt

    pps_g, ttop_g, dt_g = drive("mcc")
    pps_b, ttop_b, dt_b = drive("ucc")
    emit(f"async_gmi_{bench}", dt_g * 1e6 / rounds,
         f"PPS={pps_g:.0f}_TTOP={ttop_g:.0f}")
    emit(f"async_baseline_{bench}", dt_b * 1e6 / rounds,
         f"PPS={pps_b:.0f}_TTOP={ttop_b:.0f}")
    emit(f"async_speedup_{bench}", 0.0,
         f"pps={pps_g / pps_b:.2f}x_ttop={ttop_g / ttop_b:.2f}x_"
         f"paper~1.88x/1.65x")
