"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable

import jax

ROWS = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median-ish wall time per call in microseconds."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
