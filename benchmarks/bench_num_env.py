"""Fig 10: serving throughput and memory vs num_env (AT and HM) — the
saturation behaviour that drives Algorithm 2's Sat metric.

Two rows per common rung, both measuring the full PRODUCER (what an
AsyncRunner round actually pays to land one slot in the channel ring):

* vmap baseline — ``collect`` (per-env step under vmap, materialized
  auto-reset) stages a Trajectory, then ``pack_channels_xla`` re-copies
  it into the ring slot: the staged double copy.
* megakernel    — ``collect_ring``: one fused step program writes
  obs/action/reward/done straight into the ring slot; no staging, no
  re-copy.

The bench ASSERTS the megakernel producer strictly beats the staged
vmap producer at every common rung — the zero-copy path is a gate, not
a hope.  Timings are min-of-interleaved-samples: on a shared CPU a
noise spike only ever inflates a sample, so the min of several
alternating vmap/mega samples is the honest steady-state for a strict
comparison.  The megakernel ladder then extends to 131072 envs (Ant),
the 10^5 regime the single-kernel path exists for.

``mem_bytes`` is MEASURED: the sum of live device-buffer bytes each
path keeps resident per rollout (env state + observations + staged
trajectory + ring storage — the vmap path holds BOTH the trajectory and
the ring copy).  The old hand-derived formula survives as
``model_bytes`` for the Fig-10 curve shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.envs import make_env
from repro.kernels.channel_pack import alloc_rings, pack_channels_xla
from repro.models.policy import init_policy
from repro.rl.rollout import collect, collect_ring

T = 8          # rollout steps per timed call


def _model_bytes(spec, ne: int) -> int:
    """The legacy hand-derived rollout+state memory model (Fig 10)."""
    return 4 * ne * (spec.obs_dim * (T + 1) + spec.act_dim * (T + 2)
                     + 4 * T + spec.act_dim * 3 + 10)


def _live_bytes(*trees) -> int:
    return sum(x.nbytes for tr in trees for x in jax.tree.leaves(tr))


def _vmap_producer(env, params, ne: int):
    """collect -> staged Trajectory -> pack_channels_xla ring re-copy."""
    state, obs = env.reset(jax.random.PRNGKey(0), num_envs=ne)

    @jax.jit
    def coll(params, state, obs, key):
        return collect(params, env, state, obs, key, T)

    hold = {"st": [state, obs, jax.random.PRNGKey(1)],
            "traj": None, "bufs": None}

    def it():
        traj, s, o, lv, k = coll(params, *hold["st"])
        hold["traj"], hold["st"] = traj, [s, o, k]
        pay = {"obs": traj.obs, "actions": traj.actions,
               "rewards": traj.rewards, "dones": traj.dones,
               "bootstrap": lv, "actor_version": 0}
        if hold["bufs"] is None:
            hold["bufs"] = alloc_rings(pay, 1)
        hold["bufs"] = pack_channels_xla(hold["bufs"], pay, jnp.int32(0))
        return hold["bufs"]["dones"]

    def mem():
        return _live_bytes(hold["traj"], hold["st"][0], hold["st"][1],
                           hold["bufs"])

    return it, mem


def _mega_producer(env, params, ne: int):
    """collect_ring: fused step writes the ring slot directly."""
    state, obs = env.reset(jax.random.PRNGKey(0), num_envs=ne)
    spec = env.spec
    bufs = {"obs": jnp.zeros((T, ne, spec.obs_dim)),
            "actions": jnp.zeros((T, ne, spec.act_dim)),
            "rewards": jnp.zeros((T, ne)),
            "dones": jnp.zeros((T, ne))}
    st = [bufs, state, obs, jax.random.PRNGKey(1)]

    def it():
        st[0], st[1], st[2], boot, st[3] = collect_ring(
            params, env, st[1], st[2], st[3], T, st[0], 0)
        return boot

    def mem():
        return _live_bytes(st[0], st[1], st[2])

    return it, mem


def _race(it_v, it_m, samples: int = 7):
    """Interleaved min-of-samples: alternate the two producers so a load
    spike on the box penalizes both paths equally in expectation."""
    it_v(), it_m()                                     # compile + warm
    us_v = us_m = float("inf")
    for _ in range(samples):
        us_v = min(us_v, timeit(it_v, warmup=0, iters=1))
        us_m = min(us_m, timeit(it_m, warmup=0, iters=1))
    return us_v, us_m


def run(benches=("Ant", "Humanoid"), sweep=(128, 256, 512, 1024, 2048),
        mega_sweep=(4096, 16384, 65536, 131072)):
    for bench in benches:
        env_v = make_env(bench)
        env_m = env_v.with_megakernel(True)
        spec = env_v.spec
        params = init_policy(jax.random.key(0), spec.policy_dims)
        prev_top = None
        knee_ne, knee_top = None, None
        ladder = list(sweep) + (list(mega_sweep) if bench == "Ant" else [])
        for ne in ladder:
            common = ne in sweep
            if common:
                it_v, mem_v_fn = _vmap_producer(env_v, params, ne)
                it_m, mem_m_fn = _mega_producer(env_m, params, ne)
                us_v, us_m = _race(it_v, it_m)
                mem_v, mem_m = mem_v_fn(), mem_m_fn()
                top_v = T * ne / (us_v / 1e6)
                emit(f"numenv_{bench}_vmap_{ne}", us_v,
                     f"steps_per_s={top_v:.0f}_mem_bytes={mem_v}"
                     f"_model_bytes={_model_bytes(spec, ne)}")
            else:
                # big mega-only rungs take seconds per call — one mean
                it_m, mem_m_fn = _mega_producer(env_m, params, ne)
                us_m = timeit(it_m, warmup=1, iters=2)
                mem_m = mem_m_fn()
            top_m = T * ne / (us_m / 1e6)
            if common:
                # the zero-copy megakernel producer must strictly beat
                # the staged vmap producer at every rung both paths run
                assert top_m > top_v, (
                    f"megakernel path lost to vmap at {bench} ne={ne}: "
                    f"{top_m:.0f} vs {top_v:.0f} steps/s")
            sat = "" if prev_top is None else \
                f"_dTOP={top_m / prev_top - 1:+.2f}"
            if prev_top is not None and knee_ne is None \
                    and top_m < 1.10 * prev_top:
                knee_ne, knee_top = ne, top_m      # throughput saturates
            prev_top = top_m
            emit(f"numenv_{bench}_mega_{ne}", us_m,
                 f"steps_per_s={top_m:.0f}_mem_bytes={mem_m}"
                 f"_model_bytes={_model_bytes(spec, ne)}{sat}")
        if knee_ne is None:
            knee_ne, knee_top = ladder[-1], prev_top
        # ratio row (us=0.0: exempt from the regression gate) — where the
        # Sat metric says to stop climbing the ladder
        emit(f"numenv_{bench}_knee", 0.0,
             f"knee_ne={knee_ne}_steps_per_s={knee_top:.0f}")
