"""Fig 10: sync-training throughput and memory vs num_env (AT and HM) —
the saturation behaviour that drives Algorithm 2's Sat metric."""
from __future__ import annotations

import jax

from benchmarks.common import emit, timeit
from repro.envs import make_env
from repro.rl.ppo import PPOConfig, init_train, make_train_step


def run(benches=("Ant", "Humanoid"), sweep=(128, 256, 512, 1024, 2048)):
    cfg = PPOConfig(num_steps=8, num_epochs=1, num_minibatches=1)
    for bench in benches:
        env = make_env(bench)
        spec = env.spec
        prev_top = None
        for ne in sweep:
            params, opt, est, obs = init_train(
                jax.random.key(0), env, spec.policy_dims, num_envs=ne)
            step = make_train_step(env, cfg)
            k = jax.random.PRNGKey(0)
            state = [params, opt, est, obs, k]

            def it():
                state[0], state[1], state[2], state[3], state[4], m = \
                    step(*state)
                return m["loss"]

            us = timeit(it, warmup=1, iters=2)
            top = cfg.num_steps * ne / (us / 1e6)
            # rollout + state memory model (bytes)
            mem = 4 * ne * (spec.obs_dim * (cfg.num_steps + 1)
                            + spec.act_dim * (cfg.num_steps + 2)
                            + 4 * cfg.num_steps + spec.act_dim * 3 + 10)
            sat = "" if prev_top is None else \
                f"_dTOP={top / prev_top - 1:+.2f}"
            prev_top = top
            emit(f"numenv_{bench}_{ne}", us,
                 f"steps_per_s={top:.0f}_mem_bytes={mem}{sat}")
