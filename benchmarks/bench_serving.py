"""Fig 7(a): DRL serving throughput — TCG (colocated simulator+agent, the
paper's serving block) vs TDG (dedicated instances with a memory barrier
between them) — plus the request-serving engine rows (`run_engine`):
tok/s and p50/p95 latency of the ``repro.serve`` continuous-batching
engine under a synthetic open-loop arrival trace.

On this host the memory barrier of the TDG baseline is reproduced
faithfully as a host round-trip (device_get/device_put) between the
simulator instance and the agent instance — exactly the §5.1 argument for
why TDG loses: 2S+A+W crosses the boundary every interaction round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.cost_model import serving_speedup_tcg_over_tdg
from repro.envs import make_env
from repro.models.policy import init_policy, policy_apply, sample_action


def rollout_key(seed: int):
    """Single key-derivation idiom for BOTH serving paths (new-style typed
    keys everywhere — the TCG/TDG rollouts used to mix ``jax.random.key``
    and ``jax.random.PRNGKey`` in the same run)."""
    return jax.random.key(seed)


def run(num_env: int = 512, steps: int = 16, benches=("Ant", "Humanoid")):
    for bench in benches:
        env = make_env(bench)
        params = init_policy(rollout_key(0), env.spec.policy_dims)
        est, obs = env.reset(rollout_key(0), num_envs=num_env)

        # ---- TCG: one fused jitted serving block (COM = 0) --------------
        @jax.jit
        def tcg_rollout(params, est, obs, key):
            def step(carry, _):
                est, obs, key = carry
                key, ak = jax.random.split(key)
                mu, ls, _ = policy_apply(params, obs)
                act = sample_action(ak, mu, ls)
                est, obs, r, d = env.step(est, act)
                return (est, obs, key), r
            (est, obs, key), rs = jax.lax.scan(step, (est, obs, key), None,
                                               length=steps)
            return est, obs, key, rs.sum()

        us_tcg = timeit(lambda: tcg_rollout(params, est, obs,
                                            rollout_key(1)))

        # ---- TDG: simulator instance and agent instance with the GMI
        # memory barrier (host staging) between every interaction ----------
        sim_step = jax.jit(env.step)
        agent_step = jax.jit(
            lambda p, o, k: sample_action(
                k, *policy_apply(p, o)[:2]))

        def tdg_rollout():
            nonlocal est, obs
            e, o = est, obs
            k = rollout_key(1)
            for _ in range(steps):
                # agent GMI: obs crosses the barrier (S), action returns (A)
                o_host = np.asarray(o)                  # device -> host
                k, ak = jax.random.split(k)
                act = agent_step(params, jnp.asarray(o_host), ak)
                a_host = np.asarray(act)                # host -> device
                e, o, r, d = sim_step(e, jnp.asarray(a_host))
            return o

        us_tdg = timeit(tdg_rollout, warmup=1, iters=2)
        sps_tcg = steps * num_env / (us_tcg / 1e6)
        sps_tdg = steps * num_env / (us_tdg / 1e6)
        emit(f"serving_tcg_{bench}", us_tcg, f"steps_per_s={sps_tcg:.0f}")
        emit(f"serving_tdg_{bench}", us_tdg, f"steps_per_s={sps_tdg:.0f}")
        emit(f"serving_speedup_{bench}", 0.0,
             f"tcg_over_tdg={sps_tcg / sps_tdg:.2f}x_"
             f"(cost_model={serving_speedup_tcg_over_tdg():.2f}x_"
             f"paper~2.5x)")


def run_engine(arch: str = "internlm2-1.8b", slots: int = 4,
               n_requests: int = 12, arrivals_per_step: int = 1,
               prompt_len: int = 16, gen: int = 12):
    """Request-serving engine under a synthetic open-loop arrival trace:
    ``arrivals_per_step`` requests join per decode round until
    ``n_requests`` have arrived, then the engine drains.  Emits tok/s
    (us-per-generated-token timing row) and p50/p95 request latency."""
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve import Request, ServeEngine

    cfg = get_reduced(arch)
    params = T.init_model(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, max_slots=slots,
                         max_seq=prompt_len + gen + 4)
    rng = np.random.default_rng(0)

    def request():
        return Request(tokens=rng.integers(0, cfg.vocab_size, prompt_len),
                       max_new_tokens=gen)

    # warmup: compile prefill (one prompt length) + the batched decode
    engine.serve([request() for _ in range(2)])
    engine.telemetry.take_epoch()

    submitted = 0
    while submitted < n_requests or engine.busy:
        for _ in range(arrivals_per_step):
            if submitted < n_requests:
                engine.submit(request())
                submitted += 1
        engine.step()
    load = engine.telemetry.take_epoch(engine.cache_bytes)

    us_per_tok = load.dt / max(load.tokens, 1) * 1e6
    emit(f"serving_engine_tok_{arch}", us_per_tok,
         f"tok_s={load.tok_s:.0f}_slots={slots}_reqs={load.requests}")
    emit(f"serving_engine_p50_{arch}", load.p50_s * 1e6,
         f"p50_ms={load.p50_s*1e3:.1f}")
    emit(f"serving_engine_p95_{arch}", load.p95_s * 1e6,
         f"p95_ms={load.p95_s*1e3:.1f}")
    emit(f"serving_engine_occupancy_{arch}", 0.0,
         f"occ={load.occupancy_mean:.2f}_queue_mean={load.queue_depth_mean:.1f}")
