"""Fig 7(a): DRL serving throughput — TCG (colocated simulator+agent, the
paper's serving block) vs TDG (dedicated instances with a memory barrier
between them) — plus the request-serving engine rows (`run_engine`):
tok/s and p50/p95 latency of the ``repro.serve`` continuous-batching
engine under a synthetic open-loop arrival trace.

On this host the memory barrier of the TDG baseline is reproduced
faithfully as a host round-trip (device_get/device_put) between the
simulator instance and the agent instance — exactly the §5.1 argument for
why TDG loses: 2S+A+W crosses the boundary every interaction round.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.cost_model import serving_speedup_tcg_over_tdg
from repro.envs import make_env
from repro.models.policy import init_policy, policy_apply, sample_action


def rollout_key(seed: int):
    """Single key-derivation idiom for BOTH serving paths (new-style typed
    keys everywhere — the TCG/TDG rollouts used to mix ``jax.random.key``
    and ``jax.random.PRNGKey`` in the same run)."""
    return jax.random.key(seed)


def run(num_env: int = 512, steps: int = 16, benches=("Ant", "Humanoid")):
    for bench in benches:
        env = make_env(bench)
        params = init_policy(rollout_key(0), env.spec.policy_dims)
        est, obs = env.reset(rollout_key(0), num_envs=num_env)

        # ---- TCG: one fused jitted serving block (COM = 0) --------------
        @jax.jit
        def tcg_rollout(params, est, obs, key):
            def step(carry, _):
                est, obs, key = carry
                key, ak = jax.random.split(key)
                mu, ls, _ = policy_apply(params, obs)
                act = sample_action(ak, mu, ls)
                est, obs, r, d = env.step(est, act)
                return (est, obs, key), r
            (est, obs, key), rs = jax.lax.scan(step, (est, obs, key), None,
                                               length=steps)
            return est, obs, key, rs.sum()

        us_tcg = timeit(lambda: tcg_rollout(params, est, obs,
                                            rollout_key(1)))

        # ---- TDG: simulator instance and agent instance with the GMI
        # memory barrier (host staging) between every interaction ----------
        sim_step = jax.jit(env.step)
        agent_step = jax.jit(
            lambda p, o, k: sample_action(
                k, *policy_apply(p, o)[:2]))

        def tdg_rollout():
            nonlocal est, obs
            e, o = est, obs
            k = rollout_key(1)
            for _ in range(steps):
                # agent GMI: obs crosses the barrier (S), action returns (A)
                o_host = np.asarray(o)                  # device -> host
                k, ak = jax.random.split(k)
                act = agent_step(params, jnp.asarray(o_host), ak)
                a_host = np.asarray(act)                # host -> device
                e, o, r, d = sim_step(e, jnp.asarray(a_host))
            return o

        us_tdg = timeit(tdg_rollout, warmup=1, iters=2)
        sps_tcg = steps * num_env / (us_tcg / 1e6)
        sps_tdg = steps * num_env / (us_tdg / 1e6)
        emit(f"serving_tcg_{bench}", us_tcg, f"steps_per_s={sps_tcg:.0f}")
        emit(f"serving_tdg_{bench}", us_tdg, f"steps_per_s={sps_tdg:.0f}")
        emit(f"serving_speedup_{bench}", 0.0,
             f"tcg_over_tdg={sps_tcg / sps_tdg:.2f}x_"
             f"(cost_model={serving_speedup_tcg_over_tdg():.2f}x_"
             f"paper~2.5x)")


def run_engine(arch: str = "internlm2-1.8b", slots: int = 4,
               n_requests: int = 12, arrivals_per_step: int = 1,
               prompt_len: int = 16, gen: int = 12):
    """Request-serving engine under a synthetic open-loop arrival trace:
    ``arrivals_per_step`` requests join per decode round until
    ``n_requests`` have arrived, then the engine drains.  Emits tok/s
    (us-per-generated-token timing row) and p50/p95 request latency."""
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve import Request, ServeEngine

    cfg = get_reduced(arch)
    params = T.init_model(jax.random.key(0), cfg)
    engine = ServeEngine(cfg, params, max_slots=slots,
                         max_seq=prompt_len + gen + 4)
    rng = np.random.default_rng(0)

    def request():
        return Request(tokens=rng.integers(0, cfg.vocab_size, prompt_len),
                       max_new_tokens=gen)

    # warmup: compile prefill (one prompt length) + the batched decode.
    # The paged engine coalesces same-length prompts into one B=G
    # dispatch, so warm BOTH group sizes this trace dispatches: G=1
    # (open-loop arrivals) and G=2 (the coalesced pair)
    engine.serve([request()])
    engine.serve([request() for _ in range(2)])
    engine.telemetry.take_epoch()

    submitted = 0
    while submitted < n_requests or engine.busy:
        for _ in range(arrivals_per_step):
            if submitted < n_requests:
                engine.submit(request())
                submitted += 1
        engine.step()
    load = engine.telemetry.take_epoch(engine.cache_bytes)

    us_per_tok = load.dt / max(load.tokens, 1) * 1e6
    emit(f"serving_engine_tok_{arch}", us_per_tok,
         f"tok_s={load.tok_s:.0f}_slots={slots}_reqs={load.requests}")
    emit(f"serving_engine_p50_{arch}", load.p50_s * 1e6,
         f"p50_ms={load.p50_s*1e3:.1f}")
    emit(f"serving_engine_p95_{arch}", load.p95_s * 1e6,
         f"p95_ms={load.p95_s*1e3:.1f}")
    emit(f"serving_engine_occupancy_{arch}", 0.0,
         f"occ={load.occupancy_mean:.2f}_queue_mean={load.queue_depth_mean:.1f}")


def run_paged(arch: str = "internlm2-1.8b", prompt_len: int = 16,
              gen: int = 8, max_seq: int = 64, page: int = 8,
              n_requests: int = 16):
    """Paged-cache serving rows (ISSUE: long-context serving depth).

    * ``serving_paged_tok`` / ``p50`` / ``p95`` — the open-loop trace of
      :func:`run_engine` through the PAGED engine (the default regime),
      for a perf trajectory on the paged decode path itself.
    * ``serving_paged_admit`` — admitted concurrency at a FIXED cache
      memory budget: a dense engine spends ``max_seq`` rows per slot up
      front, the paged engine only ``ceil((prompt+gen)/page)`` pages per
      request — same bytes, strictly more simultaneous requests.  The
      claim is asserted in-bench, not just emitted.
    * ``serving_stall_whole`` / ``serving_stall_chunked`` — worst single
      decode-step wall time while a long prompt is admitted mid-decode:
      a whole-prompt prefill stalls every in-flight request for the full
      prompt, chunked prefill bounds the stall to one chunk per step
      (asserted: chunked < whole).
    """
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve import Request, ServeEngine

    cfg = get_reduced(arch)
    params = T.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    def request(n=prompt_len, g=gen):
        return Request(tokens=rng.integers(0, cfg.vocab_size, n),
                       max_new_tokens=g)

    # ---- paged engine under the run_engine open-loop trace --------------
    eng = ServeEngine(cfg, params, max_slots=4, max_seq=prompt_len + gen + 4)
    eng.serve([request() for _ in range(2)])     # compile
    eng.telemetry.take_epoch()
    submitted = 0
    while submitted < 12 or eng.busy:
        if submitted < 12:
            eng.submit(request())
            submitted += 1
        eng.step()
    load = eng.telemetry.take_epoch(eng.cache_bytes)
    emit(f"serving_paged_tok_{arch}", load.dt / max(load.tokens, 1) * 1e6,
         f"tok_s={load.tok_s:.0f}_pages={eng.total_pages}")
    emit(f"serving_paged_p50_{arch}", load.p50_s * 1e6,
         f"p50_ms={load.p50_s*1e3:.1f}")
    emit(f"serving_paged_p95_{arch}", load.p95_s * 1e6,
         f"p95_ms={load.p95_s*1e3:.1f}")

    # ---- admitted concurrency at a fixed cache-memory budget ------------
    # budget: 4 dense slots x max_seq tokens == 4 * (max_seq/page) pages
    dense_slots = 4
    budget_pages = dense_slots * (max_seq // page)
    dense = ServeEngine(cfg, params, max_slots=dense_slots, max_seq=max_seq,
                        paged=False)
    paged = ServeEngine(cfg, params, max_slots=n_requests, max_seq=max_seq,
                        page_size=page, num_pages=budget_pages + 1,
                        share_prefix=False)

    def peak_admitted(engine):
        for _ in range(n_requests):
            engine.submit(request())
        peak = 0
        while engine.busy:
            engine.step()
            peak = max(peak, engine.active_count)
        return peak

    d_peak = peak_admitted(dense)
    p_peak = peak_admitted(paged)
    assert p_peak > d_peak, \
        (f"paged engine admitted {p_peak} <= dense {d_peak} at the same "
         f"{budget_pages * page}-token cache budget")
    emit(f"serving_paged_admit_{arch}", 0.0,
         f"paged={p_peak}_dense={d_peak}_budget_tokens={budget_pages * page}")

    # ---- worst-case decode stall: whole vs chunked prefill --------------
    # the long prompt must dominate a decode dispatch for the stall to be
    # measurable over host noise: 384 prompt tokens ~ 10x one chunk
    long_len = 24 * prompt_len

    def worst_stall(chunk):
        e = ServeEngine(cfg, params, max_slots=4, max_seq=long_len + 32,
                        chunk_prefill=chunk, share_prefix=False)

        def trace(measure):
            for _ in range(3):
                e.submit(request())
            e.step()                                 # shorts decoding
            e.submit(request(long_len, 2))           # long prompt arrives
            worst = 0.0
            while e.busy:
                t0 = time.perf_counter()
                e.step()
                worst = max(worst, time.perf_counter() - t0)
            return worst

        trace(False)            # compile every shape this trace dispatches
        return trace(True)

    whole = worst_stall(0)
    # chunk > prompt_len: the steady short prompts keep their one-shot
    # prefill; only the long prompt is chunked — the stall under test
    chunked = worst_stall(prompt_len)
    assert chunked < whole, \
        (f"chunked prefill did not reduce the worst decode stall: "
         f"{chunked*1e3:.2f}ms vs whole {whole*1e3:.2f}ms")
    emit(f"serving_stall_whole_{arch}", whole * 1e6,
         f"stall_ms={whole*1e3:.2f}")
    emit(f"serving_stall_chunked_{arch}", chunked * 1e6,
         f"stall_ms={chunked*1e3:.2f}_reduction={whole/max(chunked,1e-9):.2f}x")
