"""Fig 7(a): DRL serving throughput — TCG (colocated simulator+agent, the
paper's serving block) vs TDG (dedicated instances with a memory barrier
between them).

On this host the memory barrier of the TDG baseline is reproduced
faithfully as a host round-trip (device_get/device_put) between the
simulator instance and the agent instance — exactly the §5.1 argument for
why TDG loses: 2S+A+W crosses the boundary every interaction round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.cost_model import serving_speedup_tcg_over_tdg
from repro.envs import make_env
from repro.models.policy import init_policy, policy_apply, sample_action


def run(num_env: int = 512, steps: int = 16, benches=("Ant", "Humanoid")):
    for bench in benches:
        env = make_env(bench)
        params = init_policy(jax.random.key(0), env.spec.policy_dims)
        est, obs = env.reset(jax.random.PRNGKey(0), num_envs=num_env)

        # ---- TCG: one fused jitted serving block (COM = 0) --------------
        @jax.jit
        def tcg_rollout(params, est, obs, key):
            def step(carry, _):
                est, obs, key = carry
                key, ak = jax.random.split(key)
                mu, ls, _ = policy_apply(params, obs)
                act = sample_action(ak, mu, ls)
                est, obs, r, d = env.step(est, act)
                return (est, obs, key), r
            (est, obs, key), rs = jax.lax.scan(step, (est, obs, key), None,
                                               length=steps)
            return est, obs, key, rs.sum()

        key = jax.random.PRNGKey(1)
        us_tcg = timeit(lambda: tcg_rollout(params, est, obs, key))

        # ---- TDG: simulator instance and agent instance with the GMI
        # memory barrier (host staging) between every interaction ----------
        sim_step = jax.jit(env.step)
        agent_step = jax.jit(
            lambda p, o, k: sample_action(
                k, *policy_apply(p, o)[:2]))

        def tdg_rollout():
            nonlocal est, obs
            e, o = est, obs
            k = jax.random.PRNGKey(1)
            for _ in range(steps):
                # agent GMI: obs crosses the barrier (S), action returns (A)
                o_host = np.asarray(o)                  # device -> host
                k, ak = jax.random.split(k)
                act = agent_step(params, jnp.asarray(o_host), ak)
                a_host = np.asarray(act)                # host -> device
                e, o, r, d = sim_step(e, jnp.asarray(a_host))
            return o

        us_tdg = timeit(tdg_rollout, warmup=1, iters=2)
        sps_tcg = steps * num_env / (us_tcg / 1e6)
        sps_tdg = steps * num_env / (us_tdg / 1e6)
        emit(f"serving_tcg_{bench}", us_tcg, f"steps_per_s={sps_tcg:.0f}")
        emit(f"serving_tdg_{bench}", us_tdg, f"steps_per_s={sps_tdg:.0f}")
        emit(f"serving_speedup_{bench}", 0.0,
             f"tcg_over_tdg={sps_tcg / sps_tdg:.2f}x_"
             f"(cost_model={serving_speedup_tcg_over_tdg():.2f}x_"
             f"paper~2.5x)")
