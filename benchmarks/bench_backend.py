"""Fig 8: GMI backend comparison (Direct-Share vs MPS-like vs MIG-like).

Hardware-level MPS/MIG contention cannot be measured on one CPU device;
this benchmark reports (i) a MEASURED contention proxy — two DRL workloads
interleaved on one device (direct share) vs run in isolation (perfect
partition) — and (ii) the analytic isolation model used in DESIGN.md §2.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.envs import make_env
from repro.rl.ppo import PPOConfig, init_train, make_train_step


def run(bench: str = "Ant", num_env: int = 256):
    env = make_env(bench)
    cfg = PPOConfig(num_steps=8, num_epochs=1, num_minibatches=1)

    def make(seed):
        p, o, es, ob = init_train(jax.random.key(seed), env,
                                  env.spec.policy_dims, num_env // 2)
        return [p, o, es, ob, jax.random.PRNGKey(seed)], \
            make_train_step(env, cfg)

    (s1, f1), (s2, f2) = make(0), make(1)
    # warm
    s1[0], s1[1], s1[2], s1[3], s1[4], _ = f1(*s1)
    s2[0], s2[1], s2[2], s2[3], s2[4], _ = f2(*s2)

    # direct share: the two instances' work interleaves on one device
    t0 = time.perf_counter()
    for _ in range(3):
        s1[0], s1[1], s1[2], s1[3], s1[4], m1 = f1(*s1)
        s2[0], s2[1], s2[2], s2[3], s2[4], m2 = f2(*s2)
    jax.block_until_ready((m1["loss"], m2["loss"]))
    dt_share = (time.perf_counter() - t0) / 3

    # isolated slices: each runs alone (per-instance time, then summed as if
    # the two partitions ran concurrently on disjoint resources)
    t0 = time.perf_counter()
    for _ in range(3):
        s1[0], s1[1], s1[2], s1[3], s1[4], m1 = f1(*s1)
    jax.block_until_ready(m1["loss"])
    dt_iso = (time.perf_counter() - t0) / 3

    top_share = 2 * cfg.num_steps * (num_env // 2) / dt_share
    top_iso = 2 * cfg.num_steps * (num_env // 2) / max(dt_iso, 1e-9)
    emit(f"backend_direct_share_{bench}", dt_share * 1e6,
         f"steps_per_s={top_share:.0f}")
    emit(f"backend_partitioned_{bench}", dt_iso * 1e6,
         f"steps_per_s={top_iso:.0f}_isolation_gain="
         f"{top_iso / top_share:.2f}x")
    # analytic (paper Fig 8 trend): MIG >= MPS > direct share on complex
    # benches; difference shrinks on light ones
    emit(f"backend_model_{bench}", 0.0,
         "ranking=MIG>=MPS>direct_share_per_paper_fig8")
