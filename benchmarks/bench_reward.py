"""Fig 9: reward accumulation over wall-clock training time — GMI layout
(2 holistic instances with policy sync) vs single-instance baseline, on AT
and AY (short CPU-budget runs; the TREND is the reproduction target)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.envs import make_env
from repro.rl.ppo import PPOConfig, init_train, make_train_step


def _train(bench, n_inst, num_env_total, budget_s):
    env = make_env(bench)
    cfg = PPOConfig(num_steps=16, num_epochs=2, num_minibatches=2, lr=1e-3)
    insts = []
    step = make_train_step(env, cfg)
    for i in range(n_inst):
        p, o, es, ob = init_train(jax.random.key(i), env,
                                  env.spec.policy_dims,
                                  num_env_total // n_inst)
        insts.append([p, o, es, ob, jax.random.PRNGKey(i)])
    # warm-up compile outside the budget
    for s in insts:
        s[0], s[1], s[2], s[3], s[4], _ = step(*s)
    t0 = time.perf_counter()
    acc = 0.0
    while time.perf_counter() - t0 < budget_s:
        ms = []
        for s in insts:
            s[0], s[1], s[2], s[3], s[4], m = step(*s)
            ms.append(float(m["reward_sum"]))
        acc += float(np.mean(ms))
        if n_inst > 1:
            mean_p = jax.tree.map(lambda *xs: sum(xs) / n_inst,
                                  *[s[0] for s in insts])
            for s in insts:
                s[0] = mean_p
    return acc


def run(benches=("Ant", "Anymal"), budget_s: float = 6.0):
    for bench in benches:
        acc_gmi = _train(bench, 2, 256, budget_s)
        acc_base = _train(bench, 1, 256, budget_s)
        emit(f"reward_accum_gmi_{bench}", budget_s * 1e6,
             f"acc_reward={acc_gmi:.1f}")
        emit(f"reward_accum_base_{bench}", budget_s * 1e6,
             f"acc_reward={acc_base:.1f}_gmi_ratio="
             f"{acc_gmi / max(acc_base, 1e-9):.2f}x")
