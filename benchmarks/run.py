# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  Mapping to the paper:
#   bench_serving        — Fig 7(a)  TCG vs TDG serving throughput, plus
#                          repro.serve engine rows (tok/s, p50/p95 under
#                          an open-loop arrival trace)
#   bench_sync_training  — Fig 7(b,c) sync PPO: holistic GMI vs dedicated
#   bench_lgr            — Table 7   LGR (MRR/HAR) vs MPR baseline
#   bench_mcc            — Table 8   multi-channel vs uni-channel sharing
#   bench_num_env        — Fig 10    throughput/memory vs num_env
#   bench_async          — Fig 11    async PPS / TTOP
#   bench_selection      — Alg 2     profiling-based GMI search
#   bench_backend        — Fig 8     backend isolation comparison
#   bench_reward         — Fig 9     reward accumulation over time
#   bench_kernels        — Pallas kernels (interpret-mode correctness cost)
#   bench_calibration    — Table-2 bandwidth calibration (synthetic
#                          recovery; rides in the lgr suite)
#   bench_faults         — fault-recovery cost (GMI kill / engine fail /
#                          checkpoint round-trip) + goodput retention
#   bench_disagg         — disaggregated prefill/decode serving: migrated
#                          vs local path, tok/s per role, migrate-vs-local
#                          crossover from measured Table-2 terms
#   roofline             — §Roofline terms from the dry-run artifacts
#
# Every invocation starts with the repro.analysis static pre-flight
# (python -m repro.analysis --strict): a tree with findings — tracked
# bytecode included — exits 1 before any suite runs, so it can never
# re-baseline a BENCH json.
#
# ``--quick`` runs only the perf-trajectory tier (bench_mcc + bench_kernels
# + bench_lgr + bench_serving + bench_faults + bench_disagg +
# bench_num_env, interpret mode on CPU),
# writes BENCH_*.json
# artifacts so
# future PRs have before/after numbers to diff against, and FAILS (exit 1)
# when any row regresses more than REGRESSION_FACTOR against the committed
# baseline — the perf trajectory is enforced, not advisory.  Re-baselining
# on a different machine: BENCH_ALLOW_REGRESSION=1 python -m benchmarks.run
# --quick.
import json
import os
import sys
import traceback

REGRESSION_FACTOR = 2.0

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def _parse_rows(rows):
    out = [dict(zip(("name", "us_per_call", "derived"), r.split(",", 2)))
           for r in rows]
    for r in out:
        r["us_per_call"] = float(r["us_per_call"])
    return out


def _dump_rows(path: str, suite: str, rows) -> None:
    payload = {"suite": suite, "rows": _parse_rows(rows)}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {path}", file=sys.stderr)


def _check_regressions(path: str, rows, strict: bool = False) -> tuple:
    """Compare fresh rows against the committed baseline.

    Returns ``(regressions, missing)``: a timing row more than
    REGRESSION_FACTOR slower is a regression; ratio rows (us_per_call ==
    0) and rows new to this baseline are skipped.  ``missing`` lists
    baseline rows ABSENT from the fresh run — a deleted or renamed bench
    would otherwise hide its regression forever, because rewriting the
    baseline silently drops the old row.  Missing rows are warnings by
    default and additionally folded into ``regressions`` (i.e. failures)
    when ``strict``."""
    if not os.path.exists(path):
        return [], []
    with open(path) as f:
        base = {r["name"]: r["us_per_call"] for r in json.load(f)["rows"]}
    fresh = {r["name"]: r for r in _parse_rows(rows)}
    regs = []
    for r in fresh.values():
        old = base.get(r["name"], 0.0)
        if old > 0.0 and r["us_per_call"] > REGRESSION_FACTOR * old:
            regs.append(f"{r['name']}: {r['us_per_call']:.1f}us vs "
                        f"baseline {old:.1f}us "
                        f"({r['us_per_call'] / old:.2f}x > "
                        f"{REGRESSION_FACTOR}x)")
    missing = sorted(n for n in base if n not in fresh)
    # BENCH_PAGED_BASELINE=1: one-run escape hatch for the paged-serving
    # row reshuffle (serving_paged_*/serving_stall_*/disagg_page_* rows
    # replacing or joining older names) — strict missing-row failures
    # downgrade to warnings so the re-baseline run can rewrite the JSON
    if strict and not os.environ.get("BENCH_PAGED_BASELINE"):
        regs.extend(f"{n}: baseline row missing from this run (deleted "
                    f"or renamed bench? an intentional paged-serving row "
                    f"rename re-baselines with BENCH_PAGED_BASELINE=1)"
                    for n in missing)
    return regs, missing


def _analysis_findings(root: str) -> list:
    """Static-analysis pre-flight (``python -m repro.analysis``): the
    full rule battery, including the tracked-bytecode hygiene check that
    used to live here as a private ``git ls-files`` filter.  A violating
    tree can never run the suites, so it can never re-baseline a BENCH
    json."""
    from repro.analysis import run_analysis
    from repro.analysis.__main__ import DEFAULT_PATHS
    paths = [os.path.join(root, d) for d in DEFAULT_PATHS
             if os.path.isdir(os.path.join(root, d))]
    return run_analysis(paths, root=root)


def main() -> None:
    from benchmarks import (bench_async, bench_backend, bench_calibration,
                            bench_disagg, bench_faults, bench_kernels,
                            bench_lgr, bench_mcc, bench_num_env,
                            bench_reward, bench_selection, bench_serving,
                            bench_sync_training, roofline)
    from benchmarks.common import ROWS, emit

    findings = _analysis_findings(_ROOT)
    if findings:
        print("# STATIC ANALYSIS FINDINGS (python -m repro.analysis "
              "--strict; fix them or annotate `# repro: allow(<rule>)`):",
              file=sys.stderr)
        for f in findings:
            print(f"#   {f.format()}", file=sys.stderr)
        raise SystemExit(1)

    def lgr_suite():
        # calibration rows ride in the lgr suite: both land in
        # BENCH_lgr.json under the same regression gate
        bench_lgr.run()
        bench_calibration.run()

    def disagg_suite():
        # migrated-vs-local rows + the paged-wire rows (per-page migrate
        # cost, partial-migration crossover, shared-prefix bytes saved);
        # one BENCH_disagg.json under the same gate
        bench_disagg.run()
        bench_disagg.run_paged()

    def serving_suite():
        # Fig 7(a) TCG/TDG rows + the repro.serve continuous-batching
        # engine rows (tok/s, p50/p95 under an open-loop arrival trace);
        # both land in BENCH_serving.json under the regression gate
        bench_serving.run()
        bench_serving.run_engine()
        # paged-cache rows: paged tok/s + p50/p95, admitted concurrency
        # at a fixed cache budget (asserted > dense), decode-stall with
        # vs without chunked prefill (asserted smaller)
        bench_serving.run_paged()

    print("name,us_per_call,derived")
    suites = [
        ("serving", serving_suite),
        ("sync_training", bench_sync_training.run),
        ("lgr", lgr_suite),
        ("mcc", bench_mcc.run),
        ("num_env", bench_num_env.run),
        ("async", bench_async.run),
        ("selection", bench_selection.run),
        ("backend", bench_backend.run),
        ("reward", bench_reward.run),
        ("kernels", bench_kernels.run),
        ("faults", bench_faults.run),
        ("disagg", disagg_suite),
        ("roofline", roofline.run),
    ]
    flags = {"--quick", "--strict"}
    args = [a for a in sys.argv[1:] if a not in flags]
    quick = "--quick" in sys.argv[1:]
    # strict: a baseline row missing from the fresh run (deleted/renamed
    # bench) is a gate FAILURE instead of a warning
    strict = "--strict" in sys.argv[1:] \
        or bool(os.environ.get("BENCH_STRICT"))
    only = args[0].split(",") if args else None
    if quick and only is None:
        only = ["mcc", "kernels", "lgr", "serving", "faults", "disagg",
                "num_env"]
        # an explicit selection wins; --quick then only adds the JSON
        # artifacts
    allow_regression = bool(os.environ.get("BENCH_ALLOW_REGRESSION"))
    failed = []
    regressions = []
    for name, fn in suites:
        if only and name not in only:
            continue
        start = len(ROWS)
        ok = True
        try:
            fn()
        except Exception as e:
            ok = False
            failed.append(name)
            emit(f"{name}_SUITE_FAILED", 0.0, repr(e)[:120])
            traceback.print_exc(file=sys.stderr)
        if quick and ok:
            path = f"BENCH_{name}.json"
            regs, missing = _check_regressions(path, ROWS[start:],
                                               strict=strict)
            for m in missing:
                print(f"# WARNING: {name}: baseline row {m!r} absent "
                      f"from this run — deleting/renaming a bench hides "
                      f"its regression (run with --strict to fail)",
                      file=sys.stderr)
            if regs and not allow_regression:
                # keep the last good baseline so the next run still has
                # something honest to diff against
                regressions.extend(regs)
                print(f"# NOT rewriting {path} (regressions)",
                      file=sys.stderr)
            else:
                # never clobber the last good baseline with a partial run
                _dump_rows(path, name, ROWS[start:])
    if regressions:
        print("# PERF REGRESSIONS (>"
              f"{REGRESSION_FACTOR}x vs committed baseline; "
              "set BENCH_ALLOW_REGRESSION=1 to re-baseline):",
              file=sys.stderr)
        for r in regressions:
            print(f"#   {r}", file=sys.stderr)
    if failed:
        print(f"# FAILED SUITES: {failed}", file=sys.stderr)
    if failed or regressions:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
