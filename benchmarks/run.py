# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  Mapping to the paper:
#   bench_serving        — Fig 7(a)  TCG vs TDG serving throughput
#   bench_sync_training  — Fig 7(b,c) sync PPO: holistic GMI vs dedicated
#   bench_lgr            — Table 7   LGR (MRR/HAR) vs MPR baseline
#   bench_mcc            — Table 8   multi-channel vs uni-channel sharing
#   bench_num_env        — Fig 10    throughput/memory vs num_env
#   bench_async          — Fig 11    async PPS / TTOP
#   bench_selection      — Alg 2     profiling-based GMI search
#   bench_backend        — Fig 8     backend isolation comparison
#   bench_reward         — Fig 9     reward accumulation over time
#   bench_kernels        — Pallas kernels (interpret-mode correctness cost)
#   roofline             — §Roofline terms from the dry-run artifacts
#
# ``--quick`` runs only the perf-trajectory tier (bench_mcc + bench_kernels,
# interpret mode on CPU) and writes BENCH_mcc.json / BENCH_kernels.json so
# future PRs have before/after numbers to diff against.
import json
import os
import sys
import traceback

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def _dump_rows(path: str, suite: str, rows) -> None:
    payload = {"suite": suite,
               "rows": [dict(zip(("name", "us_per_call", "derived"),
                                 r.split(",", 2))) for r in rows]}
    for r in payload["rows"]:
        r["us_per_call"] = float(r["us_per_call"])
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    from benchmarks import (bench_async, bench_backend, bench_kernels,
                            bench_lgr, bench_mcc, bench_num_env,
                            bench_reward, bench_selection, bench_serving,
                            bench_sync_training, roofline)
    from benchmarks.common import ROWS, emit

    print("name,us_per_call,derived")
    suites = [
        ("serving", bench_serving.run),
        ("sync_training", bench_sync_training.run),
        ("lgr", bench_lgr.run),
        ("mcc", bench_mcc.run),
        ("num_env", bench_num_env.run),
        ("async", bench_async.run),
        ("selection", bench_selection.run),
        ("backend", bench_backend.run),
        ("reward", bench_reward.run),
        ("kernels", bench_kernels.run),
        ("roofline", roofline.run),
    ]
    args = [a for a in sys.argv[1:] if a != "--quick"]
    quick = "--quick" in sys.argv[1:]
    only = args[0].split(",") if args else None
    if quick and only is None:
        only = ["mcc", "kernels"]   # an explicit selection wins; --quick
                                    # then only adds the JSON artifacts
    failed = []
    for name, fn in suites:
        if only and name not in only:
            continue
        start = len(ROWS)
        ok = True
        try:
            fn()
        except Exception as e:
            ok = False
            failed.append(name)
            emit(f"{name}_SUITE_FAILED", 0.0, repr(e)[:120])
            traceback.print_exc(file=sys.stderr)
        if quick and ok:
            # never clobber the last good baseline with a partial run
            _dump_rows(f"BENCH_{name}.json", name, ROWS[start:])
    if failed:
        print(f"# FAILED SUITES: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
