# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows.  Mapping to the paper:
#   bench_serving        — Fig 7(a)  TCG vs TDG serving throughput
#   bench_sync_training  — Fig 7(b,c) sync PPO: holistic GMI vs dedicated
#   bench_lgr            — Table 7   LGR (MRR/HAR) vs MPR baseline
#   bench_mcc            — Table 8   multi-channel vs uni-channel sharing
#   bench_num_env        — Fig 10    throughput/memory vs num_env
#   bench_async          — Fig 11    async PPS / TTOP
#   bench_selection      — Alg 2     profiling-based GMI search
#   bench_backend        — Fig 8     backend isolation comparison
#   bench_reward         — Fig 9     reward accumulation over time
#   bench_kernels        — Pallas kernels (interpret-mode correctness cost)
#   roofline             — §Roofline terms from the dry-run artifacts
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_async, bench_backend, bench_kernels,
                            bench_lgr, bench_mcc, bench_num_env,
                            bench_reward, bench_selection, bench_serving,
                            bench_sync_training, roofline)
    from benchmarks.common import emit

    print("name,us_per_call,derived")
    suites = [
        ("serving", bench_serving.run),
        ("sync_training", bench_sync_training.run),
        ("lgr", bench_lgr.run),
        ("mcc", bench_mcc.run),
        ("num_env", bench_num_env.run),
        ("async", bench_async.run),
        ("selection", bench_selection.run),
        ("backend", bench_backend.run),
        ("reward", bench_reward.run),
        ("kernels", bench_kernels.run),
        ("roofline", roofline.run),
    ]
    only = sys.argv[1].split(",") if len(sys.argv) > 1 else None
    failed = []
    for name, fn in suites:
        if only and name not in only:
            continue
        try:
            fn()
        except Exception as e:
            failed.append(name)
            emit(f"{name}_SUITE_FAILED", 0.0, repr(e)[:120])
            traceback.print_exc(file=sys.stderr)
    if failed:
        print(f"# FAILED SUITES: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
