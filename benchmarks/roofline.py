"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape × mesh) record:
  compute term    = HLO_dot_FLOPs_per_chip / peak_FLOP/s        [s]
  memory term     = HLO_traffic_bytes_per_chip / HBM_bw         [s]
  collective term = collective_bytes_per_chip / ICI_link_bw     [s]
(all three loop-aware, from repro.launch.hlo_analysis — XLA's own
cost_analysis counts while bodies once and reports no collectives)

plus MODEL_FLOPS = 6·N·D (train, dense) / 6·N_active·D (MoE) /
2·N_active·tokens (decode), the useful-compute ratio, the dominant term,
and a one-line "what would move it" note.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK = 197e12       # bf16 FLOP/s per v5e chip
HBM = 819e9         # B/s per chip
ICI = 50e9          # B/s per link (conservative: 1 link counted per chip)

_PARAM_CACHE: Dict[str, Dict] = {}


def _param_counts(arch: str) -> Dict[str, float]:
    """(total, active) parameter counts; cached, computed via eval_shape."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax
    from repro.configs import get_config
    from repro.models.transformer import init_abstract
    cfg = get_config(arch)
    shapes = init_abstract(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = expert = 0
    for path, leaf in flat:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        keys = [k.key for k in path if hasattr(k, "key")]
        if "moe" in keys and keys[-1] != "router":
            expert += n
    if cfg.num_experts:
        active = total - expert + expert * cfg.experts_per_token \
            / cfg.num_experts
    else:
        active = total
    _PARAM_CACHE[arch] = {"total": float(total), "active": float(active)}
    return _PARAM_CACHE[arch]


def model_flops(arch: str, shape_name: str, chips: int) -> float:
    """Per-chip useful FLOPs for this step."""
    from repro.configs import INPUT_SHAPES
    shape = INPUT_SHAPES[shape_name]
    n = _param_counts(arch)["active"]
    if shape.mode == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens / chips
    if shape.mode == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens / chips
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch / chips


def _advice(dom: str, rec: dict) -> str:
    if dom == "collective":
        return ("reduce resharding: align activation/KV shardings with the "
                "consuming matmuls (fewer all-gathers per layer)")
    if dom == "memory":
        return ("cut HBM traffic: larger fused blocks / flash-attention "
                "tiling; keep weights resident across the layer scan")
    return ("compute-bound: raise MFU via MXU-aligned tiles and fewer "
            "recompute FLOPs (remat policy)")


def load_records(art_dir: str = "artifacts/dryrun",
                 lgr: Optional[str] = None,
                 act: Optional[str] = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        if lgr and r.get("lgr") != lgr:
            continue
        if act and r.get("act_sharding") != act:
            continue
        recs.append(r)
    return recs


def analyze_record(r: dict) -> dict:
    t_comp = r["hlo_dot_flops"] / PEAK
    t_mem = r["hlo_traffic_bytes"] / HBM
    t_coll = r["collective_bytes"] / ICI
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(r["arch"], r["shape"], r["chips"])
    useful = mf / max(r["hlo_dot_flops"], 1.0)
    bound = max(terms.values())
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dom,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": t_comp / bound if bound else 0.0,
        "mem_gib": r["mem_per_device_bytes"] / 2**30,
        "advice": _advice(dom, r),
    }


def table(art_dir: str = "artifacts/dryrun", mesh: str = "16x16",
          lgr: str = "har", act: str = "dmodel") -> str:
    rows = ["| arch | shape | t_comp(s) | t_mem(s) | t_coll(s) | dominant |"
            " MODEL/HLO | roofline-frac | mem GiB | fix |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in load_records(art_dir, lgr, act):
        if r["mesh"] != mesh:
            continue
        a = analyze_record(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {a['t_compute']:.3e} | "
            f"{a['t_memory']:.3e} | {a['t_collective']:.3e} | "
            f"{a['dominant']} | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.2f} | {a['mem_gib']:.1f} | "
            f"{a['advice'][:40]}... |")
    return "\n".join(rows)


def run():
    from benchmarks.common import emit
    recs = load_records()
    if not recs:
        emit("roofline", 0.0, "NO_DRYRUN_ARTIFACTS_run_repro.launch.dryrun")
        return
    for r in recs:
        if r["mesh"] != "16x16":
            continue
        a = analyze_record(r)
        bound_us = max(a["t_compute"], a["t_memory"], a["t_collective"]) * 1e6
        emit(f"roofline_{r['arch']}_{r['shape']}", bound_us,
             f"dom={a['dominant']}_comp={a['t_compute']:.2e}"
             f"_mem={a['t_memory']:.2e}_coll={a['t_collective']:.2e}"
             f"_useful={a['useful_ratio']:.2f}")


if __name__ == "__main__":
    print(table())
