"""Table 8: multi-channel (MCC) vs uni-channel (UCC) experience sharing on
AY and FC — transfer counts, granularity, wall time, and the throughput
proxies PPS (handled experience/s) and TTOP (samples delivered to
trainers/s).

Reports before/after for the device-resident pipeline: ``mcc`` is the
ring-buffer path (in-place pack at push time, pointer-bump flush),
``mcc_host`` is the seed host-staging path (per-flush ``jnp.concatenate``
re-materialization), ``ucc`` ships every tuple field-by-field.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.channels import (HostStagedPipeline, MultiChannelPipeline,
                                 UniChannelPipeline)
from repro.envs import make_env
from repro.rl.a3c import Experience


def _make_exp(spec, T=32, N=64, version=0):
    key = jax.random.key(version)
    return Experience(
        obs=jax.random.normal(key, (T, N, spec.obs_dim)),
        actions=jax.random.normal(key, (T, N, spec.act_dim)),
        rewards=jax.random.normal(key, (T, N)),
        dones=jnp.zeros((T, N)),
        bootstrap=jnp.zeros((N,)),
        actor_version=jnp.int32(version))


def _drive_mcc(pipe, exps, agents, rounds):
    """Push+flush loop; returns (dt_total, dt_push, delivered_samples)."""
    delivered = 0
    dt_push = 0.0
    t0 = time.perf_counter()
    for r in range(rounds):
        tp = time.perf_counter()
        for a in range(agents):
            pipe.push(a, exps[r][a])
        dt_push += time.perf_counter() - tp
        for dst, batches in pipe.flush().items():
            for b in batches:
                jax.block_until_ready(b.obs)
                delivered += b.rewards.size
    return time.perf_counter() - t0, dt_push, delivered


def run(benches=("Anymal", "FrankaCabinet"), agents=4, rounds=12):
    for bench in benches:
        spec = make_env(bench).spec
        exps = [[_make_exp(spec, version=r * agents + a)
                 for a in range(agents)] for r in range(rounds)]
        jax.block_until_ready(exps)   # don't charge RNG to the first variant

        factories = {
            "mcc": lambda: MultiChannelPipeline(list(range(agents)),
                                                [100, 101]),
            "mcc_host": lambda: HostStagedPipeline(list(range(agents)),
                                                   [100, 101]),
        }
        results = {}
        variants = {}
        for name, make in factories.items():
            # warm-up round on a twin pipeline (same agent count/shapes)
            # so pack-step compilation stays outside the timed region
            warm = make()
            for a in range(agents):
                warm.push(a, exps[0][a])
            for _, bs in warm.flush().items():
                jax.block_until_ready([b.obs for b in bs])
            pipe = variants[name] = make()
            dt, dt_push, delivered = _drive_mcc(pipe, exps, agents, rounds)
            results[name] = (dt, delivered)
            emit(f"{name}_{bench}", dt * 1e6 / rounds,
                 f"PPS={delivered / max(dt_push, 1e-9):.0f}"
                 f"_TTOP={delivered / dt:.0f}"
                 f"_transfers={pipe.stats.num_transfers}"
                 f"_B/transfer={pipe.stats.bytes_per_transfer:.0f}")

        ucc = UniChannelPipeline([100, 101])
        t0 = time.perf_counter()
        delivered_u = 0
        for r in range(rounds):
            for a in range(agents):
                # UCC: each tuple shipped separately at fine granularity,
                # then materialized field-by-field at the trainer
                exp = exps[r][a]
                ucc.send(exp)
                parts = [jnp.asarray(x) for x in
                         (exp.obs, exp.actions, exp.rewards, exp.dones,
                          exp.bootstrap)]
                jax.block_until_ready(parts)
                delivered_u += exp.rewards.size
        dt_ucc = time.perf_counter() - t0
        emit(f"ucc_{bench}", dt_ucc * 1e6 / rounds,
             f"TTOP={delivered_u / dt_ucc:.0f}"
             f"_transfers={ucc.stats.num_transfers}"
             f"_B/transfer={ucc.stats.bytes_per_transfer:.0f}")

        dt_m, deliv_m = results["mcc"]
        dt_h, deliv_h = results["mcc_host"]
        mcc, host = variants["mcc"], variants["mcc_host"]
        emit(f"mcc_over_ucc_{bench}", 0.0,
             f"ttop_ratio={(deliv_m / dt_m) / (delivered_u / dt_ucc):.2f}x"
             f"_granularity_ratio={mcc.stats.bytes_per_transfer / ucc.stats.bytes_per_transfer:.1f}x")
        emit(f"mcc_ring_over_host_{bench}", 0.0,
             f"walltime_ratio={(dt_h / deliv_h) / (dt_m / deliv_m):.2f}x"
             f"_us_per_sample_ring={dt_m * 1e6 / deliv_m:.2f}"
             f"_us_per_sample_host={dt_h * 1e6 / deliv_h:.2f}"
             f"_granularity_ratio={mcc.stats.bytes_per_transfer / host.stats.bytes_per_transfer:.2f}x")
