"""Table 8: multi-channel (MCC) vs uni-channel (UCC) experience sharing on
AY and FC — transfer counts, granularity, wall time, and the throughput
proxies PPS (handled experience/s) and TTOP (samples delivered to
trainers/s).

Reports before/after for the device-resident pipeline: ``mcc`` is the
ring-buffer path (in-place pack at push time, pointer-bump flush),
``mcc_overlap`` double-buffers the rings (flush = buffer swap; trainers
consume the previous round while serving keeps packing — paper §4.1
overlap), ``mcc_host`` is the seed host-staging path (per-flush
``jnp.concatenate`` re-materialization), ``ucc`` ships every tuple
field-by-field.  Every variant's delivered-sample count is checked
against the pushed count, so ``lost``/``dup`` in the derived column are
measured, not asserted.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.channels import (HostStagedPipeline, MultiChannelPipeline,
                                 UniChannelPipeline)
from repro.envs import make_env
from repro.rl.a3c import Experience


def _make_exp(spec, T=32, N=64, version=0):
    key = jax.random.key(version)
    return Experience(
        obs=jax.random.normal(jax.random.fold_in(key, 0),
                              (T, N, spec.obs_dim)),
        actions=jax.random.normal(jax.random.fold_in(key, 1),
                                  (T, N, spec.act_dim)),
        rewards=jax.random.normal(jax.random.fold_in(key, 2), (T, N)),
        dones=jnp.zeros((T, N)),
        bootstrap=jnp.zeros((N,)),
        actor_version=jnp.int32(version))


def _make_consume(key, obs_dim):
    """A jitted pseudo trainer step (touches every delivered byte through
    two matmul+tanh layers) — the consumer work the §4.1 overlap is
    supposed to hide serving behind.  Identical for every variant."""
    w = jax.random.normal(key, (obs_dim, obs_dim)) / obs_dim ** 0.5

    @jax.jit
    def consume(obs):
        h = jnp.tanh(obs @ w)
        return jnp.tanh(h @ w).sum()

    return consume


def _drive_mcc(pipe, exps, agents, rounds, consume):
    """Blocking schedule: push -> flush -> train -> wait, every round.
    Returns (dt_total, dt_push, delivered_samples)."""
    delivered = 0
    dt_push = 0.0
    t0 = time.perf_counter()
    for r in range(rounds):
        tp = time.perf_counter()
        for a in range(agents):
            pipe.push(a, exps[r][a])
        dt_push += time.perf_counter() - tp
        for dst, batches in pipe.flush().items():
            for b in batches:
                jax.block_until_ready(consume(b.obs))
                delivered += b.rewards.size
    for dst, batches in pipe.drain().items():
        for b in batches:
            jax.block_until_ready(consume(b.obs))
            delivered += b.rewards.size
    return time.perf_counter() - t0, dt_push, delivered


def _drive_overlap(pipe, exps, agents, rounds, consume):
    """Overlap schedule the double-buffered flush enables: each round
    swaps out the PREVIOUS round's back generation, dispatches the
    trainer consume on it, and keeps serving — no per-round barrier.
    Serving stages into the front generation while pack+consume of the
    back one stream behind; the single sync at the end of the horizon
    pays for every dispatched byte, so the timing is honest.  Same
    pushes, same flush count, same per-batch consume as the blocking
    schedule — the serve and train stages just overlap instead of
    serializing."""
    delivered = 0
    dt_push = 0.0
    pend = []
    t0 = time.perf_counter()
    for r in range(rounds):
        for dst, batches in pipe.flush().items():   # round r-1's swap
            pend.extend((consume(b.obs), b.rewards.size) for b in batches)
        tp = time.perf_counter()
        for a in range(agents):                     # serve round r
            pipe.push(a, exps[r][a])
        dt_push += time.perf_counter() - tp
    for dst, batches in pipe.drain().items():       # lossless tail
        pend.extend((consume(b.obs), b.rewards.size) for b in batches)
    for out, n in pend:                             # one end-of-horizon sync
        jax.block_until_ready(out)
        delivered += n
    return time.perf_counter() - t0, dt_push, delivered


def run(benches=("Anymal", "FrankaCabinet"), agents=4, rounds=48):
    for bench in benches:
        spec = make_env(bench).spec
        exps = [[_make_exp(spec, version=r * agents + a)
                 for a in range(agents)] for r in range(rounds)]
        jax.block_until_ready(exps)   # don't charge RNG to the first variant
        expected = rounds * agents * exps[0][0].rewards.size
        consume = _make_consume(jax.random.key(7), spec.obs_dim)

        factories = {
            "mcc": (_drive_mcc,
                    lambda: MultiChannelPipeline(list(range(agents)),
                                                 [100, 101])),
            "mcc_overlap": (_drive_overlap,
                            lambda: MultiChannelPipeline(
                                list(range(agents)), [100, 101],
                                overlap=True)),
            "mcc_host": (_drive_mcc,
                         lambda: HostStagedPipeline(list(range(agents)),
                                                    [100, 101])),
        }
        variants = {}
        best = {}
        # warm-up round on a twin pipeline (same agent count/shapes) so
        # pack/consume compilation stays outside the timed region
        for name, (drive, make) in factories.items():
            warm = make()
            for a in range(agents):
                warm.push(a, exps[0][a])
            for _, bs in warm.drain().items():
                jax.block_until_ready([consume(b.obs) for b in bs])
        # interleave repetitions (all variants inside each rep) and take
        # the per-variant best: shared-CPU wall clock is ±50% run to run
        # and drifts on multi-second scales, so back-to-back reps of ONE
        # variant would bake the drift into the comparison
        reps = 5
        for _ in range(reps):
            for name, (drive, make) in factories.items():
                pipe = make()
                rep = drive(pipe, exps, agents, rounds, consume)
                if name not in best or rep[0] < best[name][0]:
                    best[name] = rep
                    variants[name] = pipe
        results = {}
        for name in factories:
            dt, dt_push, delivered = best[name]
            results[name] = (dt, delivered)
            pipe = variants[name]
            # serve_us_round: wall time the SERVING side spends per round
            # inside push — for the blocking ring this includes donation
            # stalls behind the trainer's consumption; overlap staging
            # should drive it toward zero (the §4.1 claim, measured)
            emit(f"{name}_{bench}", dt * 1e6 / rounds,
                 f"TTOP={delivered / dt:.0f}"
                 f"_serve_us_round={dt_push * 1e6 / rounds:.0f}"
                 f"_transfers={pipe.stats.num_transfers}"
                 f"_B/transfer={pipe.stats.bytes_per_transfer:.0f}"
                 f"_lost={max(expected - delivered, 0)}"
                 f"_dup={max(delivered - expected, 0)}")

        ucc = UniChannelPipeline([100, 101])
        t0 = time.perf_counter()
        delivered_u = 0
        for r in range(rounds):
            for a in range(agents):
                # UCC: each tuple shipped separately at fine granularity,
                # then materialized field-by-field at the trainer
                exp = exps[r][a]
                ucc.send(exp)
                parts = [jnp.asarray(x) for x in
                         (exp.obs, exp.actions, exp.rewards, exp.dones,
                          exp.bootstrap)]
                jax.block_until_ready(parts)
                jax.block_until_ready(consume(exp.obs))  # same trainer work
                delivered_u += exp.rewards.size
        dt_ucc = time.perf_counter() - t0
        emit(f"ucc_{bench}", dt_ucc * 1e6 / rounds,
             f"TTOP={delivered_u / dt_ucc:.0f}"
             f"_transfers={ucc.stats.num_transfers}"
             f"_B/transfer={ucc.stats.bytes_per_transfer:.0f}")

        dt_m, deliv_m = results["mcc"]
        dt_h, deliv_h = results["mcc_host"]
        dt_o, deliv_o = results["mcc_overlap"]
        mcc, host = variants["mcc"], variants["mcc_host"]
        emit(f"mcc_over_ucc_{bench}", 0.0,
             f"ttop_ratio={(deliv_m / dt_m) / (delivered_u / dt_ucc):.2f}x"
             f"_granularity_ratio={mcc.stats.bytes_per_transfer / ucc.stats.bytes_per_transfer:.1f}x")
        emit(f"mcc_ring_over_host_{bench}", 0.0,
             f"walltime_ratio={(dt_h / deliv_h) / (dt_m / deliv_m):.2f}x"
             f"_us_per_sample_ring={dt_m * 1e6 / deliv_m:.2f}"
             f"_us_per_sample_host={dt_h * 1e6 / deliv_h:.2f}"
             f"_granularity_ratio={mcc.stats.bytes_per_transfer / host.stats.bytes_per_transfer:.2f}x")
        # §4.1 serve/train overlap: double-buffered flush-as-swap vs the
        # PR 1 blocking-flush ring at identical payloads and losslessness
        emit(f"mcc_overlap_over_blocking_{bench}", 0.0,
             f"walltime_ratio={(dt_m / deliv_m) / (dt_o / deliv_o):.2f}x"
             f"_us_per_sample_overlap={dt_o * 1e6 / deliv_o:.2f}"
             f"_us_per_sample_blocking={dt_m * 1e6 / deliv_m:.2f}"
             f"_lost={max(expected - deliv_o, 0)}"
             f"_dup={max(deliv_o - expected, 0)}")
