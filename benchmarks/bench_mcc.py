"""Table 8: multi-channel (MCC) vs uni-channel (UCC) experience sharing on
AY and FC — transfer counts, granularity, wall time, and the throughput
proxies PPS (handled experience/s) and TTOP (samples delivered to
trainers/s).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.channels import MultiChannelPipeline, UniChannelPipeline
from repro.envs import make_env
from repro.rl.a3c import Experience


def _make_exp(spec, T=32, N=64, version=0):
    key = jax.random.key(version)
    return Experience(
        obs=jax.random.normal(key, (T, N, spec.obs_dim)),
        actions=jax.random.normal(key, (T, N, spec.act_dim)),
        rewards=jax.random.normal(key, (T, N)),
        dones=jnp.zeros((T, N)),
        bootstrap=jnp.zeros((N,)),
        actor_version=jnp.int32(version))


def run(benches=("Anymal", "FrankaCabinet"), agents=4, rounds=6):
    for bench in benches:
        spec = make_env(bench).spec
        exps = [[_make_exp(spec, version=r * agents + a)
                 for a in range(agents)] for r in range(rounds)]

        mcc = MultiChannelPipeline(list(range(agents)), [100, 101])
        t0 = time.perf_counter()
        delivered = 0
        for r in range(rounds):
            for a in range(agents):
                mcc.push(a, exps[r][a])
            for dst, batches in mcc.flush().items():
                for b in batches:
                    jax.block_until_ready(b.obs)
                    delivered += b.rewards.size
        dt_mcc = time.perf_counter() - t0

        ucc = UniChannelPipeline([100, 101])
        t0 = time.perf_counter()
        delivered_u = 0
        for r in range(rounds):
            for a in range(agents):
                # UCC: each tuple shipped separately at fine granularity,
                # then materialized field-by-field at the trainer
                exp = exps[r][a]
                ucc.send(exp)
                parts = [jnp.asarray(x) for x in
                         (exp.obs, exp.actions, exp.rewards, exp.dones,
                          exp.bootstrap)]
                jax.block_until_ready(parts)
                delivered_u += exp.rewards.size
        dt_ucc = time.perf_counter() - t0

        pps_m = delivered / dt_mcc
        pps_u = delivered_u / dt_ucc
        emit(f"mcc_{bench}", dt_mcc * 1e6 / rounds,
             f"TTOP={pps_m:.0f}_transfers={mcc.stats.num_transfers}"
             f"_B/transfer={mcc.stats.bytes_per_transfer:.0f}")
        emit(f"ucc_{bench}", dt_ucc * 1e6 / rounds,
             f"TTOP={pps_u:.0f}_transfers={ucc.stats.num_transfers}"
             f"_B/transfer={ucc.stats.bytes_per_transfer:.0f}")
        emit(f"mcc_over_ucc_{bench}", 0.0,
             f"ttop_ratio={pps_m / pps_u:.2f}x_granularity_ratio="
             f"{mcc.stats.bytes_per_transfer / ucc.stats.bytes_per_transfer:.1f}x")
