"""Pallas kernel microbenchmarks (interpret mode on CPU: numbers validate
CORRECTNESS cost only; TPU timings come from the roofline, not this host).
Compares kernel vs pure-jnp oracle per call."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels import ops, ref


def run():
    root = jax.random.key(0)

    def sub(i):
        # each draw gets its own fold_in-derived key; the root is never
        # consumed directly (prng-reuse)
        return jax.random.fold_in(root, i)

    B, S, H, KH, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(sub(0), (B, S, H, hd))
    k = jax.random.normal(sub(1), (B, S, KH, hd))
    v = jax.random.normal(sub(2), (B, S, KH, hd))
    us_k = timeit(lambda: ops.attention(q, k, v, block_q=128, block_k=128))
    ref_j = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
    us_r = timeit(lambda: ref_j(q, k, v))
    emit("kernel_flash_attn_interp", us_k, f"ref_us={us_r:.0f}")

    dims = [211, 512, 512, 512, 256]
    ws = [jax.random.normal(sub(10 + i), (dims[i], dims[i + 1])) * 0.05
          for i in range(4)]
    bs = [jnp.zeros((d,)) for d in dims[1:]]
    x = jax.random.normal(sub(14), (512, 211))
    us_k = timeit(lambda: ops.policy_mlp(x, ws, bs))
    ref_j = jax.jit(lambda x: ref.policy_mlp_ref(x, ws, bs))
    us_r = timeit(lambda: ref_j(x))
    emit("kernel_policy_mlp_interp", us_k, f"ref_us={us_r:.0f}")

    B, H, S, dh = 1, 4, 256, 32
    qm = jax.random.normal(sub(20), (B, H, S, dh))
    li = jax.random.normal(sub(21), (B, H, S)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(sub(22), (B, H, S)) + 2.0)
    us_k = timeit(lambda: ops.mlstm(qm, qm, qm, li, lf, chunk=64))
    ref_j = jax.jit(lambda: ref.mlstm_chunkwise_ref(qm, qm, qm, li, lf,
                                                    chunk=64))
    us_r = timeit(ref_j)
    emit("kernel_mlstm_interp", us_k, f"ref_us={us_r:.0f}")

    # fused GAE + advantage normalization (PPO hot path)
    T, N = 32, 512
    ks = jax.random.split(sub(30), 4)
    rw = jax.random.normal(ks[0], (T, N))
    vl = jax.random.normal(ks[1], (T, N))
    dn = (jax.random.uniform(ks[2], (T, N)) < 0.05).astype(jnp.float32)
    lv = jax.random.normal(ks[3], (N,))
    us_k = timeit(lambda: ops.gae_norm(rw, vl, dn, lv))
    ref_j = jax.jit(lambda r, v, d, l: ref.gae_norm_ref(r, v, d, l))
    us_r = timeit(lambda: ref_j(rw, vl, dn, lv))
    emit("kernel_gae_scan_interp", us_k, f"ref_us={us_r:.0f}")

    # ring-buffer channel pack (MCC hot path): pallas vs jitted-XLA lowering
    # (both paths donate the ring, so each call gets a fresh allocation;
    # the alloc cost is identical across the two columns)
    from repro.kernels import channel_pack as cp
    pay = {"obs": jax.random.normal(sub(40), (T, 64, 48)),
           "actions": jax.random.normal(sub(41), (T, 64, 12)),
           "rewards": jax.random.normal(sub(42), (T, 64)),
           "dones": jnp.zeros((T, 64)),
           "bootstrap": jnp.zeros((64,)),
           "actor_version": jnp.int32(0)}
    slot = jnp.int32(1)
    us_k = timeit(
        lambda: ops.pack_channels(cp.alloc_rings(pay, 4), pay, slot))
    us_x = timeit(
        lambda: cp.pack_channels_xla(cp.alloc_rings(pay, 4), pay, slot))
    emit("kernel_channel_pack_interp", us_k, f"xla_us={us_x:.0f}")
