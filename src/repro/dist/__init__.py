from repro.dist.partition import (batch_specs, cache_specs, param_specs,
                                  to_shardings)

__all__ = ["batch_specs", "cache_specs", "param_specs", "to_shardings"]
