"""Partition-spec rules for the production mesh (DESIGN.md §2).

Specs are derived per-leaf from the leaf's tree path and shape, never from a
per-architecture table, so every config in ``repro.configs.ARCHS`` shards
without registration:

* ``model`` (tensor-parallel) goes on the trailing feature dim of every
  matrix whose size divides the axis — and on the vocab dim of embedding-like
  tables (vocab-parallel).  When the vocab does not divide the axis (granite's
  49155) the table falls back to replication on ``model`` rather than
  crashing or padding (GSPMD's gather-of-sharded-table path is also buggy on
  ragged shards, so replication is the safe fallback).
* ``data`` (FSDP) goes on the first remaining dim that divides the axis —
  the stacked-layer dim when the depth divides, else the input-feature dim.
  Only applied when ``fsdp=True`` (the HAR layout); the MRR layout keeps
  params replicated so gradient sync lowers to one flat ring.
* 1-D leaves (biases, norm scales) and scalars are replicated — sharding
  them saves nothing and forces per-layer all-gathers.

Every rule is guarded by divisibility: an axis is only ever assigned to a
dim whose size it divides, so any mesh/arch combination yields a valid
(possibly partially-replicated) sharding instead of an error.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_EMBED_KEYS = ("embed", "unembed", "table", "head")


def _axis_sizes(mesh) -> Dict[str, int]:
    try:
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    except AttributeError:                      # concrete Mesh without
        return dict(mesh.shape)                 # .axis_sizes (older jax)


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return tuple(out)


def _is_spec_leaf(x) -> bool:
    return isinstance(x, P)


def to_shardings(spec_tree, mesh):
    """PartitionSpec tree -> NamedSharding tree on a concrete mesh."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=_is_spec_leaf)


# ---------------------------------------------------------------- params ---
def param_specs(params_sds, mesh, fsdp: bool = False,
                moe_spec: str = "contract"):
    """PartitionSpec per parameter leaf (see module docstring for rules).

    ``moe_spec``: "contract" shards expert matrices on their feature dims
    (generic rule); "expert" prefers the expert-count dim for ``model``.
    """
    sizes = _axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    data_n = sizes.get("data", 1)

    def one(path, sds):
        shape = sds.shape
        nd = len(shape)
        if nd == 0:
            return P()
        names = _path_names(path)
        if nd == 1:
            return P()
        axes = [None] * nd

        if any(n in _EMBED_KEYS for n in names):
            # vocab-parallel: the vocab dim is the larger of the two
            vdim = 0 if shape[0] >= shape[-1] else nd - 1
            if model_n > 1 and shape[vdim] % model_n == 0:
                axes[vdim] = "model"
            if fsdp and data_n > 1:
                other = nd - 1 if vdim == 0 else 0
                if axes[other] is None and shape[other] % data_n == 0:
                    axes[other] = "data"
            return P(*axes)

        if moe_spec == "expert" and "moe" in names and nd >= 3 \
                and model_n > 1 and shape[1] % model_n == 0:
            axes[1] = "model"
        elif model_n > 1:
            for d in (nd - 1, nd - 2):
                if shape[d] % model_n == 0:
                    axes[d] = "model"
                    break
        if fsdp and data_n > 1:
            for d in range(nd):
                if axes[d] is None and shape[d] % data_n == 0:
                    axes[d] = "data"
                    break
        return P(*axes)

    return jax.tree_util.tree_map_with_path(one, params_sds)


# ---------------------------------------------------------------- batches --
def batch_specs(batch_sds, mesh, batch_axes: Sequence[str] = ("data",)):
    """Shard the leading (global-batch) dim over the batch axes."""
    sizes = _axis_sizes(mesh)
    bt = tuple(a for a in batch_axes if sizes.get(a, 1) > 1) or \
        tuple(batch_axes)
    n = 1
    for a in bt:
        n *= sizes.get(a, 1)
    ax = bt if len(bt) > 1 else bt[0]

    def one(sds):
        if len(sds.shape) == 0 or sds.shape[0] % n != 0:
            return P()
        return P(*([ax] + [None] * (len(sds.shape) - 1)))

    return jax.tree.map(one, batch_sds)


# ---------------------------------------------------------------- caches ---
def cache_specs(cache_sds, mesh, batch_shardable: bool = True,
                layout: str = "heads"):
    """Specs for stacked decode caches (leading dim = layers/super-blocks).

    ``layout="heads"``: KV tensors (layers, B, S, n_kv, hd) shard the
    head-count dim over ``model`` when it divides (TP-style serving);
    ``layout="batch"`` leaves heads replicated.  The batch dim (index 1
    after the layer stack) shards over ``data`` when allowed.  SSM state
    leaves (rank < 4) only ever shard their batch dim — recurrent state
    dims must stay intact on one chip.
    """
    sizes = _axis_sizes(mesh)
    model_n = sizes.get("model", 1)
    data_n = sizes.get("data", 1)

    def one(sds):
        shape = sds.shape
        nd = len(shape)
        if nd < 2:
            return P()
        axes = [None] * nd
        bdim = 1 if nd >= 3 else 0     # leading layer-stack dim when rank>=3
        if batch_shardable and data_n > 1 and shape[bdim] % data_n == 0:
            axes[bdim] = "data"
        if layout == "heads" and nd >= 4 and model_n > 1 \
                and shape[nd - 2] % model_n == 0:
            axes[nd - 2] = "model"
        return P(*axes)

    return jax.tree.map(one, cache_sds)
