"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT vision encoder + mistral-nemo decoder.  The ViT is
a STUB: input_specs() feeds projected patch embeddings (B, P, 1024) that are
interleaved ahead of the text tokens. [hf:mistralai/Pixtral-12B-2409]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="pixtral-12b", family="vlm", source="hf:mistralai/Pixtral-12B-2409",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131_072,
    frontend="vision", frontend_feat_dim=1024, num_patches=256,
    act="silu", dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, frontend_feat_dim=64, num_patches=8,
        dtype="float32")
