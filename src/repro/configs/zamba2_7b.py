"""zamba2-7b [hybrid] — 81 layer applications, d_model=3584 32H (kv=32)
d_ff=14336, ssm_state=64: Mamba2 backbone with ONE shared attention+MLP
block applied periodically (9 super-blocks x (8 mamba2 + 1 shared-attn) =
81). [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid", source="arXiv:2411.15242",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32_000, ssm_state_dim=64,
    block_pattern=("mamba2",) * 8 + ("attn_shared",), num_super=9,
    conv_width=4, act="silu", dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=512, ssm_state_dim=16,
        block_pattern=("mamba2", "attn_shared"), num_super=1,
        dtype="float32")
