"""The paper's own policy models (Table 6), keyed by benchmark name."""
from repro.envs.suite import SPECS

POLICY_DIMS = {name: spec.policy_dims for name, spec in SPECS.items()}
