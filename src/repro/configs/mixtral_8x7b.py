"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000; 8 experts top-2, sliding-window attention. [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b", family="moe", source="arXiv:2401.04088",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32_000,
    num_experts=8, experts_per_token=2,
    sliding_window=4096, act="silu", dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=256, vocab_size=512, num_experts=4, experts_per_token=2,
        sliding_window=16, dtype="float32")
