"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; local+global alternating attention, logit softcaps.
[arXiv:2408.00118]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="gemma2-27b", family="dense", source="arXiv:2408.00118",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256_000,
    local_global=True, sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    act="silu", tie_embeddings=True, dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, sliding_window=16, dtype="float32")
