"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064; QKV bias. [arXiv:2407.10671]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen2-72b", family="dense", source="arXiv:2407.10671",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152_064, qkv_bias=True, act="silu",
    dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, dtype="float32")
