"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544. [arXiv:2403.17297]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="internlm2-1.8b", family="dense", source="arXiv:2403.17297",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92_544, act="silu", dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, dtype="float32")
