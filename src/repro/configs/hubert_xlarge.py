"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
(k-means target codebook); encoder-only, same trunk as wav2vec2.
The conv/mel frontend is a STUB: input_specs() feeds precomputed frame
embeddings (B, T, 512). [arXiv:2106.07447]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="hubert-xlarge", family="audio", source="arXiv:2106.07447",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, causal=False,
    frontend="audio", frontend_feat_dim=512, act="gelu", dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=512, vocab_size=64, frontend_feat_dim=32, dtype="float32")
