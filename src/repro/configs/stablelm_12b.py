"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b family]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="stablelm-12b", family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=160,
    d_ff=13824, vocab_size=100_352, act="silu", dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, dtype="float32")
