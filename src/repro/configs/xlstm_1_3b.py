"""xlstm-1.3b [ssm] — 48 blocks d_model=2048 4H vocab=50304; sLSTM + mLSTM
blocks at the xLSTM[7:1] ratio (6 super-blocks x (7 mLSTM + 1 sLSTM)).
d_ff=0: the recurrent blocks carry their own up/down projections.
[arXiv:2405.04517]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="xlstm-1.3b", family="ssm", source="arXiv:2405.04517",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",), num_super=6,
    ssm_expansion=1,   # sized to the published 1.3B total (DESIGN.md §8)
    conv_width=4, dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
        vocab_size=512, block_pattern=("mlstm", "slstm"), num_super=1,
        dtype="float32")
