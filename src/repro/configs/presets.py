"""Production presets: the best-known runtime knobs per (arch × shape),
distilled from the EXPERIMENTS.md §Perf hillclimbing.

Usage: ``preset(arch, shape)`` returns kwargs for
``repro.launch.dryrun.run_one`` / the step builders.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import get_config

# train_4k microbatch counts that bring activations under 16 GiB/chip
_TRAIN_MB = {
    "gemma2-27b": 2, "mixtral-8x7b": 4, "qwen2-72b": 4, "stablelm-12b": 2,
    "pixtral-12b": 2, "zamba2-7b": 4, "xlstm-1.3b": 4,
    "granite-moe-1b-a400m": 2, "internlm2-1.8b": 1, "hubert-xlarge": 1,
}

# activation layout is SHAPE-dependent: seq sharding wins for xlstm
# PREFILL (-9.3x collectives, keeps per-timestep slices local) but loses
# for its TRAIN backward (6x traffic); granite needs seq under
# microbatching to sidestep a GSPMD gather bug
_ACT = {("xlstm-1.3b", "prefill_32k"): "seq",
        ("granite-moe-1b-a400m", "train_4k"): "seq"}


def preset(arch: str, shape_name: str) -> Dict:
    cfg = get_config(arch)
    out: Dict = {"lgr": "har",
                 "act_sharding": _ACT.get((arch, shape_name), "dmodel"),
                 "cache_layout": "heads", "microbatches": 1,
                 "moe_spec": "contract", "decode_unroll": False}
    if shape_name == "train_4k":
        out["microbatches"] = _TRAIN_MB.get(arch, 1)
    if shape_name in ("decode_32k", "long_500k"):
        # kv_heads < |model|=16 → head-dim sharding would re-gather the
        # cache every layer; sequence-sharded cache keeps scores local
        if cfg.num_kv_heads and cfg.num_kv_heads < 16 and \
                not cfg.block_pattern:
            out["cache_layout"] = "seq"
        # local/global stacks: per-layer ring caches halve KV memory
        if cfg.local_global:
            out["per_layer_cache"] = True
            out["decode_unroll"] = True
    out.setdefault("per_layer_cache", False)
    return out
