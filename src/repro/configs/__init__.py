"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``.

The 10 assigned architectures (public-literature pool) plus the paper's own
Table-6 policy networks (``paper_policies``).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                TrainConfig)  # noqa: F401

_ARCH_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "mixtral-8x7b": "mixtral_8x7b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen2-72b": "qwen2_72b",
    "hubert-xlarge": "hubert_xlarge",
    "stablelm-12b": "stablelm_12b",
    "xlstm-1.3b": "xlstm_1_3b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "zamba2-7b": "zamba2_7b",
    "pixtral-12b": "pixtral_12b",
}

ARCHS = tuple(_ARCH_MODULES.keys())


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).FULL


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def shape_skips(arch: str) -> dict:
    """Which input shapes are skipped for this arch, and why (DESIGN.md §5).

    ``long_500k`` notes: archs without native sub-quadratic attention run it
    only under the sliding-window serving variant (window_override)."""
    cfg = get_config(arch)
    skips = {}
    if cfg.is_encoder_only:
        skips["decode_32k"] = "encoder-only: no autoregressive decode step"
        skips["long_500k"] = "encoder-only: no autoregressive decode step"
    return skips


def long_context_window(arch: str):
    """window_override used for long_500k (None = native sub-quadratic)."""
    cfg = get_config(arch)
    if cfg.family in ("ssm", "hybrid"):
        return None                      # recurrent state: O(1) per token
    if cfg.sliding_window and not cfg.local_global:
        return None                      # native SWA (mixtral)
    return 4096                          # sliding-window serving variant
