"""Architecture configuration schema.

Every assigned architecture gets a ``ModelConfig`` (full size, used only by
the dry-run via ShapeDtypeStruct) plus a ``reduced()`` variant (<=2 layers,
d_model<=512, <=4 experts) that the CPU smoke tests instantiate for real.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""               # citation for the config numbers

    # trunk dimensions ----------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # attention features --------------------------------------------------
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None     # pre-softmax logit softcap
    final_softcap: Optional[float] = None    # lm-head logit softcap
    sliding_window: Optional[int] = None     # SWA width (None = full)
    local_global: bool = False               # gemma2: alternate local/global
    causal: bool = True                      # False => encoder-only

    # MoE ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    router_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    # Routing-block size R (0 = whole sequence): capacity competition is
    # confined to R-token blocks at absolute positions, making routing
    # independent of batch composition AND of prefill chunking whenever
    # chunk boundaries land on multiples of R.
    moe_route_block: int = 0

    # SSM / hybrid ---------------------------------------------------------
    # block pattern within one "super-block"; the stack is
    # num_super * len(pattern) layer applications.  "attn_shared" entries all
    # reuse ONE weight set (zamba2-style shared block).
    block_pattern: Tuple[str, ...] = ()      # e.g. ("mlstm",)*7 + ("slstm",)
    num_super: int = 0
    ssm_state_dim: int = 0
    ssm_expansion: int = 2         # inner-dim expansion of recurrent blocks
    conv_width: int = 4

    # modality frontend stubs ----------------------------------------------
    frontend: Optional[str] = None           # "audio" | "vision"
    frontend_feat_dim: int = 0               # raw embedding dim fed by stub
    num_patches: int = 0                     # vision: patches per request

    # misc -------------------------------------------------------------------
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "float32"                   # compute dtype for dry-runs

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Flat sequence of per-layer block kinds for the whole stack."""
        if self.block_pattern:
            return tuple(self.block_pattern) * self.num_super
        return ("attn",) * self.num_layers

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # rough parameter count (embedding + trunk), for config sanity tests ----
    def approx_params(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        n = 0
        n += v * d                                   # embed
        if not self.tie_embeddings:
            n += v * d                               # unembed
        per_attn = d * q + 2 * d * kv + q * d
        per_mlp = 3 * d * f if self.act in ("silu", "swiglu") else 2 * d * f
        if self.num_experts:
            per_mlp *= self.num_experts
            per_mlp += d * self.num_experts          # router
        for kind in self.layer_kinds:
            if kind in ("attn", "attn_shared"):
                n += per_attn + per_mlp if kind == "attn" else 0
            elif kind == "mlstm":
                n += 2 * d * (2 * d) + 2 * d * d     # up/gate + qkv-ish + down
            elif kind == "slstm":
                n += 8 * d * d // 4
            elif kind == "mamba2":
                n += 2 * d * (2 * d) + d * self.ssm_state_dim * 4
        if "attn_shared" in self.layer_kinds:
            n += per_attn + per_mlp                  # one shared copy
        return n


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    mode: str        # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 10
    total_steps: int = 100
    # GMI-DRL runtime knobs
    lgr_strategy: str = "auto"       # auto | mpr | mrr | har
    gmi_layout: str = "tcg"          # tcg | tdg
    remat: bool = True
    microbatches: int = 1            # gradient-accumulation splits
