"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
per expert, 32 experts top-8, vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49_155,
    num_experts=32, experts_per_token=8, act="silu", tie_embeddings=True,
    dtype="bfloat16")


def reduced() -> ModelConfig:
    return FULL.replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=128, vocab_size=512, num_experts=4, experts_per_token=2,
        dtype="float32")
