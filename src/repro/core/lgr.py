"""DEPRECATED shim — LGR moved to the ``repro.comm`` subsystem.

The §4.1 communication support now lives in ``repro.comm``:

* schedules (MPR/MRR/HAR + the 3-level HAR3 over (gpu, inst, dev)
  meshes): ``repro.comm.schedules``
* Algorithm-1 / cost-model strategy selection: ``repro.comm.select``
* the Communicator object layers consume: ``repro.comm.api``

This module re-exports the old surface with the old calling conventions
(``make_grad_sync(strategy, intra_axis, inter_axis)`` returning raw-sum
closures; ``lgr_allreduce`` averaging) so pre-existing imports keep
working, and warns on import.  New code should import ``repro.comm``.
"""
from __future__ import annotations

import warnings

from repro.comm.schedules import flat_psum, mpr_host  # noqa: F401
from repro.comm.schedules import hierarchical_psum as _hierarchical_psum
from repro.comm.schedules import lgr_allreduce as _lgr_allreduce
from repro.comm.schedules import make_grad_sync as _make_grad_sync

warnings.warn(
    "repro.core.lgr is deprecated: the LGR schedules now live in "
    "repro.comm (which also handles the 3-axis (gpu, inst, dev) meshes "
    "this module used to reject)", DeprecationWarning, stacklevel=2)


def hierarchical_psum(grads, intra_axis: str = "inst",
                      inter_axis: str = "gpu"):
    """Old 2-level signature over the generalized N-level schedule."""
    return _hierarchical_psum(grads, (inter_axis, intra_axis))


def make_grad_sync(strategy: str, intra_axis: str = "inst",
                   inter_axis: str = "gpu"):
    """Old signature and old raw-sum semantics (callers divided
    themselves); ``repro.comm.make_grad_sync`` averages by default."""
    return _make_grad_sync(strategy, (inter_axis, intra_axis),
                           average=False)


def lgr_allreduce(grads, mesh, strategy: str, intra_axis: str = "inst",
                  inter_axis: str = "gpu"):
    """Old signature (averaged, as before).  The axis-name arguments are
    accepted for compatibility but the hierarchy is read off the mesh's
    own axis order (slow → fast), exactly what the old implementation
    required of its callers anyway."""
    return _lgr_allreduce(grads, mesh, strategy)
