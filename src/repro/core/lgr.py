"""Layout-aware gradient reduction — LGR (paper §4.1).

Three schedules, selected by Algorithm 1 from the instance layout:

* MPR  (multi-process reduction): stage every instance's gradient through
  host memory and reduce on CPU — generic, layout-agnostic, slow (paper
  Table 2: 2·(g·t−1)·Mp / (g·t·B1)).
* MRR  (multi-ring reduction): one flat ring over all instances — maps to a
  single ``psum`` over the merged mesh axes (paper: non-intersecting NCCL
  rings + final ring; valid only when t ≤ g).
* HAR  (hierarchical reduction): reduce within the fast domain first, then
  across the slow domain on 1/t-sized shards, then gather — expressed as
  ``psum_scatter(intra) → psum(inter) → all_gather(intra)``.  Each chip is
  "leader" for its shard slice: cross-domain traffic drops t× (paper
  Table 2: 2·(g−1)·Mp/(g·B2) + 2·(t−1)·Mp/(t·B1)).

The same schedules serve two scales:
  DRL GMIs   — intra axis = instances on one GPU, inter axis = GPUs;
  LLM pods   — intra axis = 'data' (ICI), inter axis = 'pod' (DCN).
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------- in-SPMD --
def flat_psum(grads, axis_names):
    """MRR analogue: one flat all-reduce over the merged axes."""
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_names), grads)


def hierarchical_psum(grads, intra_axis: str, inter_axis: str):
    """HAR: reduce_scatter(intra) -> psum(inter) -> all_gather(intra).

    Operates leaf-wise on flattened gradients (padded to the intra axis
    size) so arbitrary parameter shapes work.
    """
    # psum of a Python literal folds to the static axis size on every jax
    # version this repo supports — the one call path that never probes.
    intra = jax.lax.psum(1, intra_axis)

    def one(g):
        shape = g.shape
        flat = g.reshape(-1)
        n = flat.shape[0]
        pad = (-n) % intra
        flat = jnp.pad(flat, (0, pad))
        shard = jax.lax.psum_scatter(flat.reshape(intra, -1), intra_axis,
                                     scatter_dimension=0, tiled=False)
        shard = jax.lax.psum(shard, inter_axis)
        full = jax.lax.all_gather(shard, intra_axis, axis=0,
                                  tiled=False).reshape(-1)
        return full[:n].reshape(shape)

    return jax.tree.map(one, grads)


def make_grad_sync(strategy: str, intra_axis: str = "inst",
                   inter_axis: str = "gpu") -> Callable:
    """Gradient-sync function usable inside shard_map/pjit-SPMD bodies."""
    if strategy == "mrr":
        return functools.partial(flat_psum, axis_names=(inter_axis,
                                                        intra_axis))
    if strategy == "har":
        return functools.partial(hierarchical_psum, intra_axis=intra_axis,
                                 inter_axis=inter_axis)
    if strategy == "mpr":
        # inside an SPMD program MPR degenerates to a flat reduce; the true
        # host-staged variant is ``mpr_host`` below (submesh backend).
        return functools.partial(flat_psum, axis_names=(inter_axis,
                                                        intra_axis))
    raise ValueError(strategy)


# ------------------------------------------------------------- host-staged -
def mpr_host(grads_per_instance: Sequence):
    """True multi-process reduction for the submesh (MIG-like) backend:
    every instance's gradients are pulled to host, averaged on CPU, and the
    result is returned (to be device_put per instance by the caller).

    This is the paper's generic-but-slow baseline: O(g·t) host transfers
    and CPU-side arithmetic.
    """
    host_trees = [jax.tree.map(np.asarray, jax.device_get(g))
                  for g in grads_per_instance]
    n = len(host_trees)
    return jax.tree.map(lambda *xs: sum(xs) / n, *host_trees)


# -------------------------------------------------------------- shard_map --
def lgr_allreduce(grads, mesh: Mesh, strategy: str,
                  intra_axis: str = "inst", inter_axis: str = "gpu"):
    """Run an LGR schedule over per-instance gradient replicas.

    ``grads`` leaves must carry a leading (inter, intra) instance grid:
    shape (g, t, ...) — one gradient per instance.  Returns the reduced
    (averaged) gradient with the same leading grid (all replicas equal).
    """
    if mesh.devices.ndim != 2:
        # GMIManager.instance_mesh returns a (gpu, inst, dev) grid for
        # multi-device GMIs so resized instances can't silently lose
        # chips; the LGR schedules below only reduce over (gpu, inst).
        raise ValueError(
            f"LGR schedules reduce over a 2-axis (gpu, inst) instance "
            f"grid; got axes {mesh.axis_names}.  Multi-device GMIs need "
            "a per-'dev' reduction first (ROADMAP open item) or the "
            "mpr_host fallback.")
    g_, t_ = mesh.devices.shape
    sync = make_grad_sync(strategy, intra_axis, inter_axis)
    ntot = g_ * t_

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(inter_axis, intra_axis), grads),),
        out_specs=jax.tree.map(lambda _: P(inter_axis, intra_axis), grads))
    def run(gs):
        local = jax.tree.map(lambda x: x[0, 0], gs)
        red = sync(local)
        return jax.tree.map(lambda x: (x / ntot)[None, None], red)

    return run(grads)
