"""Analytical cost models from the paper (Tables 2, 4, 5; Eqs. 1-3).

These models drive template selection (TCG vs TDG), predict the paper's
headline speedups (~2.5x serving, ~5x sync training), and provide the
LGR time-complexity comparison used by the benchmark for Table 7.

Paper empirical constants (§5.1): alpha ~= 0.2, beta ~= 0.3,
R_s ~= 10 R_a ~= 5 R_t, T_s ~= 6 T_a ~= 3 T_t.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class WorkloadProfile:
    """Per-role dominant-resource sizes and per-iteration times (Table 3)."""
    R_s: float = 10.0     # simulator resource
    R_a: float = 1.0      # agent resource
    R_t: float = 2.0      # trainer resource  (R_s ≈ 5 R_t)
    T_s: float = 6.0      # simulator time
    T_a: float = 1.0      # agent time        (T_s ≈ 6 T_a)
    T_t: float = 2.0      # trainer time      (T_s ≈ 3 T_t)
    alpha: float = 0.2    # sharing ratio: many sims per agent
    beta: float = 0.3     # sharing ratio: many sims per trainer
    R_all: float = 80.0   # total resource pool (e.g. 8 GPUs x 10 units)


# --------------------------------------------------- Table 2: LGR times ----
def lgr_time_mpr(g: int, t: int, M_p: float, B1: float, B2: float) -> float:
    return 2 * (g * t - 1) * M_p / (g * t * B1)


def lgr_time_mrr(g: int, t: int, M_p: float, B1: float, B2: float) -> float:
    return 2 * (g - 1) * (t + 1) * M_p / (g * B2)


def lgr_time_har(g: int, t: int, M_p: float, B1: float, B2: float) -> float:
    return 2 * (g - 1) * M_p / (g * B2) + 2 * (t - 1) * M_p / (t * B1)


def lgr_time_har3(g: int, t: int, d: int, M_p: float, B1: float,
                  B2: float, B3: float) -> float:
    """3-level HAR over a (gpu=g, inst=t, dev=d) grid: the dev-level
    scatter/gather rides the fastest links (B3, intra-instance chips),
    the inst level works on 1/d shards over B1, and the cross-GPU ring
    works on 1/(t·d) shards over B2 — the Table-2 recurrence applied one
    level deeper."""
    return (2 * (d - 1) * M_p / (d * B3)
            + 2 * (t - 1) * M_p / (d * t * B1)
            + 2 * (g - 1) * M_p / (t * d * g * B2))


LGR_TIMES = {"mpr": lgr_time_mpr, "mrr": lgr_time_mrr, "har": lgr_time_har}


def lgr_coeffs(strategy: str, g: int, t: int, d: int, M_p: float) \
        -> tuple:
    """Per-axis byte coefficients of the Table-2 recurrences.

    Every ``lgr_time_*`` form above is linear in the *inverse* bandwidths:
    ``time == c1/B1 + c2/B2 + c3/B3``.  This returns ``(c1, c2, c3)`` —
    the design row the bandwidth calibrator
    (:class:`repro.comm.calibrate.BandwidthCalibrator`) inverts to fit
    effective B1/B2/B3 from measured reduce seconds.  The 2-level forms
    (mpr/mrr/har) take the merged instance count as ``t`` and ignore
    ``d``, mirroring how :class:`repro.comm.select.ReduceCostModel`
    evaluates them.
    """
    if strategy == "mpr":
        return (2 * (g * t - 1) * M_p / (g * t), 0.0, 0.0)
    if strategy == "mrr":
        return (0.0, 2 * (g - 1) * (t + 1) * M_p / g, 0.0)
    if strategy == "har":
        return (2 * (t - 1) * M_p / t, 2 * (g - 1) * M_p / g, 0.0)
    if strategy == "har3":
        return (2 * (t - 1) * M_p / (d * t),
                2 * (g - 1) * M_p / (t * d * g),
                2 * (d - 1) * M_p / d)
    raise ValueError(f"unknown reduction strategy {strategy!r}")


def best_lgr(g: int, t: int, M_p: float, B1: float, B2: float) -> str:
    feasible = {"mpr", "har"} | ({"mrr"} if t <= g else set())
    return min(feasible, key=lambda s: LGR_TIMES[s](g, t, M_p, B1, B2))


# ------------------------------------------- Table 4: serving templates ----
def serving_resource_tdg(w: WorkloadProfile) -> float:
    return (w.T_s * w.R_s + w.T_a * w.alpha * w.R_a) / (w.T_s + w.T_a)


def serving_resource_tcg(w: WorkloadProfile) -> float:
    return (w.T_s + w.T_a) * max(w.R_s, w.R_a) / (w.T_s + w.T_a)


def serving_com_tdg(S: float, A: float, W: float) -> float:
    return 2 * S + A + W


def serving_throughput(w: WorkloadProfile, R: float, com_over_bw: float) \
        -> float:
    """Eq. 2: TOP = (R_all / R) * 1 / (T_s + T_a + COM/BW)."""
    return (w.R_all / R) / (w.T_s + w.T_a + com_over_bw)


def serving_speedup_tcg_over_tdg(w: WorkloadProfile = WorkloadProfile()) \
        -> float:
    """Paper §5.1: ~2.5x, with COM/BW ≈ 2·(T_s+T_a) for TDG."""
    r_tdg = serving_resource_tdg(w)
    r_tcg = serving_resource_tcg(w)
    top_tdg = serving_throughput(w, r_tdg, 2.0 * (w.T_s + w.T_a))
    top_tcg = serving_throughput(w, r_tcg, 0.0)
    return top_tcg / top_tdg


# ------------------------------------------ Table 5: training templates ----
def training_resource_tdg_ex(w: WorkloadProfile) -> float:
    return (w.T_s * w.R_s + w.T_a * w.alpha * w.R_a
            + w.T_t * w.beta * w.R_t) / (w.T_s + w.T_a + w.T_t)


def training_resource_tcg_ex(w: WorkloadProfile) -> float:
    return max(w.R_s, w.R_a, w.R_t)


def training_com_tdg_ex(m: int, S: float, A: float, W: float, M_p: float,
                        n: int) -> float:
    return m * (S + A + W) + M_p + 2 * (n - 1) * M_p / n


def training_com_tcg_ex(M_p: float, n: int) -> float:
    return 2 * (n - 1) * M_p / n


def training_throughput(w: WorkloadProfile, R: float, com_over_bw: float) \
        -> float:
    """Eq. 3."""
    return (w.R_all / R) / (w.T_s + w.T_a + w.T_t + com_over_bw)


def training_speedup_tcg_over_tdg(w: WorkloadProfile = WorkloadProfile()) \
        -> float:
    """Paper §5.1: ~5x, with COM/BW ≈ 7·(T_s+T_a+T_t) for TDG_EX and the
    gradient-ring only for TCG_EX (≈ 0.35·cycle on the paper's profile)."""
    r_tdg = training_resource_tdg_ex(w)
    r_tcg = training_resource_tcg_ex(w)
    cyc = w.T_s + w.T_a + w.T_t
    top_tdg = training_throughput(w, r_tdg, 7.0 * cyc)
    top_tcg = training_throughput(w, r_tcg, 0.35 * cyc)
    return top_tcg / top_tdg


# ------------------------------------------------------- Eq. 1: resource ---
def dominant_resource(R_sm: float, sm_per_gpu: float, R_mem: float,
                      mem_per_gpu: float) -> str:
    return "SM" if R_sm / sm_per_gpu >= R_mem / mem_per_gpu else "Memory"


# --------------------------------------- cache migration (disaggregation) ---
# Prefill/decode disaggregation prices a finished prefill cache shipped
# from a prefill GMI to a decode GMI in the SAME units as Table 2: a
# point-to-point transfer over one of the B1/B2/B3 bandwidth tiers.  The
# alternative is running the prompt's prefill locally on the decode GMI,
# which stalls its whole decode batch for the prefill duration.

def migration_time(nbytes: float, bandwidth: float,
                   latency_s: float = 0.0) -> float:
    """Seconds to ship ``nbytes`` of packed cache over a ``bandwidth``
    bytes/s link (calibrated B1/B2 in practice) plus a fixed per-transfer
    ``latency_s`` (pack/unpack + ring hop)."""
    return latency_s + nbytes / max(bandwidth, 1e-9)


def local_prefill_time(prompt_tokens: int, prefill_tok_s: float) -> float:
    """Seconds the decode batch stalls if the decode GMI prefills this
    prompt itself, from a measured prefill throughput (tokens/s)."""
    return prompt_tokens / max(prefill_tok_s, 1e-9)


def migration_gain(nbytes: float, prompt_tokens: int, bandwidth: float,
                   prefill_tok_s: float, latency_s: float = 0.0) -> float:
    """Ratio local-prefill-stall / migration-cost.  > 1 means shipping
    the prefilled cache beats recomputing the prefill on the decode GMI;
    compare against the controller's ``min_gain`` (1.05x) hysteresis so
    the per-request decision and the GMI arbiter share one threshold."""
    return (local_prefill_time(prompt_tokens, prefill_tok_s)
            / max(migration_time(nbytes, bandwidth, latency_s), 1e-12))


def migration_beats_local(nbytes: float, prompt_tokens: int,
                          bandwidth: float, prefill_tok_s: float,
                          latency_s: float = 0.0,
                          min_gain: float = 1.05) -> bool:
    return migration_gain(nbytes, prompt_tokens, bandwidth,
                          prefill_tok_s, latency_s) >= min_gain


# ------------------------------------------- paged cache migration pricing ---
# With paged decode caches the migration unit is the fixed-size page, not
# the monolithic per-slot cache: a payload ships only the pages its prompt
# actually filled (minus any pages the destination already holds in its
# shared-prefix index), so the wire cost scales with ceil(prompt/page)
# instead of with max_seq.  These helpers keep the per-request
# migrate-vs-local decision in the same Table-2 units as above.

def pages_for_tokens(tokens: int, page_size: int) -> int:
    """Physical pages covering ``tokens`` cache entries."""
    return -(-max(int(tokens), 0) // max(int(page_size), 1))


def paged_migration_bytes(prompt_tokens: int, page_size: int,
                          page_bytes: float, shared_head_pages: int = 0)\
        -> float:
    """Wire bytes for a page-wise cache payload: the prompt's pages minus
    the leading ``shared_head_pages`` already resident on the decode GMI
    (shared-prefix dedup — those pages migrate once per decode GMI, not
    once per request)."""
    pages = pages_for_tokens(prompt_tokens, page_size)
    return max(pages - max(int(shared_head_pages), 0), 0) * float(page_bytes)


def paged_migration_time(prompt_tokens: int, page_size: int,
                         page_bytes: float, bandwidth: float,
                         latency_s: float = 0.0,
                         shared_head_pages: int = 0) -> float:
    """Seconds to ship a page-wise payload (fixed hop latency + pages on
    the wire)."""
    return migration_time(
        paged_migration_bytes(prompt_tokens, page_size, page_bytes,
                              shared_head_pages), bandwidth, latency_s)


def migration_crossover_tokens(page_size: int, page_bytes: float,
                               bandwidth: float, prefill_tok_s: float,
                               latency_s: float = 0.0,
                               min_gain: float = 1.05,
                               max_tokens: int = 1 << 20) -> int:
    """Smallest prompt length whose page-wise migration beats local
    prefill (the bench_disagg crossover row).  Returns ``max_tokens`` when
    migration never wins below that bound (e.g. bandwidth too low)."""
    for n in range(1, int(max_tokens) + 1):
        t_mig = paged_migration_time(n, page_size, page_bytes, bandwidth,
                                     latency_s)
        if local_prefill_time(n, prefill_tok_s) >= min_gain * t_mig:
            return n
    return int(max_tokens)
