"""GMI — GPU/TPU Multiplexing Instance (paper §3).

On TPU a GMI is a named, resource-budgeted slice of the device mesh:
``n_devices`` chips assigned to one DRL role.  Two backends mirror the
paper's MPS/MIG duality:

* ``axis``    (MPS-like): instances are index ranges along a shared mesh
  axis inside ONE SPMD program — collectives between instances are possible
  (needed for training); isolation is logical.
* ``submesh`` (MIG-like): instances own disjoint ``jax.sharding.Mesh``
  objects — hard isolation, no direct collectives; cross-instance data must
  stage through the host (the "memory barrier" of §1 that LGR/MCC exist to
  work around).

``GMIManager`` mirrors Listing 1's ``GMI_DRL.GMI_manager``: registration,
device attachment, communication groups.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh


@dataclass
class GMI:
    gmi_id: int
    role: str                       # "simulator" | "agent" | "trainer" | "holistic"
    device_ids: List[int]           # global device indices owned
    gpu_id: int                     # which physical device group (paper: GPU)
    backend: str = "axis"           # "axis" | "submesh"
    resource_fraction: float = 1.0  # paper: SM fraction / MIG slice size
    group: Optional[str] = None

    @property
    def num_devices(self) -> int:
        return len(self.device_ids)


class GMIManager:
    """Global registry of instances and their layout (Listing 1)."""

    def __init__(self, devices: Optional[Sequence] = None,
                 devices_per_gpu: Optional[int] = None,
                 backend: str = "axis"):
        self.devices = list(devices if devices is not None else jax.devices())
        self.devices_per_gpu = devices_per_gpu or len(self.devices)
        self.backend = backend
        self.gmis: Dict[int, GMI] = {}
        self.groups: Dict[str, List[int]] = {}

    # -- Listing 1 API ---------------------------------------------------
    def add_gmi(self, gmi_id: int, role: str = "holistic",
                resource_fraction: float = 1.0) -> GMI:
        if gmi_id in self.gmis:
            raise ValueError(f"GMI {gmi_id} already registered")
        g = GMI(gmi_id, role, [], -1, self.backend, resource_fraction)
        self.gmis[gmi_id] = g
        return g

    def set_gpu(self, gmi_id: int, gpu_id: int):
        """Attach a GMI to a physical device group and carve its slice."""
        g = self.gmis[gmi_id]
        start = gpu_id * self.devices_per_gpu
        pool = list(range(start, start + self.devices_per_gpu))
        taken = [d for other in self.gmis.values()
                 if other.gpu_id == gpu_id for d in other.device_ids]
        free = [d for d in pool if d not in taken]
        want = max(int(round(self.devices_per_gpu * g.resource_fraction)), 1)
        if len(free) < want:
            raise ValueError(
                f"GPU {gpu_id}: need {want} devices, {len(free)} free "
                f"(resource overcommit — paper Alg.2 'not runnable')")
        g.gpu_id = gpu_id
        g.device_ids = free[:want]

    def get_group(self, gmi_id: int, name: str = "default") -> str:
        self.groups.setdefault(name, [])
        if gmi_id not in self.groups[name]:
            self.groups[name].append(gmi_id)
        self.gmis[gmi_id].group = name
        return name

    # -- layout queries ----------------------------------------------------
    def gmi_to_gpu_mapping(self, role: Optional[str] = None) -> List[List[int]]:
        """The MPL list of Algorithm 1: MPL[g] = GMI ids on GPU g."""
        sel = [g for g in self.gmis.values()
               if role is None or g.role == role]
        gpus = sorted({g.gpu_id for g in sel})
        return [[g.gmi_id for g in sel if g.gpu_id == gid] for gid in gpus]

    def submesh(self, gmi_id: int, axis_name: str = "devices") -> Mesh:
        """MIG-like backend: a dedicated Mesh over the instance's devices."""
        g = self.gmis[gmi_id]
        devs = np.array([self.devices[i] for i in g.device_ids])
        return Mesh(devs, (axis_name,))

    def instance_mesh(self, role: str, axes=("gpu", "inst")) -> Mesh:
        """Axis backend: one shared mesh (gpu × instance) over all GMIs of a
        role — instances are coordinates along ``inst``; LGR collectives run
        over these axes.  Multi-device GMIs (resized slices) contribute ALL
        their chips along a trailing ``dev`` axis — silently keeping only
        ``device_ids[0]`` would shrink a resized instance unnoticed."""
        mpl = self.gmi_to_gpu_mapping(role)
        if not mpl:
            raise ValueError(f"no GMIs with role {role}")
        t = len(mpl[0])
        if any(len(row) != t for row in mpl):
            raise ValueError("axis backend needs a rectangular GMI layout")
        sizes = {self.gmis[gmi_id].num_devices
                 for row in mpl for gmi_id in row}
        if 0 in sizes:
            raise ValueError(
                f"role {role} has GMIs with no devices attached "
                "(set_gpu not called)")
        if len(sizes) > 1:
            raise ValueError(
                f"axis backend needs uniform devices-per-GMI, got {sizes} "
                "for role " + role)
        d = sizes.pop()
        if d == 1:
            dev_grid = np.empty((len(mpl), t), dtype=object)
            for gi, row in enumerate(mpl):
                for ii, gmi_id in enumerate(row):
                    dev_grid[gi, ii] = self.devices[
                        self.gmis[gmi_id].device_ids[0]]
            return Mesh(dev_grid, axes)
        if "dev" in axes:
            raise ValueError("axes may not already contain 'dev'")
        dev_grid = np.empty((len(mpl), t, d), dtype=object)
        for gi, row in enumerate(mpl):
            for ii, gmi_id in enumerate(row):
                for di, dev_id in enumerate(self.gmis[gmi_id].device_ids):
                    dev_grid[gi, ii, di] = self.devices[dev_id]
        return Mesh(dev_grid, tuple(axes) + ("dev",))

    def summary(self) -> str:
        lines = [f"GMIManager(backend={self.backend}, "
                 f"devices={len(self.devices)}, "
                 f"per_gpu={self.devices_per_gpu})"]
        for g in sorted(self.gmis.values(), key=lambda x: x.gmi_id):
            lines.append(
                f"  GMI {g.gmi_id}: role={g.role} gpu={g.gpu_id} "
                f"devices={g.device_ids} frac={g.resource_fraction}")
        return "\n".join(lines)


class DRLRole:
    """Process-based GMI programming base class (paper Listing 1)."""

    def __init__(self, manager: GMIManager, gmi_id: int, role: str,
                 gpu_id: int, resource_fraction: float = 1.0):
        self.gmi_id = gmi_id
        self.role = role
        self.mgr = manager
        self.mgr.add_gmi(gmi_id, role, resource_fraction)
        self.mgr.set_gpu(gmi_id, gpu_id)
        self.group = self.mgr.get_group(gmi_id, role)

    # communication primitives are provided by repro.core.channels /
    # repro.comm; subclasses implement the execution routine:
    def gmi_run(self, *args, **kwargs):
        raise NotImplementedError
