"""Online GMI management — the runtime half of Algorithm 2 (paper §5.2).

``selection.explore`` searches (GMIperGPU, num_env) *offline* with a
profiling callable and the saturation metric Sat = ΔTOP/ΔMem.  The paper's
adaptive GMI management does not stop there: the serving:training resource
split is workload-dependent (arXiv:2012.04210) and the right num_env moves
with the policy size and environment mix, so the same search has to keep
running against *live* measurements.  This controller closes that loop:

* every serve/train round the runner reports what actually happened —
  delivered samples, wall time, ring-occupancy high water, spill count,
  delivered bytes (the memory-pressure proxy) — one :class:`RoundSample`;
* every ``epoch_rounds`` rounds the samples fold into a recorded
  :class:`ProfilePoint` keyed by the live (gmi_per_gpu, num_env) and
  ``selection.explore`` re-runs over the *measured* table (unmeasured
  configs report not-runnable, so the search only walks observed ground
  and the fixed Sat rule handles flat/shrinking memory between recorded
  points);
* ring pressure drives the serving:training GPU split: any spill means
  producers genuinely outran the trainers (the ring overflowed between
  flushes) — shift one GPU from serving to training; occupancy under
  the low-water mark with no spills means trainers starve — shift one
  back.  Occupancy exactly at 1.0 is NOT pressure: a group-sized ring
  filled once per round is the healthy round-interleaved pattern;
* when the measured ladder is too thin to compute saturation for the
  current GMIperGPU, the controller proposes *probing* the next num_env
  up the sweep (Algorithm 2's explore step, now interleaved with
  exploitation);
* a re-plan is only emitted when the projected system throughput of the
  winning config beats the live config by ``min_gain`` (hysteresis —
  re-planning drains rings and resets environments, it is not free);
* with an attached :class:`repro.comm.Communicator`, the reduction
  strategy joins the re-plan loop: measured per-round reduce times flow
  into the communicator (``RoundSample.reduce_s`` or direct
  ``Communicator.observe`` calls from the runner), and when
  ``propose_switch`` says the measured time disagrees with the current
  choice by more than the same ``min_gain`` hysteresis, the decision
  carries a ``reduction_strategy`` — applied by ``AsyncRunner.replan``
  as pure communication plumbing (model/optimizer state untouched).
  With calibration enabled on the communicator, those same measured
  reduce times (plus the pipeline's channel-transfer timings, forwarded
  by :meth:`OnlineGMIController.observe_pipeline`) feed a
  :class:`~repro.comm.calibrate.BandwidthCalibrator`; once its Table-2
  inversion is conditioned the switch decision is scored against
  *measured* per-axis bandwidths instead of the static defaults; while
  feasible candidates remain unmeasured the controller schedules
  in-place probes of them (one visit each — a probe in progress is left
  alone until its calibration cell fills) to condition the fit.

Since PR 5 the controller also closes the loop for the *request-serving*
half (paper §4's adaptive GMI management under inference traffic): each
serving engine's telemetry epoch (a duck-typed
:class:`repro.serve.telemetry.ServingLoad` — queue depth, decode-slot
occupancy, p50/p95 latency, tok/s) folds into its own measured
ProfilePoint table via :meth:`OnlineGMIController.observe_serving`, keyed
(gmi_per_gpu, decode slots) so the slot ladder plays the role num_env
plays for rollouts.  Sustained admission backlog (every round of the
epoch ends with requests waiting and all slots busy) moves a GPU *to*
serving; an idle epoch (occupancy under the low-water mark, empty queues)
gives one back; when the split cannot grow, the controller probes the
next decode-slot count up the ladder instead (Algorithm 2's explore step
under traffic); and ``selection.explore`` re-runs over the measured
serving table under the same ``min_gain`` hysteresis.
``repro.serve.RequestRouter.maybe_replan`` applies the resulting
``Decision`` by scaling its engine set.

``plan_layout`` materializes the current decision as a
``placement.plan_async`` layout so the runner can rebuild its pipeline
between training epochs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.selection import (NUM_ENV_SWEEP, ProfilePoint,
                                  estimate_system_throughput, explore)


SLOT_SWEEP = (1, 2, 4, 8, 16, 32, 64)


@dataclass
class ControllerConfig:
    alpha: float = 0.1             # explore()'s saturation threshold
    epoch_rounds: int = 4          # rounds folded into one decision epoch
    min_gain: float = 1.05         # projected-throughput hysteresis
    occ_low: float = 0.25          # trainer starvation -> grow serving side
    num_env_sweep: Tuple[int, ...] = NUM_ENV_SWEEP
    probe: bool = True             # walk the num_env ladder when unmeasured
    slot_sweep: Tuple[int, ...] = SLOT_SWEEP  # decode-slot ladder (serving)


@dataclass
class RoundSample:
    """One serve/train round's live measurements."""
    samples: int                   # experience samples delivered to trainers
    dt: float                      # wall seconds for the round
    occupancy: float               # ring fill high-water during the round
    spills: int                    # ring-overflow spills during the round
    mem_bytes: float               # bytes moved (memory-pressure proxy)
    reduce_s: float = 0.0          # measured gradient-reduce seconds


@dataclass
class Decision:
    """A re-plan emitted between training epochs."""
    num_env: int
    gmi_per_gpu: int
    serving_gpus: int
    reason: str
    # set when the measured reduce time says the LGR schedule should
    # change; applied by the runner via Communicator.switch (no model
    # state involved)
    reduction_strategy: Optional[str] = None
    # False when ONLY the reduction strategy moved: the runner then
    # switches the communicator in place instead of paying the full
    # drain-and-rebuild re-plan
    layout_changed: bool = True
    # set by serving decisions: decode slots per engine (the serving
    # analogue of the num_env ladder); None for rollout decisions
    slots: Optional[int] = None
    # prefill-specialist GPUs carved out of the serving pool
    # (disaggregated serving); None when the epoch carried no
    # prefill/decode telemetry
    prefill_gpus: Optional[int] = None
    # staleness fence for the single-arbiter control plane: the
    # controller's ``plan_seq`` at emission time.  ``AsyncRunner.replan``
    # bumps ``plan_seq`` on every drain/rebuild, so an apply path
    # (``RequestRouter.apply_decision``) can refuse a decision computed
    # against a layout that no longer exists.
    seq: int = 0


@dataclass
class _Recorded:
    point: ProfilePoint
    epochs: int = 0


def _fold_point(table: Dict[Tuple[int, int], "_Recorded"],
                key: Tuple[int, int], top: float, mem: float) -> None:
    """Fold one measured (throughput, memory) epoch into a recorded table
    as a running mean — shared by the rollout and serving tables."""
    rec = table.get(key)
    if rec is None:
        table[key] = _Recorded(ProfilePoint(True, top, mem), 1)
        return
    n = rec.epochs
    rec.point = ProfilePoint(
        True, (rec.point.throughput * n + top) / (n + 1),
        (rec.point.memory * n + mem) / (n + 1))
    rec.epochs = n + 1


def _frozen_profile(table: Dict[Tuple[int, int], "_Recorded"]):
    """A recorded table as an ``explore``-compatible profile callable:
    measured configs answer with their point, everything else is
    not-runnable (the online search never extrapolates)."""
    frozen = {k: r.point for k, r in table.items()}

    def profile(bench: str, first: int, second: int) -> ProfilePoint:
        return frozen.get((first, second), ProfilePoint(False, 0.0, 0.0))

    return profile


class OnlineGMIController:
    """Feeds live pipeline stats back into Algorithm 2 and re-plans the
    GMI layout between training epochs."""

    def __init__(self, num_gpu: int, serving_gpus: int, gmi_per_gpu: int,
                 num_env: int, cfg: Optional[ControllerConfig] = None,
                 communicator=None):
        if not (1 <= serving_gpus < num_gpu):
            raise ValueError("need 1 <= serving_gpus < num_gpu")
        self.num_gpu = int(num_gpu)
        self.serving_gpus = int(serving_gpus)
        self.gmi_per_gpu = int(gmi_per_gpu)
        self.num_env = int(num_env)
        self.cfg = cfg or ControllerConfig()
        self.communicator = communicator
        self._table: Dict[Tuple[int, int], _Recorded] = {}
        self._epoch: List[RoundSample] = []
        self._spill_mark = 0
        self._bytes_mark = 0
        self.decisions: List[Decision] = []
        # request-serving loop (PR 5): its own measured table, keyed
        # (gmi_per_gpu, decode slots) — the slot ladder is the serving
        # analogue of the num_env ladder
        self.serving_slots = 0         # learned from the first epoch
        self._serving_table: Dict[Tuple[int, int], _Recorded] = {}
        self._serving_epoch: List = []
        # disaggregated serving (PR 7): prefill-specialist GPUs carved
        # out of the serving pool; 0 = aggregated (every serving GMI
        # prefills locally).  Arbitrated in _decide_serving from the
        # prefill_backlog/migrations telemetry fields.
        self.prefill_gpus = 0
        # bumped by AsyncRunner.replan on every drain/rebuild; stamped
        # onto emitted decisions as the staleness fence
        self.plan_seq = 0

    # ------------------------------------------------------- observation --
    def observe_pipeline(self, pipeline, samples: int,
                         dt: float) -> Optional[Decision]:
        """Convenience: pull occupancy/spill/bytes deltas off a
        ``MultiChannelPipeline`` after one round and :meth:`record`.
        When the communicator is calibrating, the pipeline's per-round
        channel-transfer timings are forwarded as B1 evidence."""
        if self.communicator is not None:
            take = getattr(pipeline, "take_transfer_samples", None)
            if take is not None:
                for sec, nbytes in take():
                    self.communicator.observe_transfer(sec, nbytes)
        if pipeline.spill_count < self._spill_mark \
                or pipeline.stats.total_bytes < self._bytes_mark:
            # fresh pipeline after a re-plan: counters restarted at zero
            self._spill_mark = 0
            self._bytes_mark = 0
        spills = pipeline.spill_count - self._spill_mark
        mem = pipeline.stats.total_bytes - self._bytes_mark
        self._spill_mark = pipeline.spill_count
        self._bytes_mark = pipeline.stats.total_bytes
        return self.record(RoundSample(
            samples=samples, dt=dt,
            occupancy=pipeline.take_occupancy_high_water(),
            spills=spills, mem_bytes=float(mem)))

    def record(self, sample: RoundSample) -> Optional[Decision]:
        """Fold one round in; returns a Decision at epoch boundaries when
        the measured evidence says the layout should change."""
        if self.communicator is not None and sample.reduce_s > 0.0:
            # runners that time the sync closure themselves call
            # Communicator.observe directly; this path serves external
            # callers that only report RoundSamples
            self.communicator.observe(sample.reduce_s)
        self._epoch.append(sample)
        if len(self._epoch) < self.cfg.epoch_rounds:
            return None
        rounds, self._epoch = self._epoch, []
        dt = sum(s.dt for s in rounds)
        samples = sum(s.samples for s in rounds)
        if dt <= 0.0 or samples <= 0:
            return None
        # per-serving-instance throughput, so recorded points are
        # comparable across gmi_per_gpu exactly like offline profiles
        n_inst = max(self.serving_gpus * self.gmi_per_gpu, 1)
        top = samples / dt / n_inst
        mem = sum(s.mem_bytes for s in rounds) / len(rounds)
        _fold_point(self._table, (self.gmi_per_gpu, self.num_env), top, mem)
        occ = max(s.occupancy for s in rounds)
        spills = sum(s.spills for s in rounds)
        return self._decide(occ, spills)

    # ------------------------------------------------- serving observation --
    def observe_serving(self, load) -> Optional[Decision]:
        """Fold one serving telemetry epoch (a duck-typed
        :class:`repro.serve.telemetry.ServingLoad`: needs ``dt, tokens,
        occupancy_mean, queue_depth_mean, queue_depth_max, backlog,
        p95_s``; ``slots`` and ``mem_bytes`` optional) into the serving
        half of the Algorithm-2 loop.  Loads are expected at ROUTER level
        (aggregated over the serving engines, e.g.
        ``RequestRouter.take_epoch``): ``slots`` is the total decode-slot
        count, divided by the live instance count to key the measured
        table.  Emits a Decision at epoch boundaries when measured
        traffic says the serving side should grow, shrink, or
        re-shape."""
        self._serving_epoch.append(load)
        if len(self._serving_epoch) < self.cfg.epoch_rounds:
            return None
        rounds, self._serving_epoch = self._serving_epoch, []
        n_inst = max(self.serving_gpus * self.gmi_per_gpu, 1)
        # the slot ladder state follows what the telemetry says actually
        # ran — an unapplied probe decision resets here instead of
        # mis-keying every later epoch under a width that never existed
        obs = [float(getattr(l, "slots", 0)) for l in rounds]
        per_inst = int(round(sum(obs) / len(obs) / n_inst))
        if per_inst >= 1:
            self.serving_slots = per_inst
        elif self.serving_slots <= 0:
            self.serving_slots = 1
        dt = sum(l.dt for l in rounds)
        tokens = sum(l.tokens for l in rounds)
        if dt > 0.0 and tokens > 0:
            # per-serving-instance tok/s, comparable across gmi_per_gpu
            # exactly like the rollout table
            top = tokens / dt / n_inst
            mem = sum(float(getattr(l, "mem_bytes", 0.0))
                      for l in rounds) / len(rounds)
            _fold_point(self._serving_table,
                        (self.gmi_per_gpu, self.serving_slots), top, mem)
        return self._decide_serving(rounds)

    def _decide_serving(self, rounds) -> Optional[Decision]:
        cfg = self.cfg
        # sustained pressure: every round of the epoch ended with requests
        # waiting while all decode slots were busy (a transient queue
        # blip inside one round is not pressure)
        backlogged = all(l.backlog > 0 for l in rounds)
        idle = (max(l.occupancy_mean for l in rounds) <= cfg.occ_low
                and all(l.backlog == 0 for l in rounds)
                and max(l.queue_depth_max for l in rounds) == 0)
        serving = self.serving_gpus
        slots = self.serving_slots
        reason = None
        q = sum(l.queue_depth_mean for l in rounds) / len(rounds)
        p95 = max(l.p95_s for l in rounds)
        if backlogged and serving < self.num_gpu - 1:
            serving += 1
            reason = (f"serving backlog (queue={q:.1f}, "
                      f"p95={p95 * 1e3:.0f}ms): +1 serving GPU")
        elif backlogged and cfg.probe:
            # the split cannot grow: walk the decode-slot ladder instead
            # (Algorithm 2's explore step under traffic) — to the next
            # UNMEASURED rung, so a measured neighbor can't stall the walk
            nxt = next(
                (s for s in cfg.slot_sweep if s > slots
                 and (self.gmi_per_gpu, s) not in self._serving_table),
                None)
            if nxt is not None:
                slots = nxt
                reason = (f"serving backlog at max split (queue={q:.1f}): "
                          f"probe slots={nxt}")
        elif idle and serving > 1:
            serving -= 1
            occ = max(l.occupancy_mean for l in rounds)
            reason = (f"serving idle (occ={occ:.2f}, empty queue): "
                      "+1 training GPU")

        # prefill:decode arbitration inside the serving pool (disagg):
        # sustained prefill backlog moves a serving GPU to prefill duty;
        # an epoch with zero prefill work anywhere gives one back.  Only
        # active when the telemetry actually carries disagg signals —
        # aggregated fleets never enter here.
        prefill = self.prefill_gpus
        pf_back = [int(getattr(l, "prefill_backlog", 0)) for l in rounds]
        pf_migr = [int(getattr(l, "migrations", 0)) for l in rounds]
        disagg = prefill > 0 or any(pf_back) or any(pf_migr)
        if disagg:
            if all(b > 0 for b in pf_back) and prefill < serving - 1:
                prefill += 1
                note = (f"prefill backlog ({sum(pf_back)} waiting): "
                        "+1 prefill GMI")
                reason = f"{reason}; {note}" if reason else note
            elif prefill > 1 and not any(pf_back) and not any(pf_migr):
                prefill -= 1
                note = "prefill idle epoch: +1 decode GMI"
                reason = f"{reason}; {note}" if reason else note

        # explore over the measured serving table: same search, with the
        # slot ladder standing in for the num_env sweep.  The search is
        # PINNED to the live gmi_per_gpu — that knob belongs to the
        # rollout re-plan loop; a serving decision moving it would
        # corrupt the rollout table's keying without anything re-planning
        # the training side.  A just-decided probe is never overwritten:
        # exploitation waits until the probed rung has been measured.
        probing = slots != self.serving_slots
        keys = [k for k in self._serving_table if k[0] == self.gmi_per_gpu]
        if not probing and len(keys) > 1:
            slot_sweep = sorted(k[1] for k in keys)
            trace = explore(self._serving_profile(), "serving",
                            self.num_gpu, alpha=cfg.alpha,
                            gmi_per_gpu_range=[self.gmi_per_gpu],
                            num_env_sweep=slot_sweep)
            sl, _ = trace.best_config
            cur = self._serving_table.get(
                (self.gmi_per_gpu, self.serving_slots))
            cur_top = estimate_system_throughput(
                self.gmi_per_gpu, self.num_gpu,
                cur.point.throughput) if cur else 0.0
            if sl != self.serving_slots and trace.best_throughput \
                    > cfg.min_gain * max(cur_top, 1e-12):
                gain = trace.best_throughput / max(cur_top, 1e-12)
                move = (f"measured serving optimum (slots={sl}) "
                        f"projects {gain:.2f}x")
                reason = f"{reason}; {move}" if reason else move
                slots = sl

        if reason is None:
            return None
        layout_changed = (serving != self.serving_gpus
                          or slots != self.serving_slots
                          or prefill != self.prefill_gpus)
        decision = Decision(num_env=self.num_env,
                            gmi_per_gpu=self.gmi_per_gpu,
                            serving_gpus=serving,
                            reason=reason, slots=slots,
                            prefill_gpus=prefill if disagg else None,
                            layout_changed=layout_changed,
                            seq=self.plan_seq)
        self.serving_gpus = serving
        self.serving_slots = slots
        self.prefill_gpus = prefill
        self.decisions.append(decision)
        return decision

    def _serving_profile(self):
        """The measured serving table as an ``explore`` profile callable
        (slots stand in for num_env; unmeasured configs not runnable)."""
        return _frozen_profile(self._serving_table)

    # -------------------------------------------------------- Algorithm 2 --
    def recorded_profile(self):
        """The live rollout table as an ``explore``-compatible profile
        callable (measured configs answer with their recorded point,
        everything else is not-runnable)."""
        return _frozen_profile(self._table)

    def _projected(self, key: Tuple[int, int]) -> float:
        rec = self._table.get(key)
        if rec is None:
            return 0.0
        return estimate_system_throughput(key[0], self.num_gpu,
                                          rec.point.throughput)

    def propose_probe(self) -> Optional[int]:
        """Next unmeasured num_env up the sweep for the current GMIperGPU
        — Algorithm 2's explore step, taken online when the measured
        ladder cannot yet support a saturation estimate.  Once a measured
        point above the current config has turned DOWN (throughput no
        better than here), the ladder is saturated and probing stops."""
        measured = {ne: rec.point
                    for (gpg, ne), rec in self._table.items()
                    if gpg == self.gmi_per_gpu}
        cur = measured.get(self.num_env)
        if cur is not None and any(
                ne > self.num_env and p.throughput <= cur.throughput
                for ne, p in measured.items()):
            return None
        for ne in sorted(self.cfg.num_env_sweep):
            if ne > self.num_env and ne not in measured:
                return ne
        return None

    def _decide(self, occ: float, spills: int) -> Optional[Decision]:
        cfg = self.cfg
        # 1. serving:training split from ring pressure (arXiv:2012.04210:
        #    the right split is workload-dependent — re-measure, don't
        #    hard-code).  Spills are the overflow signal; a ring merely
        #    filled to 1.0 once per round is healthy.
        serving = self.serving_gpus
        split_reason = None
        if spills > 0 and serving > 1:
            serving -= 1
            split_reason = (f"ring pressure (spills={spills}, "
                            f"occ={occ:.2f}): +1 training GPU")
        elif occ <= cfg.occ_low and spills == 0 \
                and serving < self.num_gpu - 1:
            serving += 1
            split_reason = (f"trainer starvation (occ={occ:.2f}): "
                            "+1 serving GPU")

        # 2. (num_env, gmi_per_gpu) from explore over the measured table
        keys = sorted(self._table)
        gpg_range = sorted({k[0] for k in keys}, reverse=True)
        ne_sweep = sorted({k[1] for k in keys})
        best_key, best_top = None, 0.0
        if keys:
            trace = explore(self.recorded_profile(), "live", self.num_gpu,
                            alpha=cfg.alpha, gmi_per_gpu_range=gpg_range,
                            num_env_sweep=ne_sweep)
            ne, gpg = trace.best_config
            best_key, best_top = (gpg, ne), trace.best_throughput

        cur_key = (self.gmi_per_gpu, self.num_env)
        cur_top = self._projected(cur_key)
        reason = split_reason
        num_env, gmi_per_gpu = self.num_env, self.gmi_per_gpu
        if best_key is not None and best_key != cur_key \
                and best_top > cfg.min_gain * max(cur_top, 1e-12):
            gmi_per_gpu, num_env = best_key
            gain = best_top / max(cur_top, 1e-12)
            move = (f"measured optimum (gmi_per_gpu={gmi_per_gpu}, "
                    f"num_env={num_env}) projects {gain:.2f}x")
            reason = f"{reason}; {move}" if reason else move
        elif cfg.probe and reason is None and spills == 0:
            probe = self.propose_probe()
            if probe is not None:
                num_env = probe
                reason = (f"probe num_env={probe} (ladder unmeasured, "
                          "saturation unknown)")

        # 3. reduction strategy from measured reduce time: when the live
        #    per-round reduce measurements disagree with the current LGR
        #    choice by more than the same min_gain hysteresis, fold a
        #    strategy switch into the re-plan (Table-2 cost model —
        #    calibrated per-axis bandwidths once the fit is conditioned,
        #    the static defaults until then — scaled by the measured/
        #    modelled ratio; see Communicator).  While feasible
        #    candidates remain unmeasured, propose an in-place probe of
        #    one instead (the communication analogue of the num_env
        #    ladder walk above).
        reduction_strategy = None
        if self.communicator is not None:
            comm = self.communicator
            switch = comm.propose_switch(cfg.min_gain)
            if switch is not None:
                reduction_strategy = switch
                basis = "calibrated Table-2 bandwidths" \
                    if getattr(comm, "calibrated", False) \
                    else "default Table-2 bandwidths"
                note = (f"measured reduce time favors {switch} over "
                        f"{comm.strategy} (> {cfg.min_gain:.2f}x, "
                        f"{basis})")
                reason = f"{reason}; {note}" if reason else note
            elif cfg.probe and reason is None:
                probe_strategy = comm.propose_probe() \
                    if hasattr(comm, "propose_probe") else None
                if probe_strategy is not None:
                    reduction_strategy = probe_strategy
                    reason = (f"probe reduction strategy {probe_strategy} "
                              "(unmeasured by the bandwidth calibration)")

        if reason is None:
            return None
        layout_changed = (serving != self.serving_gpus
                          or num_env != self.num_env
                          or gmi_per_gpu != self.gmi_per_gpu)
        decision = Decision(num_env=num_env, gmi_per_gpu=gmi_per_gpu,
                            serving_gpus=serving,
                            reason=reason,
                            reduction_strategy=reduction_strategy,
                            layout_changed=layout_changed,
                            seq=self.plan_seq)
        self.num_env = num_env
        self.gmi_per_gpu = gmi_per_gpu
        self.serving_gpus = serving
        self.decisions.append(decision)
        return decision

    # ------------------------------------------------------- persistence --
    def state_dict(self) -> dict:
        """The controller's learned state as a JSON-serializable dict —
        the measured rollout/serving tables plus the committed knobs —
        for the checkpoint manifest (``AsyncRunner.checkpoint``).  Losing
        these to a preemption would restart Algorithm 2's online search
        from scratch; the epoch-in-progress sample buffers are cheap and
        deliberately not persisted."""

        def dump(table):
            return [[k[0], k[1], rec.point.throughput, rec.point.memory,
                     rec.epochs] for k, rec in sorted(table.items())]

        return {"num_gpu": self.num_gpu,
                "serving_gpus": self.serving_gpus,
                "gmi_per_gpu": self.gmi_per_gpu,
                "num_env": self.num_env,
                "serving_slots": self.serving_slots,
                "prefill_gpus": self.prefill_gpus,
                "plan_seq": self.plan_seq,
                "table": dump(self._table),
                "serving_table": dump(self._serving_table)}

    def load_state_dict(self, state: dict) -> None:
        def parse(rows):
            return {(int(a), int(b)):
                    _Recorded(ProfilePoint(True, float(top), float(mem)),
                              int(epochs))
                    for a, b, top, mem, epochs in rows}

        self.num_gpu = int(state["num_gpu"])
        self.serving_gpus = int(state["serving_gpus"])
        self.gmi_per_gpu = int(state["gmi_per_gpu"])
        self.num_env = int(state["num_env"])
        self.serving_slots = int(state.get("serving_slots", 0))
        self.prefill_gpus = int(state.get("prefill_gpus", 0))
        self.plan_seq = int(state.get("plan_seq", 0))
        self._table = parse(state.get("table", []))
        self._serving_table = parse(state.get("serving_table", []))
        self._epoch = []
        self._serving_epoch = []

    # ----------------------------------------------------------- layouts --
    def plan_layout(self, devices=None, devices_per_gpu=None):
        """Materialize the current decision state as an async placement
        (serving GPUs vs training GPUs, gmi_per_gpu instances each)."""
        from repro.core.placement import plan_async
        return plan_async(self.num_gpu, self.serving_gpus, self.gmi_per_gpu,
                          devices=devices, devices_per_gpu=devices_per_gpu)

    def summary(self) -> str:
        lines = [f"OnlineGMIController(num_gpu={self.num_gpu}, "
                 f"serving={self.serving_gpus}, "
                 f"gmi_per_gpu={self.gmi_per_gpu}, "
                 f"num_env={self.num_env}, "
                 f"measured={len(self._table)} configs, "
                 f"replans={len(self.decisions)})"]
        if self.communicator is not None:
            lines.append(f"  comm: {self.communicator!r}")
        for (gpg, ne), rec in sorted(self._table.items()):
            lines.append(f"  (gpg={gpg}, ne={ne}): "
                         f"top/inst={rec.point.throughput:.0f}/s "
                         f"mem={rec.point.memory:.2e}B "
                         f"epochs={rec.epochs}")
        for (gpg, sl), rec in sorted(self._serving_table.items()):
            lines.append(f"  serving (gpg={gpg}, slots={sl}): "
                         f"tok/inst={rec.point.throughput:.0f}/s "
                         f"mem={rec.point.memory:.2e}B "
                         f"epochs={rec.epochs}")
        return "\n".join(lines)
