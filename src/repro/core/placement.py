"""Task-aware GMI mapping (paper §5.1) + communication strategy selection
(paper Algorithm 1).

Layout templates:
* TCG   — serving block: simulator + agent colocated per GMI (COM = 0).
* TDG   — dedicated GMIs per task (baseline the paper argues against).
* TCG_EX— holistic training GMI: simulator + agent + trainer colocated;
          only cross-GMI traffic is gradient reduction.
* TDG_EX— dedicated trainer GMIs fed by serving GMIs.
* async — decoupled serving-GPU set and training-GPU set (§5.1, Fig 6b).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

# Algorithm 1 now lives in the comm subsystem; re-exported here because
# this module was its historical home and core/__init__ + callers import
# it from placement.
from repro.comm.select import select_reduction_strategy  # noqa: F401
from repro.core.gmi import GMIManager


# ------------------------------------------------------------- templates ---
@dataclass
class Layout:
    name: str
    manager: GMIManager
    serving_gmis: List[int]
    trainer_gmis: List[int]

    @property
    def mpl(self):
        """Trainer-GMI placement list; ``[]`` for serving-only layouts
        (no trainers anywhere — callers must not infer a reduction)."""
        return self.manager.gmi_to_gpu_mapping("trainer") or \
            self.manager.gmi_to_gpu_mapping("holistic")

    def reduction_strategy(self, cost_model=None) -> Optional[str]:
        """Algorithm 1 over this layout's trainer GMIs (Table-2
        cost-scored when a ``ReduceCostModel`` is supplied); ``None`` for
        a serving-only layout — there is no gradient reduction to
        select."""
        mpl = self.mpl
        if not mpl:
            return None
        return select_reduction_strategy(mpl, cost_model)

    def communicator(self, cost_model=None, *, average: bool = True,
                     with_mesh: bool = False):
        """This layout's :class:`repro.comm.Communicator` (``None`` for a
        serving-only layout)."""
        from repro.comm.api import Communicator
        return Communicator.from_layout(self, cost_model=cost_model,
                                        average=average,
                                        with_mesh=with_mesh)


def plan_tcg_serving(num_gpus: int, gmis_per_gpu: int,
                     devices=None, devices_per_gpu=None) -> Layout:
    """DRL serving: each GMI runs simulator+agent sequentially (TCG)."""
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    serving = []
    for gpu in range(num_gpus):
        for _ in range(gmis_per_gpu):
            mgr.add_gmi(gid, "serving", 1.0 / gmis_per_gpu)
            mgr.set_gpu(gid, gpu)
            serving.append(gid)
            gid += 1
    return Layout("tcg_serving", mgr, serving, [])


def plan_tdg_serving(num_gpus: int, pairs_per_gpu: int,
                     devices=None, devices_per_gpu=None) -> Layout:
    """Baseline: dedicated simulator GMIs and agent GMIs (TDG)."""
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    serving = []
    # paper §5.1: Rs ≈ 10 Ra -> simulator gets the big slice
    sim_frac = 0.8 / pairs_per_gpu
    agent_frac = 0.2 / pairs_per_gpu
    for gpu in range(num_gpus):
        for _ in range(pairs_per_gpu):
            mgr.add_gmi(gid, "simulator", sim_frac)
            mgr.set_gpu(gid, gpu)
            serving.append(gid)
            gid += 1
            mgr.add_gmi(gid, "agent", agent_frac)
            mgr.set_gpu(gid, gpu)
            serving.append(gid)
            gid += 1
    return Layout("tdg_serving", mgr, serving, [])


def plan_tcg_ex_training(num_gpus: int, gmis_per_gpu: int,
                         devices=None, devices_per_gpu=None) -> Layout:
    """Sync training: holistic GMIs (sim+agent+trainer), grad-sync only."""
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    trainers = []
    for gpu in range(num_gpus):
        for _ in range(gmis_per_gpu):
            mgr.add_gmi(gid, "holistic", 1.0 / gmis_per_gpu)
            mgr.set_gpu(gid, gpu)
            trainers.append(gid)
            gid += 1
    return Layout("tcg_ex", mgr, trainers, trainers)


def plan_tdg_ex_training(num_gpus: int, serving_per_gpu: int,
                         trainers_per_gpu: int,
                         devices=None, devices_per_gpu=None) -> Layout:
    """Baseline: dedicated serving GMIs + dedicated trainer GMIs."""
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    serving, trainers = [], []
    s_frac = 0.7 / serving_per_gpu
    t_frac = 0.3 / trainers_per_gpu
    for gpu in range(num_gpus):
        for _ in range(serving_per_gpu):
            mgr.add_gmi(gid, "serving", s_frac)
            mgr.set_gpu(gid, gpu)
            serving.append(gid)
            gid += 1
        for _ in range(trainers_per_gpu):
            mgr.add_gmi(gid, "trainer", t_frac)
            mgr.set_gpu(gid, gpu)
            trainers.append(gid)
            gid += 1
    return Layout("tdg_ex", mgr, serving, trainers)


def plan_async(num_gpus: int, serving_gpus: int, gmis_per_gpu: int,
               devices=None, devices_per_gpu=None) -> Layout:
    """Async (A3C): serving GMIs grouped on one GPU set, trainer GMIs on the
    other (Fig 6b); experience flows over the channel pipeline (§4.2)."""
    if serving_gpus >= num_gpus:
        raise ValueError("need at least one training GPU")
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    serving, trainers = [], []
    for gpu in range(num_gpus):
        role = "serving" if gpu < serving_gpus else "trainer"
        for _ in range(gmis_per_gpu):
            mgr.add_gmi(gid, role, 1.0 / gmis_per_gpu)
            mgr.set_gpu(gid, gpu)
            (serving if role == "serving" else trainers).append(gid)
            gid += 1
    return Layout("async", mgr, serving, trainers)
