"""Task-aware GMI mapping (paper §5.1) + communication strategy selection
(paper Algorithm 1).

Layout templates:
* TCG   — serving block: simulator + agent colocated per GMI (COM = 0).
* TDG   — dedicated GMIs per task (baseline the paper argues against).
* TCG_EX— holistic training GMI: simulator + agent + trainer colocated;
          only cross-GMI traffic is gradient reduction.
* TDG_EX— dedicated trainer GMIs fed by serving GMIs.
* async — decoupled serving-GPU set and training-GPU set (§5.1, Fig 6b).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.gmi import GMIManager


# ----------------------------------------------------------- Algorithm 1 ---
def select_reduction_strategy(mpl: List[List[int]]) -> str:
    """Paper Algorithm 1, verbatim logic.

    mpl[g] = list of (trainer) GMI ids on GPU g.
    Returns one of "mpr" | "mrr" | "har".
    """
    if not mpl or not any(mpl):
        # no trainer GMIs at all: there is no gradient to reduce, and
        # answering "mpr" would let a serving-only layout silently wire
        # up a reduction schedule
        raise ValueError(
            "empty MPL — a layout with no trainer GMIs has no reduction "
            "strategy")
    gmi_per_gpu = set()
    # all GMIs on the same GPU -> plain multi-process reduction
    if len(mpl) <= 1:
        return "mpr"
    for gmi_li in mpl:
        gmi_per_gpu.add(len(gmi_li))
    # different GPUs host different numbers of GMIs
    if len(gmi_per_gpu) > 1:
        return "har"
    # more GMIs per GPU than GPUs: MRR's final ring would need >1 endpoint
    # on one GPU ("multiple CUDA streams error" in NCCL; one ICI ring
    # endpoint per chip here)
    if gmi_per_gpu.pop() > len(mpl):
        return "har"
    return "mrr"


# ------------------------------------------------------------- templates ---
@dataclass
class Layout:
    name: str
    manager: GMIManager
    serving_gmis: List[int]
    trainer_gmis: List[int]

    @property
    def mpl(self):
        """Trainer-GMI placement list; ``[]`` for serving-only layouts
        (no trainers anywhere — callers must not infer a reduction)."""
        return self.manager.gmi_to_gpu_mapping("trainer") or \
            self.manager.gmi_to_gpu_mapping("holistic")

    def reduction_strategy(self) -> Optional[str]:
        """Algorithm 1 over this layout's trainer GMIs; ``None`` for a
        serving-only layout — there is no gradient reduction to select."""
        mpl = self.mpl
        if not mpl:
            return None
        return select_reduction_strategy(mpl)


def plan_tcg_serving(num_gpus: int, gmis_per_gpu: int,
                     devices=None, devices_per_gpu=None) -> Layout:
    """DRL serving: each GMI runs simulator+agent sequentially (TCG)."""
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    serving = []
    for gpu in range(num_gpus):
        for _ in range(gmis_per_gpu):
            mgr.add_gmi(gid, "serving", 1.0 / gmis_per_gpu)
            mgr.set_gpu(gid, gpu)
            serving.append(gid)
            gid += 1
    return Layout("tcg_serving", mgr, serving, [])


def plan_tdg_serving(num_gpus: int, pairs_per_gpu: int,
                     devices=None, devices_per_gpu=None) -> Layout:
    """Baseline: dedicated simulator GMIs and agent GMIs (TDG)."""
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    serving = []
    # paper §5.1: Rs ≈ 10 Ra -> simulator gets the big slice
    sim_frac = 0.8 / pairs_per_gpu
    agent_frac = 0.2 / pairs_per_gpu
    for gpu in range(num_gpus):
        for _ in range(pairs_per_gpu):
            mgr.add_gmi(gid, "simulator", sim_frac)
            mgr.set_gpu(gid, gpu)
            serving.append(gid)
            gid += 1
            mgr.add_gmi(gid, "agent", agent_frac)
            mgr.set_gpu(gid, gpu)
            serving.append(gid)
            gid += 1
    return Layout("tdg_serving", mgr, serving, [])


def plan_tcg_ex_training(num_gpus: int, gmis_per_gpu: int,
                         devices=None, devices_per_gpu=None) -> Layout:
    """Sync training: holistic GMIs (sim+agent+trainer), grad-sync only."""
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    trainers = []
    for gpu in range(num_gpus):
        for _ in range(gmis_per_gpu):
            mgr.add_gmi(gid, "holistic", 1.0 / gmis_per_gpu)
            mgr.set_gpu(gid, gpu)
            trainers.append(gid)
            gid += 1
    return Layout("tcg_ex", mgr, trainers, trainers)


def plan_tdg_ex_training(num_gpus: int, serving_per_gpu: int,
                         trainers_per_gpu: int,
                         devices=None, devices_per_gpu=None) -> Layout:
    """Baseline: dedicated serving GMIs + dedicated trainer GMIs."""
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    serving, trainers = [], []
    s_frac = 0.7 / serving_per_gpu
    t_frac = 0.3 / trainers_per_gpu
    for gpu in range(num_gpus):
        for _ in range(serving_per_gpu):
            mgr.add_gmi(gid, "serving", s_frac)
            mgr.set_gpu(gid, gpu)
            serving.append(gid)
            gid += 1
        for _ in range(trainers_per_gpu):
            mgr.add_gmi(gid, "trainer", t_frac)
            mgr.set_gpu(gid, gpu)
            trainers.append(gid)
            gid += 1
    return Layout("tdg_ex", mgr, serving, trainers)


def plan_async(num_gpus: int, serving_gpus: int, gmis_per_gpu: int,
               devices=None, devices_per_gpu=None) -> Layout:
    """Async (A3C): serving GMIs grouped on one GPU set, trainer GMIs on the
    other (Fig 6b); experience flows over the channel pipeline (§4.2)."""
    if serving_gpus >= num_gpus:
        raise ValueError("need at least one training GPU")
    mgr = GMIManager(devices, devices_per_gpu)
    gid = 0
    serving, trainers = [], []
    for gpu in range(num_gpus):
        role = "serving" if gpu < serving_gpus else "trainer"
        for _ in range(gmis_per_gpu):
            mgr.add_gmi(gid, role, 1.0 / gmis_per_gpu)
            mgr.set_gpu(gid, gpu)
            (serving if role == "serving" else trainers).append(gid)
            gid += 1
    return Layout("async", mgr, serving, trainers)
