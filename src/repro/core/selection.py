"""Workload-aware GMI selection — profiling-based exploration (Algorithm 2).

Searches (GMIperGPU, num_env) to maximize projected system throughput,
pruning with the saturation metric Sat = ΔTOP/ΔMem < alpha.  The profile
function is pluggable: the real one times a PPO/serving iteration on this
host; benchmarks may inject analytic or recorded profiles.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

logger = logging.getLogger(__name__)


@dataclass
class ProfilePoint:
    runnable: bool
    throughput: float     # env-steps / second
    memory: float         # bytes (or model-relative units)


@dataclass
class SearchTrace:
    points: List[Tuple[int, int, ProfilePoint, float]]  # (gpg, ne, prof, sat)
    best_config: Tuple[int, int]
    best_throughput: float


NUM_ENV_SWEEP = (128, 256, 512, 1024, 2048, 4096, 8192, 16384)


def estimate_system_throughput(gmi_per_gpu: int, num_gpu: int,
                               top: float) -> float:
    """Line 20: project one instance's throughput to the whole system.

    Scaling is sub-linear in instances per GPU (shared HBM bandwidth):
    the paper's estimate() uses measured per-GMI throughput x instance
    count with a contention discount.
    """
    contention = 1.0 - 0.05 * (gmi_per_gpu - 1)
    return top * gmi_per_gpu * max(contention, 0.5) * num_gpu


def explore(profile: Callable[[str, int, int], ProfilePoint],
            drl_bench: str, num_gpu: int, *, alpha: float = 0.1,
            gmi_per_gpu_range=range(10, 0, -1),
            num_env_sweep=NUM_ENV_SWEEP) -> SearchTrace:
    """Algorithm 2, faithful to the pseudocode (incl. early-stop rules)."""
    best_config: Optional[Tuple[int, int]] = None
    max_top = float("-inf")
    trace: List[Tuple[int, int, ProfilePoint, float]] = []

    for gmi_per_gpu in gmi_per_gpu_range:
        pre_top = 0.0
        pre_mem = 0.0
        for num_env in num_env_sweep:
            prof = profile(drl_bench, gmi_per_gpu, num_env)
            if not prof.runnable:                      # line 6-8
                continue
            if pre_top == 0.0 and pre_mem == 0.0:      # line 9-12
                pre_top, pre_mem = prof.throughput, prof.memory
                trace.append((gmi_per_gpu, num_env, prof, float("inf")))
                # robustness beyond the paper's pseudocode: the first
                # runnable point is also a candidate (otherwise a space
                # with a single runnable config returns nothing)
                acc_top = estimate_system_throughput(gmi_per_gpu, num_gpu,
                                                     prof.throughput)
                if acc_top > max_top:
                    max_top = acc_top
                    best_config = (num_env, gmi_per_gpu)
                continue
            r_top = (prof.throughput - pre_top) / pre_top     # line 13
            r_mem = (prof.memory - pre_mem) / max(pre_mem, 1e-9)
            if r_mem <= 0.0:
                # The paper's Sat = ΔTOP/ΔMem assumes memory grows with
                # num_env.  When it is flat or shrinks (allocator slack,
                # recorded online profiles), the ratio is meaningless —
                # clamping the denominator exploded it to ±1e9·r_top,
                # either never pruning or aborting the sweep spuriously.
                # A throughput gain at no memory cost must never prune;
                # no gain at no cost means the sweep is saturated.
                sat = float("inf") if r_top > 0.0 else float("-inf")
            else:
                sat = r_top / r_mem                           # line 15
            pre_top, pre_mem = prof.throughput, prof.memory
            trace.append((gmi_per_gpu, num_env, prof, sat))
            if sat < alpha:                             # line 17-19
                break
            acc_top = estimate_system_throughput(gmi_per_gpu, num_gpu,
                                                 prof.throughput)
            if acc_top > max_top:                       # line 21-24
                max_top = acc_top
                best_config = (num_env, gmi_per_gpu)

    if best_config is None:
        raise RuntimeError("no runnable configuration found")
    return SearchTrace(trace, best_config, max_top)


# ------------------------------------------------------- real profiler -----
_RESOURCE_MARKERS = ("RESOURCE_EXHAUSTED", "RESOURCE EXHAUSTED",
                     "OUT_OF_MEMORY", "out of memory", "Out of memory",
                     "OOM ", "failed to allocate")


def is_resource_exhausted(err: BaseException) -> bool:
    """Only allocator/OOM-type failures count as Alg. 2 'not runnable';
    anything else (shape bugs, NaN guards) is a genuine error."""
    if isinstance(err, MemoryError):
        return True
    return any(m in str(err) for m in _RESOURCE_MARKERS)


def make_ppo_profiler(iters: int = 3, mem_budget_bytes: float = 32e9):
    """Times actual PPO iterations on this host.  GMIperGPU scales the
    simulated per-instance resource slice by shrinking num_env headroom
    (1/GMIperGPU of the device), mirroring MPS percentage caps."""
    import jax
    from repro.envs import make_env
    from repro.rl import ppo

    def profile(bench: str, gmi_per_gpu: int, num_env: int) -> ProfilePoint:
        env = make_env(bench)
        eff_env = num_env // gmi_per_gpu
        if eff_env < 8:
            return ProfilePoint(False, 0.0, 0.0)
        spec = env.spec
        # memory model: obs/action/reward rollouts + policy + physics state
        bytes_per_env = 4 * (spec.obs_dim * 2 + spec.act_dim * 2 + 8) * 32
        mem = bytes_per_env * eff_env + 4e6
        if mem > mem_budget_bytes / gmi_per_gpu:
            return ProfilePoint(False, 0.0, mem)
        try:
            cfg = ppo.PPOConfig(num_steps=8, num_epochs=1, num_minibatches=1)
            params, opt, est, obs = ppo.init_train(
                jax.random.key(0), env, spec.policy_dims, num_envs=eff_env)
            step = ppo.make_train_step(env, cfg)
            k = jax.random.PRNGKey(0)
            params, opt, est, obs, k, m = step(params, opt, est, obs, k)
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for _ in range(iters):
                params, opt, est, obs, k, m = step(params, opt, est, obs, k)
            jax.block_until_ready(m["loss"])
            dt = (time.perf_counter() - t0) / iters
            top = cfg.num_steps * eff_env / dt
            return ProfilePoint(True, top, mem)
        except Exception as e:
            # resource exhaustion is the ONE failure Algorithm 2 expects
            # (config too big for the GMI slice -> not runnable); a bare
            # except here used to swallow genuine bugs as "not runnable"
            if is_resource_exhausted(e):
                return ProfilePoint(False, 0.0, mem)
            logger.exception(
                "profiler failed on (%s, gmi_per_gpu=%d, num_env=%d) with a "
                "non-resource error — surfacing it", bench, gmi_per_gpu,
                num_env)
            raise

    return profile
