# The paper's primary contribution: GPU/TPU spatial multiplexing for DRL.
# gmi.py        — instance abstraction + manager (paper §3)
# placement.py  — task-aware GMI mapping templates (§5.1); Algorithm 1
#                 lives in repro.comm.select and is re-exported here
# channels.py   — channel-based experience sharing MCC (§4.2)
# selection.py  — workload-aware GMI selection, Algorithm 2 (§5.2)
# controller.py — online GMI management, the runtime half of Alg. 2 (§5.2)
# cost_model.py — analytical models, Tables 2/4/5 (+3-level HAR), Eqs. 1-3
from repro.core import (channels, controller, cost_model, gmi,  # noqa: F401
                        placement, selection)
from repro.core.controller import (ControllerConfig,  # noqa: F401
                                   OnlineGMIController)
from repro.core.gmi import DRLRole, GMI, GMIManager  # noqa: F401
from repro.core.placement import select_reduction_strategy  # noqa: F401
