"""Channel-based experience sharing — MCC (paper §4.2).

Four services connect agent instances to trainer instances in async DRL:

* Dispenser (per agent)  — categorizes experience into per-field channels
  (state / action / reward / done / bootstrap) at collection granularity.
* Compressor (system)    — concatenates per-channel payloads across agents
  to raise transfer granularity (bandwidth-friendly large moves).
* Migrator (system)      — routes channel payloads to trainers: direct
  forward when agent and trainer share a device group; gather-then-least-
  loaded distribution otherwise.
* Batcher (per trainer)  — slices (small-batch, high update frequency) or
  stacks (large-batch, noise reduction) into training batches.

The uni-channel (UCC) baseline ships whole experience tuples one by one —
the comparison of Table 8.  Both paths count transfers and bytes so the
benchmark can report transfer efficiency.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.rl.a3c import Experience

CHANNELS = ("obs", "actions", "rewards", "dones", "bootstrap",
            "actor_version")


@dataclass
class TransferStats:
    num_transfers: int = 0
    total_bytes: int = 0
    ops: int = 0

    def record(self, tree):
        leaves = jax.tree.leaves(tree)
        self.num_transfers += 1
        self.ops += len(leaves)
        self.total_bytes += sum(
            int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)

    @property
    def bytes_per_transfer(self) -> float:
        return self.total_bytes / max(self.num_transfers, 1)


# ---------------------------------------------------------------- services -
class Dispenser:
    """Per-agent: split experience into typed channels (§4.2 first svc)."""

    def __init__(self, agent_gmi: int):
        self.agent_gmi = agent_gmi
        self.out: Dict[str, List] = {c: [] for c in CHANNELS}

    def push(self, exp: Experience):
        for c in CHANNELS:
            self.out[c].append(getattr(exp, c))

    def drain(self) -> Dict[str, List]:
        out, self.out = self.out, {c: [] for c in CHANNELS}
        return out


class Compressor:
    """System-wide: batch channel payloads into large transfers."""

    def __init__(self, min_batch: int = 1):
        self.min_batch = min_batch
        self.stats = TransferStats()

    def compress(self, per_agent: Sequence[Dict[str, List]]) \
            -> Dict[str, jax.Array]:
        merged: Dict[str, jax.Array] = {}
        for c in CHANNELS:
            items = [x for d in per_agent for x in d[c]]
            if not items:
                continue
            arrs = [jnp.asarray(x) for x in items]
            if arrs[0].ndim == 0:
                merged[c] = jnp.stack(arrs)
            else:
                # concat along the env axis (axis 1 for (T,N,...) payloads,
                # axis 0 for (N,) bootstraps)
                axis = 1 if arrs[0].ndim >= 2 else 0
                merged[c] = jnp.concatenate(arrs, axis=axis)
            self.stats.record(merged[c])      # ONE transfer per channel
        return merged


class Migrator:
    """System-wide: route compressed channels to trainer instances."""

    def __init__(self, trainer_gmis: Sequence[int],
                 gmi_gpu: Optional[Dict[int, int]] = None):
        self.trainer_gmis = list(trainer_gmis)
        self.gmi_gpu = gmi_gpu or {}
        self.load = {t: 0 for t in self.trainer_gmis}

    def route(self, channels: Dict[str, jax.Array],
              agent_gpu: Optional[int] = None) -> int:
        """Pick the destination trainer: same-GPU direct forward if any,
        otherwise least-loaded (paper §4.2 migrator policy)."""
        same = [t for t in self.trainer_gmis
                if agent_gpu is not None
                and self.gmi_gpu.get(t) == agent_gpu]
        pool = same or self.trainer_gmis
        dst = min(pool, key=lambda t: self.load[t])
        n = channels["rewards"].shape[1] if "rewards" in channels else 1
        self.load[dst] += int(n)
        return dst


class Batcher:
    """Per-trainer: slice or stack into training batches."""

    def __init__(self, mode: str = "stack", batch_envs: Optional[int] = None):
        assert mode in ("stack", "slice")
        self.mode = mode
        self.batch_envs = batch_envs

    def prepare(self, channels: Dict[str, jax.Array]) -> List[Experience]:
        exp = Experience(
            obs=channels["obs"], actions=channels["actions"],
            rewards=channels["rewards"], dones=channels["dones"],
            bootstrap=channels["bootstrap"],
            actor_version=jnp.max(channels["actor_version"])
            if channels["actor_version"].ndim else channels["actor_version"])
        if self.mode == "stack" or self.batch_envs is None:
            return [exp]
        N = exp.rewards.shape[1]
        b = self.batch_envs
        out = []
        for s in range(0, N, b):
            sl = slice(s, min(s + b, N))
            out.append(Experience(
                obs=exp.obs[:, sl], actions=exp.actions[:, sl],
                rewards=exp.rewards[:, sl], dones=exp.dones[:, sl],
                bootstrap=exp.bootstrap[sl],
                actor_version=exp.actor_version))
        return out


# ---------------------------------------------------------------- pipelines -
class MultiChannelPipeline:
    """Dispenser -> Compressor -> Migrator -> Batcher (the paper's MCC)."""

    def __init__(self, agent_gmis: Sequence[int], trainer_gmis: Sequence[int],
                 gmi_gpu: Optional[Dict[int, int]] = None,
                 batch_mode: str = "stack",
                 batch_envs: Optional[int] = None):
        self.dispensers = {a: Dispenser(a) for a in agent_gmis}
        self.compressor = Compressor()
        self.migrator = Migrator(trainer_gmis, gmi_gpu)
        self.batchers = {t: Batcher(batch_mode, batch_envs)
                         for t in trainer_gmis}

    def push(self, agent_gmi: int, exp: Experience):
        self.dispensers[agent_gmi].push(exp)

    def flush(self) -> Dict[int, List[Experience]]:
        """Move everything agents produced to trainer batches."""
        per_agent = [d.drain() for d in self.dispensers.values()]
        per_agent = [d for d in per_agent if any(d[c] for c in CHANNELS)]
        if not per_agent:
            return {}
        channels = self.compressor.compress(per_agent)
        dst = self.migrator.route(channels)
        return {dst: self.batchers[dst].prepare(channels)}

    @property
    def stats(self) -> TransferStats:
        return self.compressor.stats


class UniChannelPipeline:
    """UCC baseline: every experience tuple is its own fine-grained
    transfer (one op per field per agent per round — Table 8's loser)."""

    def __init__(self, trainer_gmis: Sequence[int]):
        self.trainer_gmis = list(trainer_gmis)
        self.stats = TransferStats()
        self._rr = 0

    def send(self, exp: Experience) -> int:
        for c in CHANNELS:
            self.stats.record(getattr(exp, c))  # one transfer PER FIELD
        dst = self.trainer_gmis[self._rr % len(self.trainer_gmis)]
        self._rr += 1
        return dst
