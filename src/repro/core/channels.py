"""Channel-based experience sharing — MCC (paper §4.2), device-resident.

Four services connect agent instances to trainer instances in async DRL:

* Dispenser (per agent)  — categorizes experience into per-field channels
  (state / action / reward / done / bootstrap) at collection granularity.
* Compressor (system)    — raises transfer granularity by batching channel
  payloads across agents into large contiguous moves.
* Migrator (system)      — routes channel payloads to trainers: direct
  forward when agent and trainer share a device group; least-loaded
  distribution otherwise.
* Batcher (per trainer)  — slices (small-batch, high update frequency) or
  stacks (large-batch, noise reduction) into training batches.

Ring-buffer design
------------------
The seed implementation staged every push through host-side Python lists
and re-materialized each channel with ``jnp.asarray`` + ``jnp.concatenate``
on every flush — O(agents x channels) host round-trips, exactly the
fine-grained-transfer pathology the paper (and arXiv:2012.04210) blames
for DRL throughput collapse.  The pipeline is now device-resident end to
end:

* Each agent *group* (agents sharing a GPU per ``gmi_gpu``; all agents
  when no placement is given) owns a :class:`ChannelRing` — preallocated
  per-channel device buffers with capacity ``slots x T x N`` samples
  (``slots`` = agents in the group), laid out so push ``s`` occupies the
  slot-aligned column block ``[s*N, (s+1)*N)``.
* ``push`` writes the agent's whole (T, N, ...) block in place via the
  Pallas ``pack_channels`` kernel (one launch packs all six channels; ring
  buffers are donated/aliased).  Off-TPU the identical program lowers
  through a jitted, donated XLA ``dynamic_update_slice`` — still one
  dispatch per push, still in place.
* ``flush`` is a pointer bump: a full ring hands its buffers to the
  consumer zero-copy and restarts on fresh storage; a partial ring hands
  out one contiguous device slice per channel (two on wraparound).  No
  host staging anywhere.
* The Migrator routes **per agent group** (the fix for the seed behavior
  of shipping every flush to a single trainer): same-GPU groups forward
  directly to their co-located trainer, the rest spread least-loaded, so
  ``trainer_gmis`` balance within one flush instead of idling in turns.

Double-buffered overlap (paper §4.1)
------------------------------------
With ``overlap=True`` each ring alternates storage *generations*:
pushes stage device-resident payload references (no device work, no
donation — the producer can never stall behind a trainer still reading
the previous flush) and ``flush`` becomes a buffer *swap* instead of a
barrier — the back generation is bulk-packed in one fused dispatch
(``pack_generation``) and parked one round, while what is handed to the
trainers is the *previous* swap: arrays that had a whole serving round
of wall-clock to materialize.  Serving GMIs keep staging into the front
generation while trainer GMIs consume the back one, the
producer/consumer overlap that WarpDrive (arXiv:2108.13976) shows
end-to-end on-device RL lives or dies on.  The spill-not-drop guarantee
survives the swap: ring-overflow spills are delivered in push order,
ahead of the swap they preceded, and a final
:meth:`MultiChannelPipeline.drain` empties both generations — zero
lost, zero duplicated samples under any interleaved push/flush
schedule.

``TransferStats`` counts one transfer per channel per routed group —
physically separate moves are counted separately.  On a single-group
layout (no placement map; the Table-8 benchmark configuration) this
degenerates to exactly the seed accounting — one transfer per channel
per flush at full cross-agent size — so comparisons against the UCC
baseline (``UniChannelPipeline``, untouched, still the loser) remain
apples-to-apples; multi-GPU layouts report the real per-trainer
granularity instead.  The seed host-staging path survives as
:class:`HostStagedPipeline` for before/after benchmarking.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.channel_pack import (CHANNELS, alloc_rings,
                                        cache_payload_bytes,
                                        pack_cache_payload,
                                        pack_channels_fresh,
                                        pack_channels_xla,
                                        pack_generation,
                                        unpack_cache_payload)
from repro.rl.a3c import Experience


@dataclass
class TransferStats:
    num_transfers: int = 0
    total_bytes: int = 0
    ops: int = 0

    def record(self, tree):
        leaves = jax.tree.leaves(tree)
        self.num_transfers += 1
        self.ops += len(leaves)
        self.total_bytes += sum(
            int(np.prod(x.shape)) * x.dtype.itemsize for x in leaves)

    @property
    def bytes_per_transfer(self) -> float:
        # zero transfers -> 0.0, never a ZeroDivisionError
        return self.total_bytes / max(self.num_transfers, 1)


def _payloads(exp: Experience) -> Dict[str, jax.Array]:
    return {c: getattr(exp, c) for c in CHANNELS}


# ------------------------------------------------------------- ring buffer -
class ChannelRing:
    """Preallocated per-channel device ring, one slot per push.

    ``slots`` pushes of fixed (T, N, ...) shape fit before the ring wraps
    and overwrites the oldest slot.  ``snapshot`` returns the valid slots
    oldest-first as one contiguous slice per channel (two + a concat on
    the rare wrapped read) and logically empties the ring; a full
    unwrapped ring is handed out zero-copy and the next push restarts on
    fresh storage (a single fused alloc+write dispatch).

    ``double_buffered=True`` turns ``snapshot`` into a buffer swap over
    alternating storage *generations*: pushes stage device-resident
    payload references (no device work, nothing to donate, so the
    producer can never stall behind the consumer) and the swap packs the
    whole back generation in ONE fused donation-free dispatch
    (``pack_generation``) whose output the consumer owns outright, while
    the front generation keeps staging the next round.  See
    ``kernels/channel_pack`` for the measurements that ruled out the
    shared-storage and per-push-donation alternatives.
    """

    def __init__(self, slots: int, use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 double_buffered: bool = False):
        assert slots >= 1
        self.slots = int(slots)
        self.double_buffered = bool(double_buffered)
        self.use_pallas = (jax.default_backend() == "tpu") \
            if use_pallas is None else use_pallas
        self.interpret = interpret
        self.bufs: Optional[Dict[str, jax.Array]] = None
        self._staged: List[Dict[str, jax.Array]] = []   # double-buffer front
        self.head = 0          # next slot to write
        self.count = 0         # valid slots (<= slots)
        self.shape: Optional[Tuple[int, int]] = None   # (T, N)
        self._sig = None       # full per-push payload shapes

    def append(self, exp: Experience) -> None:
        pay = _payloads(exp)
        sig = tuple(tuple(pay[c].shape) for c in CHANNELS)
        if self._sig is None:
            self._sig = sig
            self.shape = pay["rewards"].shape
        elif self._sig != sig:
            raise ValueError(
                f"ring expects payload shapes {self._sig}, got {sig}")
        if self.double_buffered:
            if self.count == self.slots:   # ring semantics: evict oldest
                self._staged.pop(0)
            self._staged.append(pay)
        elif self.bufs is None:
            assert self.head == 0
            if self.use_pallas:
                self.bufs = ops.pack_channels(
                    alloc_rings(pay, self.slots), pay, jnp.int32(0),
                    interpret=self.interpret)
            else:
                self.bufs = pack_channels_fresh(pay, slots=self.slots)
        elif self.use_pallas:
            self.bufs = ops.pack_channels(self.bufs, pay,
                                          jnp.int32(self.head),
                                          interpret=self.interpret)
        else:
            self.bufs = pack_channels_xla(self.bufs, pay,
                                          jnp.int32(self.head))
        self.head = (self.head + 1) % self.slots
        self.count = min(self.count + 1, self.slots)

    # ------------------------------------------- zero-copy producer slot --
    _PRODUCED = ("obs", "actions", "rewards", "dones")

    def acquire(self, T: int, N: int, obs_dim: int, act_dim: int):
        """Hand out the ring's live producer channels plus the slot index
        for a zero-copy producer (``rl.rollout.collect_ring``): the
        megakernel rollout writes obs/action/reward/done for slot
        ``head`` directly into the returned buffers — no staged payload,
        no ``pack_channels`` re-copy.  The four arrays are DETACHED from
        the ring until :meth:`commit` reattaches them (the producer's
        jitted scan donates them).  Blocking rings only: a
        double-buffered ring's pushes already stage references, so there
        is nothing to save on its producer side."""
        if self.double_buffered:
            raise ValueError(
                "acquire/commit targets blocking rings; double-buffered "
                "rings stage payload references (use append)")
        sig = ((T, N, obs_dim), (T, N, act_dim), (T, N), (T, N), (N,), ())
        if self._sig is None:
            self._sig = sig
            self.shape = (T, N)
        elif self._sig != sig:
            raise ValueError(
                f"ring expects payload shapes {self._sig}, got {sig}")
        if self.bufs is None:
            assert self.head == 0
            S = self.slots
            self.bufs = {
                "obs": jnp.zeros((T, S * N, obs_dim), jnp.float32),
                "actions": jnp.zeros((T, S * N, act_dim), jnp.float32),
                "rewards": jnp.zeros((T, S * N), jnp.float32),
                "dones": jnp.zeros((T, S * N), jnp.float32),
                "bootstrap": jnp.zeros((S, N), jnp.float32),
                "actor_version": jnp.zeros((S, 1), jnp.int32),
            }
        out = {c: self.bufs.pop(c) for c in self._PRODUCED}
        return out, self.head

    def commit(self, bufs: Dict[str, jax.Array], bootstrap,
               actor_version) -> None:
        """Reattach the producer-written channels from :meth:`acquire`
        and finalize the slot: the bootstrap/actor_version rows land via
        two small in-place row updates, then the write pointer bumps —
        the slot becomes visible to ``snapshot`` exactly like an
        ``append``-ed push."""
        assert self.bufs is not None and self.shape is not None
        missing = [c for c in self._PRODUCED if c not in bufs]
        assert not missing, f"commit missing channels {missing}"
        self.bufs.update({c: bufs[c] for c in self._PRODUCED})
        s = self.head
        boot = jnp.asarray(bootstrap).reshape(1, -1)
        ver = jnp.asarray(actor_version, jnp.int32).reshape(1, 1)
        self.bufs["bootstrap"] = \
            self.bufs["bootstrap"].at[s:s + 1].set(boot)
        self.bufs["actor_version"] = \
            self.bufs["actor_version"].at[s:s + 1].set(ver)
        self.head = (self.head + 1) % self.slots
        self.count = min(self.count + 1, self.slots)

    def snapshot(self) -> Dict[str, jax.Array]:
        """Valid slots oldest-first as channel arrays; empties the ring.

        Double-buffered rings swap generations instead of draining in
        place: the back generation is bulk-packed in one dispatch and
        handed to the consumer; staging restarts immediately."""
        assert self.count > 0
        if self.double_buffered:
            staged, self._staged = self._staged, []
            self.head = 0
            self.count = 0
            return pack_generation(staged)

        assert self.bufs is not None
        S, (_, N) = self.slots, self.shape
        start = (self.head - self.count) % S
        bufs, count = self.bufs, self.count

        if count == S and start == 0:
            # full unwrapped ring: hand the buffers out zero-copy; the
            # next push re-allocates (consumer owns this storage now)
            self.bufs = None
            out = dict(bufs)
        else:
            def cols(buf, lo, hi):        # env-column range [lo, hi) slots
                return buf[:, lo * N:hi * N]

            def rows(buf, lo, hi):
                return buf[lo:hi]

            out = {}
            end = start + count
            for c in CHANNELS:
                take = rows if c in ("bootstrap", "actor_version") else cols
                if end <= S:
                    out[c] = take(bufs[c], start, end)
                else:                     # wrapped read: two slices
                    out[c] = jnp.concatenate(
                        [take(bufs[c], start, S), take(bufs[c], 0, end - S)],
                        axis=0 if take is rows else 1)
        self.head = 0
        self.count = 0
        out["bootstrap"] = out["bootstrap"].reshape(-1)
        out["actor_version"] = out["actor_version"].reshape(-1)
        return out



# ---------------------------------------------------------------- services -
class Dispenser:
    """Per-agent host-staged categorization (§4.2 first svc) — retained for
    the :class:`HostStagedPipeline` baseline.  In the device-resident
    pipeline the dispenser role (typed per-field split) happens inside the
    ``pack_channels`` kernel itself."""

    def __init__(self, agent_gmi: int):
        self.agent_gmi = agent_gmi
        self.out: Dict[str, List] = {c: [] for c in CHANNELS}

    def push(self, exp: Experience):
        for c in CHANNELS:
            self.out[c].append(getattr(exp, c))

    def drain(self) -> Dict[str, List]:
        out, self.out = self.out, {c: [] for c in CHANNELS}
        return out


class Compressor:
    """System-wide: batch channel payloads into large transfers.

    ``record_flush`` accounts a device-resident flush (one transfer per
    channel, sized across all groups); ``compress`` is the legacy
    host-staging path used by :class:`HostStagedPipeline`."""

    def __init__(self, min_batch: int = 1):
        self.min_batch = min_batch
        self.stats = TransferStats()

    def record_flush(self, groups: Sequence[Dict[str, jax.Array]]) -> None:
        # one transfer per channel per GROUP: groups route to different
        # trainers, so they are physically separate moves (a single-group
        # flush degenerates to the seed accounting: one per channel)
        for g in groups:
            for c in CHANNELS:
                self.stats.record(g[c])

    def compress(self, per_agent: Sequence[Dict[str, List]]) \
            -> Dict[str, jax.Array]:
        merged: Dict[str, jax.Array] = {}
        for c in CHANNELS:
            items = [x for d in per_agent for x in d[c]]
            if not items:
                continue
            arrs = [jnp.asarray(x) for x in items]
            if arrs[0].ndim == 0:
                merged[c] = jnp.stack(arrs)
            else:
                # concat along the env axis (axis 1 for (T,N,...) payloads,
                # axis 0 for (N,) bootstraps)
                axis = 1 if arrs[0].ndim >= 2 else 0
                merged[c] = jnp.concatenate(arrs, axis=axis)
            self.stats.record(merged[c])      # ONE transfer per channel
        return merged


class Migrator:
    """System-wide: route compressed channels to trainer instances."""

    def __init__(self, trainer_gmis: Sequence[int],
                 gmi_gpu: Optional[Dict[int, int]] = None):
        self.trainer_gmis = list(trainer_gmis)
        self.gmi_gpu = gmi_gpu or {}
        self.load = {t: 0 for t in self.trainer_gmis}

    def route(self, channels: Dict[str, jax.Array],
              agent_gpu: Optional[int] = None) -> int:
        """Pick the destination trainer: same-GPU direct forward if any,
        otherwise least-loaded (paper §4.2 migrator policy)."""
        same = [t for t in self.trainer_gmis
                if agent_gpu is not None
                and self.gmi_gpu.get(t) == agent_gpu]
        pool = same or self.trainer_gmis
        dst = min(pool, key=lambda t: self.load[t])
        n = channels["rewards"].shape[1] if "rewards" in channels else 1
        self.load[dst] += int(n)
        return dst


class Batcher:
    """Per-trainer: slice or stack into training batches."""

    def __init__(self, mode: str = "stack", batch_envs: Optional[int] = None):
        assert mode in ("stack", "slice")
        self.mode = mode
        self.batch_envs = batch_envs

    def prepare(self, channels: Dict[str, jax.Array]) -> List[Experience]:
        # a batch always carries ONE scalar version — the OLDEST merged
        # payload's, so downstream staleness is an upper bound for every
        # sample in the batch — whatever rank the channel arrived with
        # (0-d single push, (k,) merged pushes)
        version = jnp.min(jnp.atleast_1d(channels["actor_version"]))
        exp = Experience(
            obs=channels["obs"], actions=channels["actions"],
            rewards=channels["rewards"], dones=channels["dones"],
            bootstrap=channels["bootstrap"], actor_version=version)
        if self.mode == "stack" or self.batch_envs is None:
            return [exp]
        N = exp.rewards.shape[1]
        b = self.batch_envs
        out = []
        for s in range(0, N, b):          # ragged tail kept, never dropped
            sl = slice(s, min(s + b, N))
            out.append(Experience(
                obs=exp.obs[:, sl], actions=exp.actions[:, sl],
                rewards=exp.rewards[:, sl], dones=exp.dones[:, sl],
                bootstrap=exp.bootstrap[sl],
                actor_version=exp.actor_version))
        return out


# ---------------------------------------------------------------- pipelines -
class MultiChannelPipeline:
    """Device-resident MCC: ring-pack -> pointer-bump flush -> route ->
    batch (the paper's Dispenser/Compressor/Migrator/Batcher flow)."""

    def __init__(self, agent_gmis: Sequence[int], trainer_gmis: Sequence[int],
                 gmi_gpu: Optional[Dict[int, int]] = None,
                 batch_mode: str = "stack",
                 batch_envs: Optional[int] = None,
                 ring_slots: Optional[int] = None,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 overlap: bool = False):
        self.agent_gmis = list(agent_gmis)
        # fault-injection seam (repro.fault): called once per delivering
        # group at flush time with (group_key, channels); may answer
        # "drop" (the transfer is lost in transit — the pipeline
        # RETRANSMITS it on the next flush, so the spill-not-drop
        # guarantee survives a lossy link) or "poison" (delivered
        # corrupted — the trainer-side non-finite guard must catch it)
        self.fault_hook = None
        self.dropped_flushes = 0
        self.poisoned_flushes = 0
        self.gmi_gpu = gmi_gpu or {}
        self.compressor = Compressor()
        self.migrator = Migrator(trainer_gmis, gmi_gpu)
        self.batchers = {t: Batcher(batch_mode, batch_envs)
                         for t in trainer_gmis}
        self.ring_slots = ring_slots
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.overlap = bool(overlap)
        # agents sharing a GPU share a ring (direct-forward group); agents
        # with unknown placement share the catch-all group
        self._group_of = {a: self.gmi_gpu.get(a, -1) for a in self.agent_gmis}
        self._group_size: Dict[int, int] = {}
        for g in self._group_of.values():
            self._group_size[g] = self._group_size.get(g, 0) + 1
        self._rings: Dict[Tuple[int, Tuple], ChannelRing] = {}
        # ring-overflow spill: the pipeline is lossless even when agents
        # push more often than the consumer flushes — a full ring is
        # snapshotted (still one coarse device move per channel) before
        # the overwriting push lands
        self._pending: Dict[int, List[Dict[str, jax.Array]]] = {}
        # overlap mode: the previous flush's swapped-out buffers, parked
        # one round so trainers consume round r-1 while agents serve r
        self._inflight: List[Tuple[int, Dict[str, jax.Array]]] = []
        # controller-facing counters (occupancy is read off live rings)
        self.spill_count = 0
        self.occupancy_high_water = 0.0
        self.delivered_samples = 0
        # per-round (seconds, bytes) channel-transfer timings for the
        # bandwidth calibrator; bounded so an idle consumer can't grow it
        self._transfer_samples: List[Tuple[float, int]] = []

    def _ring_for_sig(self, group: int, sig) -> ChannelRing:
        key = (group, sig)
        ring = self._rings.get(key)
        if ring is None:
            slots = self.ring_slots or self._group_size[group]
            ring = ChannelRing(slots, use_pallas=self.use_pallas,
                               interpret=self.interpret,
                               double_buffered=self.overlap)
            self._rings[key] = ring
        return ring

    def _ring_for(self, agent_gmi: int, exp: Experience) -> ChannelRing:
        sig = tuple(tuple(getattr(exp, c).shape)
                    for c in ("obs", "actions", "rewards"))
        return self._ring_for_sig(self._group_of[agent_gmi], sig)

    def push(self, agent_gmi: int, exp: Experience):
        ring = self._ring_for(agent_gmi, exp)
        if ring.count == ring.slots:       # would evict an unread slot
            group = self._group_of[agent_gmi]
            self._pending.setdefault(group, []).append(ring.snapshot())
            self.spill_count += 1
        ring.append(exp)
        self.occupancy_high_water = max(self.occupancy_high_water,
                                        ring.count / ring.slots)

    def produce(self, agent_gmi: int, T: int, N: int, obs_dim: int,
                act_dim: int, producer) -> None:
        """Zero-copy push: hand the group ring's live slot storage to the
        producer instead of packing a staged payload.

        ``producer(bufs, slot) -> (bufs, bootstrap, actor_version)``
        receives the ring's own ``{obs, actions, rewards, dones}``
        buffers (detached, donated into the producer's jitted scan) plus
        the slot index, and returns the written buffers with the
        bootstrap values and actor version for the slot — the
        ``rl.rollout.collect_ring`` contract.  Spill-not-drop and
        occupancy accounting match :meth:`push` exactly.  Blocking rings
        only (overlap mode already stages references at zero producer
        cost)."""
        if self.overlap:
            raise ValueError(
                "produce targets blocking rings; overlap mode stages "
                "payload references (push is already zero-cost on the "
                "producer side)")
        group = self._group_of[agent_gmi]
        sig = ((T, N, obs_dim), (T, N, act_dim), (T, N))
        ring = self._ring_for_sig(group, sig)
        if ring.count == ring.slots:       # would evict an unread slot
            self._pending.setdefault(group, []).append(ring.snapshot())
            self.spill_count += 1
        bufs, slot = ring.acquire(T, N, obs_dim, act_dim)
        bufs, bootstrap, version = producer(bufs, slot)
        ring.commit(bufs, bootstrap, version)
        self.occupancy_high_water = max(self.occupancy_high_water,
                                        ring.count / ring.slots)

    def flush(self) -> Dict[int, List[Experience]]:
        """Move experience toward trainer batches.

        Blocking mode (default): everything pushed since the last flush
        is snapshotted, routed, and returned — the consumer sees this
        round's data and serving implicitly waits on it.

        Overlap mode: flush is a buffer swap, not a barrier.  This
        round's pushes (spills first, in push order, then the ring swap)
        are parked in flight, and what is returned is the PREVIOUS
        flush's swap — arrays that had a whole serving round to
        materialize while pushes kept landing in the front halves.  The
        first flush returns ``{}``; :meth:`drain` delivers the tail.
        """
        t0 = time.perf_counter()
        current: List[Tuple[int, Dict[str, jax.Array]]] = []
        for gkey, snaps in self._pending.items():
            current.extend((gkey, ch) for ch in snaps)
        self._pending = {}
        for (gkey, _), ring in self._rings.items():
            if ring.count:
                current.append((gkey, ring.snapshot()))
        if self.overlap:
            groups, self._inflight = self._inflight, current
        else:
            groups = current
        if self.fault_hook is not None and groups:
            kept = []
            for gkey, ch in groups:
                action = self.fault_hook(gkey, ch)
                if action == "drop":
                    # lost in transit: back into pending for the next
                    # flush (retransmission) — lossy link, lossless data
                    self._pending.setdefault(gkey, []).append(ch)
                    self.dropped_flushes += 1
                elif action == "poison":
                    from repro.fault.inject import poison_channels
                    kept.append((gkey, poison_channels(ch)))
                    self.poisoned_flushes += 1
                else:
                    kept.append((gkey, ch))
            groups = kept
        if not groups:
            return {}
        bytes_before = self.compressor.stats.total_bytes
        self.compressor.record_flush([ch for _, ch in groups])
        out: Dict[int, List[Experience]] = {}
        for gkey, ch in groups:
            dst = self.migrator.route(
                ch, agent_gpu=None if gkey == -1 else gkey)
            out.setdefault(dst, []).extend(self.batchers[dst].prepare(ch))
            self.delivered_samples += int(np.prod(ch["rewards"].shape))
        nbytes = self.compressor.stats.total_bytes - bytes_before
        if nbytes > 0:
            # one (seconds, bytes) sample per delivering flush — the live
            # channel-transfer evidence the bandwidth calibrator consumes
            # (overlap mode undercounts: the back generation materialized
            # during the previous round, which is why the calibrator
            # down-weights transfer rows relative to reduce rows)
            self._transfer_samples.append(
                (time.perf_counter() - t0, int(nbytes)))
            del self._transfer_samples[:-64]
        return out

    def take_transfer_samples(self) -> List[Tuple[float, int]]:
        """Per-flush (seconds, bytes) channel-transfer timings since the
        last call — drained by the controller into the communicator's
        bandwidth calibrator."""
        samples, self._transfer_samples = self._transfer_samples, []
        return samples

    def requeue(self, exps: Sequence[Experience]) -> None:
        """Put consumed-but-untrained experience back into the delivery
        stream (spill-not-drop for a trainer dying mid-update): the
        batches rejoin ``_pending`` in order and re-deliver — re-routed by
        the Migrator, which no longer counts the dead trainer — at the
        next flush."""
        for exp in exps:
            self._pending.setdefault(-1, []).append(_payloads(exp))

    def drain(self) -> Dict[int, List[Experience]]:
        """Pipeline-ending flush: deliver the in-flight back buffers AND
        any still-buffered front pushes (two swap steps in overlap mode,
        one plain flush otherwise) — the overlap tail is never lost.
        Extra rounds cover retransmissions (dropped flushes re-entering
        ``_pending``), bounded so a hook that drops everything forever
        cannot livelock the drain."""
        out: Dict[int, List[Experience]] = {}
        for _ in range(2 if self.overlap else 1):
            for dst, bs in self.flush().items():
                out.setdefault(dst, []).extend(bs)
        guard = 0
        while guard < 8 and (self._pending or self._inflight
                             or any(r.count for r in self._rings.values())):
            guard += 1
            for dst, bs in self.flush().items():
                out.setdefault(dst, []).extend(bs)
        return out

    def clone_for(self, agent_gmis: Sequence[int],
                  trainer_gmis: Sequence[int],
                  gmi_gpu: Optional[Dict[int, int]] = None) \
            -> "MultiChannelPipeline":
        """A fresh pipeline over a new layout carrying THIS pipeline's
        configuration (batching, ring sizing, backend, overlap) — the
        re-plan path; counters restart with the new layout."""
        some_batcher = next(iter(self.batchers.values()), None)
        return MultiChannelPipeline(
            agent_gmis, trainer_gmis, gmi_gpu=gmi_gpu,
            batch_mode=some_batcher.mode if some_batcher else "stack",
            batch_envs=some_batcher.batch_envs if some_batcher else None,
            ring_slots=self.ring_slots, use_pallas=self.use_pallas,
            interpret=self.interpret, overlap=self.overlap)

    def ring_occupancy(self) -> float:
        """Current front-buffer fill fraction (peak across live rings)."""
        occ = [r.count / r.slots for r in self._rings.values()]
        return max(occ) if occ else 0.0

    def take_occupancy_high_water(self) -> float:
        """Peak fill fraction any ring reached since the last call.
        Exactly 1.0 once per round is the healthy interleaved pattern
        (spills, not occupancy, are the controller's overflow signal);
        ≈0 means trainers starve.  Resets the mark so each decision
        epoch sees its own peak."""
        hw, self.occupancy_high_water = self.occupancy_high_water, 0.0
        return hw

    @property
    def stats(self) -> TransferStats:
        return self.compressor.stats


class CacheChannel:
    """Point-to-point ring for prefill->decode cache migration.

    A prefill-specialist GMI finishes a prompt and ships the resulting
    cache pytree to a decode-specialist GMI's slot.  ``send`` packs the
    pytree into per-dtype contiguous buffers (``pack_cache_payload`` —
    the same coarse-grained-transfer discipline as the experience rings;
    dozens of small leaves would be the §4.2 fine-grained pathology) and
    stages the transfer; ``deliver`` moves everything staged, reassembles
    each payload bit-exactly, and records one :class:`TransferStats`
    entry plus a (seconds, bytes) timing sample per delivering batch —
    calibrator-compatible, so measured migration bandwidth feeds the same
    Table-2 fit as gradient reduces.

    Fault seam: ``fault_hook(source, item)`` may answer ``"drop"`` — the
    transfer is lost in transit and RETRANSMITTED on the next deliver
    (lossy link, lossless data, matching the experience-ring contract).
    A dead *source* is different: :meth:`fail_source` evicts that
    engine's still-staged payloads (their device buffers died with it)
    and returns the items so the caller can re-prefill them on a
    survivor — the supervisor's zero-request-loss path.
    """

    def __init__(self, name: str = "cache"):
        self.name = name
        self.fault_hook = None
        self.stats = TransferStats()
        self.dropped = 0
        self._staged: List[tuple] = []   # (source, item, bufs, meta)
        self._transfer_samples: List[Tuple[float, int]] = []

    def send(self, item, tree, *, source=None) -> int:
        """Stage ``tree`` (a cache pytree) for delivery; ``item`` is the
        caller's opaque routing handle, ``source`` identifies the sending
        engine for :meth:`fail_source`.  Returns the wire size."""
        bufs, meta = pack_cache_payload(tree)
        self._staged.append((source, item, bufs, meta))
        return cache_payload_bytes(bufs)

    @property
    def in_flight(self) -> int:
        return len(self._staged)

    def deliver(self) -> List[tuple]:
        """Deliver everything staged as ``(item, tree)`` pairs, oldest
        first.  Dropped transfers stay staged for retransmission."""
        t0 = time.perf_counter()
        staged, self._staged = self._staged, []
        out: List[tuple] = []
        nbytes = 0
        for source, item, bufs, meta in staged:
            if self.fault_hook is not None \
                    and self.fault_hook(source, item) == "drop":
                self.dropped += 1
                self._staged.append((source, item, bufs, meta))
                continue
            tree = unpack_cache_payload(bufs, meta)
            self.stats.record(tree)
            nbytes += cache_payload_bytes(bufs)
            out.append((item, tree))
        if nbytes > 0:
            self._transfer_samples.append(
                (time.perf_counter() - t0, int(nbytes)))
            del self._transfer_samples[:-64]
        return out

    def fail_source(self, source) -> List:
        """Evict payloads still staged from a dead source engine; returns
        their ``item`` handles for re-prefill on a survivor."""
        lost = [item for (src, item, _, _) in self._staged
                if src is source]
        self._staged = [e for e in self._staged if e[0] is not source]
        return lost

    def take_transfer_samples(self) -> List[Tuple[float, int]]:
        """Per-delivery (seconds, bytes) samples since the last call —
        the migration-bandwidth evidence for the calibrator."""
        samples, self._transfer_samples = self._transfer_samples, []
        return samples


class HostStagedPipeline:
    """The seed MCC: host-list staging + per-flush ``jnp.concatenate``
    re-materialization, single destination per flush.  Kept as the
    before/after baseline for ``bench_mcc`` — not for production use."""

    def __init__(self, agent_gmis: Sequence[int], trainer_gmis: Sequence[int],
                 gmi_gpu: Optional[Dict[int, int]] = None,
                 batch_mode: str = "stack",
                 batch_envs: Optional[int] = None):
        self.dispensers = {a: Dispenser(a) for a in agent_gmis}
        self.compressor = Compressor()
        self.migrator = Migrator(trainer_gmis, gmi_gpu)
        self.batchers = {t: Batcher(batch_mode, batch_envs)
                         for t in trainer_gmis}

    def push(self, agent_gmi: int, exp: Experience):
        self.dispensers[agent_gmi].push(exp)

    def flush(self) -> Dict[int, List[Experience]]:
        per_agent = [d.drain() for d in self.dispensers.values()]
        per_agent = [d for d in per_agent if any(d[c] for c in CHANNELS)]
        if not per_agent:
            return {}
        channels = self.compressor.compress(per_agent)
        dst = self.migrator.route(channels)
        return {dst: self.batchers[dst].prepare(channels)}

    def drain(self) -> Dict[int, List[Experience]]:
        """API parity with :class:`MultiChannelPipeline` (host staging has
        no in-flight buffers — drain is a plain flush)."""
        return self.flush()

    @property
    def stats(self) -> TransferStats:
        return self.compressor.stats


class UniChannelPipeline:
    """UCC baseline: every experience tuple is its own fine-grained
    transfer (one op per field per agent per round — Table 8's loser)."""

    def __init__(self, trainer_gmis: Sequence[int]):
        self.trainer_gmis = list(trainer_gmis)
        self.stats = TransferStats()
        self._rr = 0

    def send(self, exp: Experience) -> int:
        for c in CHANNELS:
            self.stats.record(getattr(exp, c))  # one transfer PER FIELD
        dst = self.trainer_gmis[self._rr % len(self.trainer_gmis)]
        self._rr += 1
        return dst
