from repro.fault.inject import (KINDS, TEAR_MODES, FaultEvent,  # noqa: F401
                                FaultPlan, InjectedFault,
                                make_save_crash_hook, tear_checkpoint)
from repro.fault.supervisor import FleetSupervisor  # noqa: F401
