"""Fleet supervision: failure classification, quarantine, and lossless
recovery over the async trainer and the serving router.

The :class:`FleetSupervisor` is the recovery half of the fault subsystem
(:mod:`repro.fault.inject` is the breakage half).  It wraps an
``AsyncRunner`` (and optionally a ``RequestRouter``), installs the
injection hooks at every seam, and turns each raised
:class:`~repro.fault.inject.InjectedFault` into the paper-shaped recovery
action for its class:

* **serving GMI dies** — the GPU is quarantined, the pool shrinks by one
  serving GPU, and a controller-style re-plan (``AsyncRunner.replan``
  with an explicit reduced-pool layout) drains-and-trains everything
  still buffered, rebuilds the pipeline over the survivors, and rebinds
  the communicator.  No experience sample is lost: everything already
  pushed rides the drain.
* **trainer GMI dies** — the batch it was consuming and every batch
  behind it have already been re-queued into the ring by ``_train``
  (spill-not-drop); the round's gradient is discarded, the GPU is
  quarantined, and the same reduced-pool re-plan re-delivers the spilled
  experience to the surviving trainers.
* **serving engine dies mid-decode** — ``RequestRouter.fail_engine``:
  queued requests re-route to survivors with their latency clocks
  intact, in-flight requests restart from scratch under a capped retry
  budget, deadlines keep running throughout.
* **prefill GMI dies** — classified separately from decode-engine death:
  ``DisaggFront.fail_prefill_engine`` re-routes its queued prompts to a
  surviving prefill specialist, evicts the dead source's in-flight cache
  payloads from the migration channel, and re-prefills those requests on
  survivors with their submit clocks intact — zero requests lost.
* **channel drop / poison** — the pipeline retransmits dropped flushes
  from ``_pending``; poisoned flushes reach the trainer, whose
  non-finite guard (enabled by the supervisor) discards the update
  instead of corrupting the model.
* **checkpoint tear** — periodic preemption-safe checkpoints go through
  the hardened atomic ``repro.checkpoint`` writer; a scheduled
  ``ckpt_tear`` event either crashes the save mid-write (atomicity
  leaves the previous pair intact) or corrupts the finished pair
  post-hoc (``AsyncRunner.restore`` skips it and falls back).

A quarantined GPU re-enters the pool after ``probation`` consecutive
healthy rounds (re-admission is one more re-plan, growing the pool
back).  Every failure and recovery is recorded in ``failures`` /
``recoveries`` for tests and benches to assert against.
"""
from __future__ import annotations

from typing import List, Optional

from repro.fault.inject import (TEAR_MODES, FaultPlan, InjectedFault,
                                tear_checkpoint, make_save_crash_hook)


class FleetSupervisor:
    """Drives ``runner.round()`` / ``router.step()`` under a
    :class:`~repro.fault.inject.FaultPlan`, recovering losslessly from
    every fault class the plan can schedule.

    Parameters
    ----------
    runner : AsyncRunner
        The async trainer to supervise.  Its ``fault_hook`` /
        ``nonfinite_guard`` are installed here.
    layout : placement Layout
        The layout the runner currently runs — the device universe for
        reduced-pool re-plans.
    plan : FaultPlan, optional
        The fault schedule.  ``None`` supervises without injection (the
        hooks stay armed; real failures raised at the seams recover the
        same way).
    router : RequestRouter, optional
        The serving front; engine hooks are armed on its live engine set
        every guarded step.
    ckpt_dir / ckpt_every : periodic preemption-safe checkpointing —
        every ``ckpt_every`` healthy rounds, params/opt/version plus
        counters and controller tables are checkpointed atomically.
    probation : healthy rounds before a quarantined GPU re-enters.
    max_retries : per-request restart budget after engine deaths.
    """

    def __init__(self, runner, layout, *, plan: Optional[FaultPlan] = None,
                 router=None, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 0, probation: int = 2,
                 max_retries: int = 2):
        self.runner = runner
        self.router = router
        self.plan = plan
        self.layout = layout
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = int(ckpt_every)
        self.probation = int(probation)
        self.max_retries = int(max_retries)

        gmis = layout.manager.gmis.values()
        gpus = {g.gpu_id for g in gmis}
        serving = {g.gpu_id for g in gmis if g.role == "serving"}
        per_gpu = {}
        for g in gmis:
            per_gpu[g.gpu_id] = per_gpu.get(g.gpu_id, 0) + 1
        self.num_gpu = len(gpus)
        self.serving_gpus = max(len(serving), 1)
        self.gmi_per_gpu = max(per_gpu.values()) if per_gpu else 1

        self.rounds_total = 0
        self.healthy_streak = 0
        self.quarantined: List[dict] = []    # {"gpu","role","round"}
        self.failures: List[dict] = []
        self.recoveries: List[dict] = []
        self.ckpt_steps: List[int] = []

        runner.fault_hook = self._runner_hook
        runner.nonfinite_guard = True
        self._install_pipe_hook()
        self._drop_mark = 0
        self._poison_mark = 0
        self._poison_batch_mark = runner.poisoned_batches

    # ------------------------------------------------------------- hooks --
    def _runner_hook(self, role: str, gmi: int) -> None:
        if self.plan is None:
            return
        kind = "kill_serving" if role == "serving" else "kill_trainer"
        ev = self.plan.take(kind, target=gmi)
        if ev is not None:
            exc = InjectedFault(ev)
            exc.victim = gmi
            exc.role = role
            raise exc

    def _pipe_hook(self, gkey, channels) -> Optional[str]:
        if self.plan is None:
            return None
        if self.plan.take("channel_drop", target=gkey) is not None:
            return "drop"
        if self.plan.take("channel_poison", target=gkey) is not None:
            return "poison"
        return None

    def _install_pipe_hook(self) -> None:
        pipe = self.runner.pipe
        if hasattr(pipe, "fault_hook"):
            pipe.fault_hook = self._pipe_hook

    def _arm_engines(self) -> None:
        if self.router is None:
            return
        for i, eng in enumerate(self.router.engines):
            eng.fault_hook = self._make_engine_hook(i)
        for i, eng in enumerate(getattr(self.router,
                                        "prefill_engines", ())):
            eng.fault_hook = self._make_engine_hook(i, kind="prefill_fail")

    def _make_engine_hook(self, index: int, kind: str = "engine_fail"):
        def hook(engine):
            if self.plan is None:
                return
            ev = self.plan.take(kind, target=index)
            if ev is not None:
                raise InjectedFault(ev, engine=engine)
        return hook

    # ---------------------------------------------------------- the loop --
    def round(self):
        """One supervised serve->ship->train round (plus one guarded
        router step when a router is attached).  Returns the runner's
        (losses, staleness) — empty on a failed-and-recovered round."""
        if self.plan is not None:
            self.plan.advance(self.rounds_total)
        losses, stale = [], []
        try:
            losses, stale = self.runner.round()
            self._on_healthy_round()
        except InjectedFault as exc:
            self._recover_runner(exc)
        self._classify_telemetry()
        if self.router is not None:
            self.step_serving()
        self.rounds_total += 1
        if self.ckpt_dir and self.ckpt_every > 0 \
                and self.rounds_total % self.ckpt_every == 0:
            if self.plan is not None:
                # checkpoint steps are stamped with the post-round count;
                # a tear scheduled for round N must be due when step N is
                # written, not one cadence later
                self.plan.advance(self.rounds_total)
            self._checkpoint()
        return losses, stale

    def run(self, rounds: int):
        """Supervise ``rounds`` rounds, then drain the tail
        (``runner.finish``) so trained_samples catches up."""
        for _ in range(rounds):
            self.round()
        return self.runner.finish()

    def step_serving(self):
        """One guarded router step: engine hooks armed on the live set
        (decode AND prefill specialists); a dying decode engine is failed
        over via ``fail_engine``, a dying prefill GMI via
        ``fail_prefill_engine`` (lossless — queued prompts and in-flight
        cache payloads re-route to survivors)."""
        self._arm_engines()
        try:
            return self.router.step()
        except Exception as exc:
            eng = getattr(exc, "engine", None)
            if eng is None:
                raise
            if eng in getattr(self.router, "prefill_engines", ()):
                self.failures.append({
                    "kind": "prefill_fail", "round": self.rounds_total,
                    "target": getattr(eng, "name", None)})
                rerouted = self.router.fail_prefill_engine(eng)
                self.recoveries.append({
                    "kind": "prefill_fail", "round": self.rounds_total,
                    "action": f"re-routed {rerouted} prompt(s)/payload(s) "
                              f"to surviving prefill GMI(s)"})
                return []
            self.failures.append({
                "kind": "engine_fail", "round": self.rounds_total,
                "target": getattr(eng, "name", None)})
            failed = self.router.fail_engine(eng, self.max_retries)
            self.recoveries.append({
                "kind": "engine_fail", "round": self.rounds_total,
                "action": f"failed over to {self.router.num_engines} "
                          f"survivor(s), {len(failed)} retry-exhausted"})
            return failed

    def drain_serving(self):
        """Guarded ``router.drain()``: step until idle, failing over any
        engine that dies on the way."""
        done = []
        while self.router is not None and self.router.busy:
            done.extend(self.step_serving() or [])
        return done

    # ----------------------------------------------------------- recovery --
    def _recover_runner(self, exc: InjectedFault) -> None:
        role = getattr(exc, "role",
                       "serving" if exc.event.kind == "kill_serving"
                       else "trainer")
        victim = getattr(exc, "victim", exc.event.target)
        gpu = None
        g = self.layout.manager.gmis.get(victim) if victim is not None \
            else None
        if g is not None:
            gpu = g.gpu_id
        self.failures.append({"kind": exc.event.kind,
                              "round": self.rounds_total,
                              "target": victim, "gpu": gpu})
        self.healthy_streak = 0
        if role == "serving":
            # the dead GMI's GPU leaves the pool as a serving GPU; the
            # floor is one serving GPU — below that the fleet restarts
            # the GMI in place instead of shrinking
            if self.serving_gpus > 1:
                self.serving_gpus -= 1
                self.num_gpu -= 1
                self.quarantined.append({"gpu": gpu, "role": "serving",
                                         "round": self.rounds_total})
                action = f"quarantined serving GPU {gpu}"
            else:
                action = "restarted last serving GPU in place"
        else:
            if self.num_gpu - 1 > self.serving_gpus:
                self.num_gpu -= 1
                self.quarantined.append({"gpu": gpu, "role": "trainer",
                                         "round": self.rounds_total})
                action = f"quarantined trainer GPU {gpu}"
            else:
                action = "restarted last trainer GPU in place"
        self._replan(f"{exc.event.kind}: {action}")
        self.recoveries.append({"kind": exc.event.kind,
                                "round": self.rounds_total,
                                "action": action,
                                "num_gpu": self.num_gpu,
                                "serving_gpus": self.serving_gpus})

    def _replan(self, reason: str) -> None:
        """Reduced/grown-pool re-plan: drain-and-train (lossless), then
        rebuild pipeline + actors + communicator binding over the new
        pool.  Bypasses the controller's own layout planning — the
        supervisor, not Algorithm 2, decides the post-failure pool."""
        from repro.core.controller import Decision
        from repro.core.placement import plan_async
        mgr = self.layout.manager
        layout = plan_async(self.num_gpu, self.serving_gpus,
                            self.gmi_per_gpu, devices=mgr.devices,
                            devices_per_gpu=mgr.devices_per_gpu)
        decision = Decision(num_env=self.runner.num_envs,
                            gmi_per_gpu=self.gmi_per_gpu,
                            serving_gpus=self.serving_gpus,
                            reason=reason)
        self.layout = self.runner.replan(decision, layout=layout) or layout
        # clone_for starts the new pipeline without hooks — re-arm
        self._install_pipe_hook()
        self._drop_mark = 0
        self._poison_mark = 0
        ctl = self.runner.controller
        if ctl is not None:
            # the controller's notion of the fleet must track the real
            # (post-quarantine) pool, or its next decision re-plans a
            # layout over GPUs that no longer exist
            ctl.num_gpu = self.num_gpu
            ctl.serving_gpus = self.serving_gpus
            ctl.gmi_per_gpu = self.gmi_per_gpu

    def _on_healthy_round(self) -> None:
        self.healthy_streak += 1
        if self.quarantined and self.healthy_streak >= self.probation:
            back = self.quarantined.pop(0)
            self.num_gpu += 1
            if back["role"] == "serving":
                self.serving_gpus += 1
            self._replan(f"probation passed ({self.probation} healthy "
                         f"rounds): re-admitting {back['role']} GPU "
                         f"{back['gpu']}")
            self.recoveries.append({"kind": "readmit",
                                    "round": self.rounds_total,
                                    "gpu": back["gpu"],
                                    "role": back["role"],
                                    "num_gpu": self.num_gpu,
                                    "serving_gpus": self.serving_gpus})
            self.healthy_streak = 0

    def _classify_telemetry(self) -> None:
        """Classify sub-fatal faults from existing telemetry deltas:
        dropped/poisoned flush counters on the pipeline and discarded
        non-finite updates on the runner."""
        pipe = self.runner.pipe
        drops = getattr(pipe, "dropped_flushes", 0)
        poisons = getattr(pipe, "poisoned_flushes", 0)
        bad = self.runner.poisoned_batches
        if drops > self._drop_mark:
            self.failures.append({"kind": "channel_drop",
                                  "round": self.rounds_total,
                                  "count": drops - self._drop_mark})
            self.recoveries.append({"kind": "channel_drop",
                                    "round": self.rounds_total,
                                    "action": "retransmit from _pending"})
        if poisons > self._poison_mark:
            self.failures.append({"kind": "channel_poison",
                                  "round": self.rounds_total,
                                  "count": poisons - self._poison_mark})
        if bad > self._poison_batch_mark:
            self.recoveries.append({
                "kind": "channel_poison", "round": self.rounds_total,
                "action": f"discarded {bad - self._poison_batch_mark} "
                          "non-finite update(s)"})
        self._drop_mark = drops
        self._poison_mark = poisons
        self._poison_batch_mark = bad

    # --------------------------------------------------------- checkpoint --
    def _checkpoint(self) -> None:
        """Periodic preemption-safe checkpoint, honoring any scheduled
        ``ckpt_tear``: a SAVE_STAGES mode crashes the save mid-write (the
        atomic writer leaves the previous pair intact), a TEAR_MODES mode
        corrupts the finished pair post-hoc (restore must skip it)."""
        step = self.rounds_total
        ev = self.plan.take("ckpt_tear") if self.plan is not None else None
        hook = None
        if ev is not None and ev.mode is not None \
                and ev.mode not in TEAR_MODES:
            hook = make_save_crash_hook(ev.mode, ev)
        try:
            self.runner.checkpoint(self.ckpt_dir, step=step,
                                   fault_hook=hook)
            self.ckpt_steps.append(step)
        except InjectedFault:
            self.failures.append({"kind": "ckpt_tear", "round": step,
                                  "mode": ev.mode})
            self.recoveries.append({
                "kind": "ckpt_tear", "round": step,
                "action": "save crashed mid-write; previous pair intact"})
            return
        if ev is not None and (ev.mode is None or ev.mode in TEAR_MODES):
            tear_checkpoint(self.ckpt_dir, step, ev.mode or "torn_npz")
            self.failures.append({"kind": "ckpt_tear", "round": step,
                                  "mode": ev.mode or "torn_npz"})
            self.recoveries.append({
                "kind": "ckpt_tear", "round": step,
                "action": "pair corrupted post-hoc; restore will skip"})

    # ------------------------------------------------------------ queries --
    def summary(self) -> str:
        lines = [f"FleetSupervisor(rounds={self.rounds_total}, "
                 f"num_gpu={self.num_gpu}, serving={self.serving_gpus}, "
                 f"quarantined={len(self.quarantined)}, "
                 f"failures={len(self.failures)}, "
                 f"recoveries={len(self.recoveries)})"]
        for f in self.failures:
            lines.append(f"  FAIL r{f['round']}: "
                         + ", ".join(f"{k}={v}" for k, v in f.items()
                                     if k != "round"))
        for r in self.recoveries:
            lines.append(f"  RECOVER r{r['round']}: "
                         + ", ".join(f"{k}={v}" for k, v in r.items()
                                     if k != "round"))
        return "\n".join(lines)
