"""Deterministic fault injection — the reproducible half of the fault
subsystem.

A :class:`FaultPlan` is a seeded, pre-computed schedule of
:class:`FaultEvent`\\ s.  Hooks installed by the
:class:`~repro.fault.supervisor.FleetSupervisor` consult the plan at the
existing seams (``AsyncRunner.fault_hook``, ``ServeEngine.fault_hook``,
``MultiChannelPipeline.fault_hook``, ``checkpoint.save(fault_hook=)``)
and fire each event exactly once at its scheduled round — so a test or
bench replaying the same plan against the same workload sees the exact
same failure sequence AND the exact same recovery sequence.  Nothing in
this module knows how to recover; it only breaks things on schedule.

Fault classes (``KINDS``):

* ``kill_serving``   — a serving GMI dies mid-round, before its push.
* ``kill_trainer``   — a trainer GMI dies mid-round: the batch it was
  consuming (gradient discarded) and everything not yet consumed must be
  re-queued in the ring — spill, not drop.
* ``engine_fail``    — a request-serving engine dies mid-decode: its
  decode slots (cache and all) are gone; queued requests survive at the
  admission front.
* ``prefill_fail``   — a prefill-specialist GMI dies: its queued prompts
  and any cache payload it has in flight on the migration channel must
  re-route to survivors with their latency clocks intact (lossless).
* ``channel_drop``   — a channel flush is lost in transit (the pipeline
  retransmits it on the next flush).
* ``channel_poison`` — a channel flush is delivered corrupted (NaN
  rewards; the trainer-side non-finite guard must discard the update).
* ``ckpt_tear``      — a checkpoint write fails: either a crash mid-save
  (``mode`` naming a :data:`repro.checkpoint.ckpt.SAVE_STAGES` stage) or
  post-hoc corruption of the pair (``mode`` "torn_npz"/"missing_npz",
  applied via :func:`tear_checkpoint`).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

KINDS = ("kill_serving", "kill_trainer", "engine_fail", "prefill_fail",
         "channel_drop", "channel_poison", "ckpt_tear")

# ckpt_tear modes: SAVE_STAGES entries crash mid-save (atomicity holds);
# these two post-hoc-corrupt a completed pair (what an unhardened saver
# or external damage produces — the state recovery must SKIP)
TEAR_MODES = ("torn_npz", "missing_npz")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``target`` narrows the victim (a GMI id for
    kill_* events, an engine index for engine_fail); ``None`` matches the
    first candidate the hooks offer — still deterministic, because hook
    call order is the (deterministic) execution order."""
    kind: str
    round: int
    target: Optional[int] = None
    mode: Optional[str] = None        # ckpt_tear only

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


class InjectedFault(RuntimeError):
    """Raised at an injection seam; carries the event (and, for engine
    faults, the dying engine) so the supervisor can classify and target
    recovery without guessing."""

    def __init__(self, event: FaultEvent, engine=None):
        super().__init__(
            f"injected fault {event.kind} at round {event.round}"
            + (f" (target {event.target})" if event.target is not None
               else ""))
        self.event = event
        self.engine = engine


@dataclass
class FaultPlan:
    """A deterministic schedule of faults.

    ``round`` is advanced by the supervisor; :meth:`take` fires the first
    matching not-yet-fired event whose scheduled round has arrived.  An
    event never fires twice, and an event whose round has passed fires at
    the next opportunity (a kill scheduled for round 3 against a GMI only
    asked about at round 4 still fires — late, but exactly once and at a
    reproducible point)."""
    events: Sequence[FaultEvent] = ()
    seed: int = 0
    round: int = 0
    fired: List[FaultEvent] = field(default_factory=list)

    def __post_init__(self):
        self.events = sorted(
            self.events,
            key=lambda e: (e.round, KINDS.index(e.kind),
                           -1 if e.target is None else e.target))
        self._live: List[FaultEvent] = list(self.events)

    @classmethod
    def random(cls, seed: int, rounds: int,
               kinds: Sequence[str] = ("kill_serving", "kill_trainer",
                                       "engine_fail", "channel_drop"),
               rate: float = 0.25,
               targets: Sequence[int] = (0, 1, 2)) -> "FaultPlan":
        """A seeded random plan: each round draws at most one fault with
        probability ``rate``.  Same seed -> same plan, always."""
        rng = np.random.default_rng(seed)
        events = []
        for r in range(rounds):
            if rng.random() < rate:
                kind = str(rng.choice(list(kinds)))
                target = int(rng.choice(list(targets)))
                events.append(FaultEvent(kind=kind, round=r, target=target))
        return cls(events=events, seed=seed)

    # ------------------------------------------------------------ queries --
    def advance(self, round_index: int) -> None:
        self.round = int(round_index)

    def pending(self, kind: Optional[str] = None) -> List[FaultEvent]:
        return [e for e in self._live if kind is None or e.kind == kind]

    @property
    def exhausted(self) -> bool:
        return not self._live

    def take(self, kind: str, target: Optional[int] = None) \
            -> Optional[FaultEvent]:
        """Fire-once matching: the first live event of ``kind`` whose
        scheduled round has arrived and whose target matches (an event
        with ``target=None`` matches any offered target; an offered
        ``target=None`` matches any event)."""
        for e in self._live:
            if e.kind != kind or e.round > self.round:
                continue
            if e.target is not None and target is not None \
                    and e.target != target:
                continue
            self._live.remove(e)
            self.fired.append(e)
            return e
        return None


# ---------------------------------------------------------- ckpt tearing --
def tear_checkpoint(directory: str, step: int, mode: str = "torn_npz") -> str:
    """Post-hoc corrupt a completed checkpoint pair — the damage an
    UNHARDENED saver (or bit rot / external deletion) produces, which the
    atomic write path can no longer create by crashing.  ``torn_npz``
    truncates the array file mid-byte; ``missing_npz`` deletes it,
    leaving a manifest pointing at nothing.  Returns the damaged path."""
    if mode not in TEAR_MODES:
        raise ValueError(f"unknown tear mode {mode!r}; "
                         f"expected one of {TEAR_MODES}")
    npz = os.path.join(directory, f"ckpt_{step}.npz")
    if not os.path.exists(npz):
        raise FileNotFoundError(npz)
    if mode == "missing_npz":
        os.remove(npz)
    else:
        size = os.path.getsize(npz)
        with open(npz, "r+b") as f:
            f.truncate(max(size // 3, 1))
    return npz


def make_save_crash_hook(stage: str, event: Optional[FaultEvent] = None):
    """A ``checkpoint.save(fault_hook=)`` that crashes (raises
    :class:`InjectedFault`) at ``stage`` — simulating preemption exactly
    at that durability boundary."""
    from repro.checkpoint.ckpt import SAVE_STAGES
    if stage not in SAVE_STAGES:
        raise ValueError(f"unknown save stage {stage!r}; "
                         f"expected one of {SAVE_STAGES}")
    ev = event or FaultEvent(kind="ckpt_tear", round=0, mode=stage)

    def hook(at: str):
        if at == stage:
            raise InjectedFault(ev)
    return hook


def poison_channels(channels: dict) -> dict:
    """What a torn transfer delivers: the reward stream replaced with
    NaNs (the downstream non-finite guard's detection surface)."""
    import jax.numpy as jnp
    out = dict(channels)
    out["rewards"] = jnp.full_like(out["rewards"], jnp.nan)
    return out
