"""Layout-aware gradient-reduction schedules — LGR (paper §4.1), N-level.

The paper's three schedules, generalized from the original 2-axis
(gpu, inst) instance grid to the hierarchical (gpu, inst, dev) meshes
``GMIManager.instance_mesh`` builds for multi-device GMIs:

* MPR  (multi-process reduction): stage every instance's gradient through
  host memory and reduce on CPU — generic, layout-agnostic, slow (paper
  Table 2: 2·(g·t−1)·Mp / (g·t·B1)).  Inside one SPMD program it
  degenerates to a flat reduce; the true host-staged variant is
  :func:`mpr_host`.
* MRR  (multi-ring reduction): one flat ring over all instances — a single
  ``psum`` over every mesh axis (paper: non-intersecting NCCL rings + a
  final ring; valid only when instances-per-GPU ≤ GPUs).
* HAR  (hierarchical reduction): reduce within the fast domain first, then
  across the slow domain on shrunken shards, then gather — expressed as
  ``psum_scatter(intra) → psum(inter) → all_gather(intra)``.  On a 3-axis
  mesh the intra domain is the merged ``(inst, dev)`` plane.
* HAR3 (3-level hierarchical reduction): the fast domain is itself
  hierarchical — chips inside one GMI (``dev``, fastest links) and GMIs on
  one GPU (``inst``) — so the reduce nests one more level:
  ``psum_scatter(dev) → psum_scatter(inst) → psum(gpu) →
  all_gather(inst) → all_gather(dev)``.  Cross-GPU traffic drops
  (inst·dev)×; cross-instance traffic drops dev×.

Sum-vs-mean semantics live in exactly ONE place: every schedule returns a
raw SUM; :func:`_finalize_average` applies the optional division, used by
:func:`make_grad_sync` (in-SPMD) and :func:`mpr_host` (host) alike.

The same schedules serve two scales:
  DRL GMIs   — ``dev`` = chips in one instance, ``inst`` = instances on one
               GPU, ``gpu`` = physical device groups;
  LLM pods   — intra axis = 'data' (ICI), inter axis = 'pod' (DCN).
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

STRATEGIES = ("mpr", "mrr", "har", "har3")


# ----------------------------------------------------- average (one place) --
def _finalize_average(tree, count: int, average: bool):
    """THE single sum-vs-mean switch: every schedule produces raw sums and
    every public entry point funnels through here (``average=True`` divides
    by the participant count, ``False`` returns the sum untouched)."""
    if not average:
        return tree
    return jax.tree.map(lambda g: g / count, tree)


def _axis_count(axis_names) -> int:
    """Static participant count inside an SPMD body: psum of a Python
    literal folds to the axis size on every jax version this repo
    supports — the one call path that never probes a live buffer."""
    n = 1
    for a in axis_names:
        n *= jax.lax.psum(1, a)
    return n


# ---------------------------------------------------------------- in-SPMD --
def flat_psum(grads, axis_names):
    """MRR analogue: one flat all-reduce (raw sum) over the merged axes."""
    return jax.tree.map(lambda g: jax.lax.psum(g, tuple(axis_names)), grads)


def hierarchical_psum(grads, axes: Sequence):
    """N-level HAR (raw sum).  ``axes[0]`` is the slow reduce axis (plain
    ``psum``); ``axes[1:]`` are scatter levels ordered slow → fast, each a
    mesh-axis name or a tuple of names (a merged domain).

    Scatters apply fastest level first, gathers undo them in reverse:
    the 3-level form over ``("gpu", "inst", "dev")`` is exactly
    ``psum_scatter(dev) → psum_scatter(inst) → psum(gpu) →
    all_gather(inst) → all_gather(dev)``.  Operates leaf-wise on flattened
    gradients (padded to the product of scatter-level sizes) so arbitrary
    parameter shapes work.
    """
    reduce_axis = axes[0]
    levels = [tuple(a) if isinstance(a, (tuple, list)) else (a,)
              for a in axes[1:]]
    if not levels:
        return jax.tree.map(lambda g: jax.lax.psum(g, reduce_axis), grads)
    sizes = [_axis_count(lvl) for lvl in levels]
    block = int(np.prod(sizes))

    def one(g):
        shape = g.shape
        flat = g.reshape(-1)
        n = flat.shape[0]
        pad = (-n) % block
        flat = jnp.pad(flat, (0, pad))
        shard = flat
        for lvl, s in zip(reversed(levels), reversed(sizes)):   # fast first
            shard = jax.lax.psum_scatter(shard.reshape(s, -1), lvl,
                                         scatter_dimension=0, tiled=False)
        shard = jax.lax.psum(shard, reduce_axis)
        for lvl in levels:                  # undo scatters in reverse order
            shard = jax.lax.all_gather(shard, lvl, axis=0,
                                       tiled=False).reshape(-1)
        return shard[:n].reshape(shape)

    return jax.tree.map(one, grads)


def make_grad_sync(strategy: str, axes: Sequence[str] = ("gpu", "inst"),
                   *, average: bool = True) -> Callable:
    """Gradient-sync closure usable inside shard_map/pjit-SPMD bodies.

    ``axes`` is the instance grid ordered slow → fast (mesh axis order),
    e.g. ``("gpu", "inst")`` or ``("gpu", "inst", "dev")``.  ``average``
    divides the reduced sum by the total participant count — handled here
    (via :func:`_finalize_average`), never inside a schedule.
    """
    axes = tuple(axes)
    if len(axes) < 2:
        raise ValueError(
            f"LGR schedules need at least a 2-axis (inter, intra) instance "
            f"grid; got axes {axes}")
    if strategy in ("mrr", "mpr"):
        # inside an SPMD program MPR degenerates to a flat reduce; the true
        # host-staged variant is ``mpr_host`` below (submesh backend)
        sync_sum = functools.partial(flat_psum, axis_names=axes)
    elif strategy == "har":
        intra = axes[1] if len(axes) == 2 else tuple(axes[1:])
        sync_sum = functools.partial(hierarchical_psum,
                                     axes=(axes[0], intra))
    elif strategy == "har3":
        if len(axes) != 3:
            raise ValueError(
                f"har3 is the 3-level schedule and needs a 3-axis "
                f"(gpu, inst, dev) grid; got axes {axes} — use 'har' for "
                "2-level layouts")
        sync_sum = functools.partial(hierarchical_psum, axes=axes)
    else:
        raise ValueError(f"unknown reduction strategy {strategy!r}; "
                         f"expected one of {STRATEGIES}")
    if not average:
        return sync_sum

    def sync(grads):
        return _finalize_average(sync_sum(grads), _axis_count(axes), True)

    return sync


# ------------------------------------------------------------- host-staged -
def mpr_host(grads_per_instance: Sequence, *, average: bool = True):
    """True multi-process reduction for the submesh (MIG-like) backend:
    every instance's gradients are pulled to host, reduced on CPU, and the
    result is returned (to be device_put per instance by the caller).

    This is the paper's generic-but-slow baseline: O(g·t) host transfers
    and CPU-side arithmetic.  ``average`` follows the same single-switch
    semantics as every other schedule (:func:`_finalize_average`).
    """
    host_trees = [jax.tree.map(np.asarray, jax.device_get(g))
                  for g in grads_per_instance]
    total = jax.tree.map(lambda *xs: sum(xs), *host_trees)
    return _finalize_average(total, len(host_trees), average)


# -------------------------------------------------------------- shard_map --
def lgr_allreduce(grads, mesh: Mesh, strategy: str, *,
                  average: bool = True):
    """Run an LGR schedule over per-instance gradient replicas.

    ``grads`` leaves must carry a leading instance grid matching the mesh
    shape — ``(g, t, ...)`` on a (gpu, inst) mesh, ``(g, t, d, ...)`` on a
    (gpu, inst, dev) mesh — one gradient per instance.  Returns the
    reduced (averaged by default) gradient with the same leading grid
    (all replicas equal).
    """
    nd = mesh.devices.ndim
    if nd not in (2, 3):
        raise ValueError(
            f"LGR schedules reduce over a 2-axis (gpu, inst) or 3-axis "
            f"(gpu, inst, dev) instance grid; got axes {mesh.axis_names}")
    axes = mesh.axis_names
    sync = make_grad_sync(strategy, axes, average=average)
    spec = P(*axes)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: spec, grads),),
        out_specs=jax.tree.map(lambda _: spec, grads))
    def run(gs):
        local = jax.tree.map(lambda x: x[(0,) * nd], gs)
        red = sync(local)
        return jax.tree.map(lambda x: x[(None,) * nd], red)

    return run(grads)
