"""The Communicator — communication as a first-class subsystem object.

Every layer that used to string-pass a strategy name ("mrr"/"har"/...)
now consumes one :class:`Communicator` that owns

* the trainer instance grid — the logical (g, t[, d]) shape and,
  when running on real devices, the ``GMIManager.instance_mesh`` it maps
  to;
* the active reduction strategy and its in-SPMD grad-sync closure
  (:attr:`grad_sync_fn` — duck-typed so ``rl.ppo``/``rl.a3c`` accept a
  Communicator anywhere a ``grad_sync_fn`` callable was accepted);
* the :class:`~repro.comm.select.ReduceCostModel` plus a table of
  *measured* per-strategy reduce times (:meth:`observe`), from which
  :meth:`propose_switch` answers the online controller's question: does
  the measured per-round reduce time disagree with the current choice by
  more than the re-plan hysteresis?

Strategy switches (:meth:`switch`) are pure communication plumbing — the
mesh, the measurement table, and (critically) the caller's model and
optimizer state are untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.comm.calibrate import BandwidthCalibrator
from repro.comm.schedules import (STRATEGIES, lgr_allreduce, make_grad_sync,
                                  mpr_host)
from repro.comm.select import ReduceCostModel, select_reduction_strategy

_DEFAULT_AXES = ("gpu", "inst", "dev")


def _layout_grid(layout, role: Optional[str] = None):
    """(mpl, grid, dev_per_inst, uniform, role) of a layout's trainer
    placement — the one place the instance grid is read off a layout
    (from_layout and rebind both derive through here)."""
    mpl = layout.mpl
    if not mpl:
        raise ValueError("layout has no trainer GMIs — no instance grid")
    mgr = layout.manager
    if role is None:
        role = "trainer" if mgr.gmi_to_gpu_mapping("trainer") \
            else "holistic"
    sizes = {mgr.gmis[gid].num_devices for row in mpl for gid in row}
    if len(sizes) > 1:
        # mirror instance_mesh: a resized instance must never lose chips
        # by silently planning as if every GMI were single-chip
        raise ValueError(
            f"role {role} has mixed devices-per-GMI {sorted(sizes)}; the "
            "instance grid (and its cost model) needs a uniform dev axis")
    d = max(sizes.pop(), 1)
    uniform = len({len(row) for row in mpl}) == 1
    grid = (len(mpl), max(len(row) for row in mpl))
    if d > 1:
        grid = grid + (d,)
    return mpl, grid, d, uniform, role


class Communicator:
    """Owns mesh + strategy + grad-sync closure for one trainer layout."""

    def __init__(self, strategy: str, *, mesh=None,
                 grid: Optional[Sequence[int]] = None, average: bool = True,
                 cost_model: Optional[ReduceCostModel] = None,
                 uniform: bool = True, calibrate: bool = False):
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown reduction strategy {strategy!r}; "
                             f"expected one of {STRATEGIES}")
        self.strategy = strategy
        self.mesh = mesh
        self.average = average
        # False for ragged layouts (unequal GMIs per GPU): no axis mesh
        # exists, so candidates() must stay in the mpr/har set
        self.uniform = uniform
        if grid is None and mesh is not None:
            grid = tuple(int(s) for s in mesh.devices.shape)
        self.grid = tuple(int(s) for s in grid) if grid is not None else None
        if cost_model is None:
            d = self.grid[2] if self.grid and len(self.grid) > 2 else 1
            cost_model = ReduceCostModel(dev_per_inst=d)
        self.cost_model = cost_model
        # strategy -> [ema_seconds, ema_bytes, observation_count]
        self._measured: Dict[str, list] = {}
        # measured-bandwidth calibration (opt-in): steady-state observe()
        # samples and channel-transfer timings accumulate here, and once
        # the Table-2 inversion is well conditioned estimate()/best() are
        # re-scored against the fitted bandwidths instead of the defaults
        self.calibrator: Optional[BandwidthCalibrator] = None
        self._calibrated: Optional[ReduceCostModel] = None
        self._calibrated_at = -1          # calibrator.version of the cache
        if calibrate:
            self.enable_calibration()

    # ------------------------------------------------------ construction --
    @classmethod
    def from_layout(cls, layout, *, cost_model: Optional[ReduceCostModel]
                    = None, average: bool = True, with_mesh: bool = False,
                    role: Optional[str] = None,
                    calibrate: bool = False) -> Optional["Communicator"]:
        """Build from a placement layout: grid off the trainer MPL (the
        dev axis off the GMIs' device counts), strategy from Algorithm 1 —
        or the Table-2 cost model when one is supplied.  Returns ``None``
        for a serving-only layout (no gradient to reduce).  ``with_mesh``
        additionally materializes ``instance_mesh`` so :meth:`allreduce`
        can run — only meaningful when the layout holds real devices.
        """
        mpl = layout.mpl
        if not mpl:
            return None
        mpl, grid, d, uniform, role = _layout_grid(layout, role)
        cm = cost_model if cost_model is not None \
            else ReduceCostModel(dev_per_inst=d)
        if cm.dev_per_inst != d:
            cm = dataclasses.replace(cm, dev_per_inst=d)
        strategy = select_reduction_strategy(
            mpl, cm if cost_model is not None else None)
        if strategy not in cm.candidates(grid, uniform):
            # Algorithm 1 is dev-blind: on a (g, t, d) grid its answer can
            # be infeasible (e.g. "mrr" when t*d > g breaks the one-ring-
            # endpoint-per-chip rule) — fall back to the cheapest feasible
            # candidate rather than construct an unswitchable state
            strategy = cm.best(grid, uniform)
        mesh = layout.manager.instance_mesh(role) if with_mesh else None
        return cls(strategy, mesh=mesh, grid=grid, average=average,
                   cost_model=cm, uniform=uniform, calibrate=calibrate)

    def rebind(self, layout) -> "Communicator":
        """Re-derive the instance grid from a re-planned layout IN PLACE
        (the controller and runner share this object).  Measured reduce
        times are cleared — they were taken against the old grid — and
        the active strategy is coerced to a feasible candidate of the new
        one (cost-scored best when the current choice no longer fits).
        CALIBRATION observations survive: bandwidths are machine
        properties, not layout properties, and every observation carries
        the grid it was measured on — only the calibrator's base model is
        refreshed to track the new dev axis.  The mesh, if any, is NOT
        rebuilt here: mesh-attached communicators belong to SPMD
        launchers that own their own re-layout."""
        mpl, grid, d, uniform, _ = _layout_grid(layout)
        self.grid = grid
        self.uniform = uniform
        if self.cost_model.dev_per_inst != d:
            self.cost_model = dataclasses.replace(self.cost_model,
                                                  dev_per_inst=d)
        self._measured.clear()
        if self.calibrator is not None:
            self.calibrator.base = self.cost_model
            self._calibrated_at = -1         # re-derive from the new base
        if self.strategy not in self.candidates():
            self.strategy = self.effective_cost_model.best(grid, uniform)
        return self

    # ---------------------------------------------------------- reduce ----
    @property
    def axes(self) -> Tuple[str, ...]:
        if self.mesh is not None:
            return tuple(self.mesh.axis_names)
        n = len(self.grid) if self.grid else 2
        return _DEFAULT_AXES[:n]

    @property
    def num_instances(self) -> int:
        if self.grid is None:
            return 1
        n = 1
        for s in self.grid:
            n *= s
        return n

    @property
    def grad_sync_fn(self):
        """Gradient-sync closure for the active strategy.

        Identity when no instance mesh is attached (a single logical
        instance, or the host-simulated multi-GMI loops where
        cross-instance sync happens at the parameter level).  With a mesh
        attached, this is the *in-SPMD* closure — it calls named-axis
        collectives and is only valid inside a shard_map/pjit body over
        that mesh (eager callers crash on unbound axis names; they want
        :meth:`allreduce` over grid-stacked gradients instead)."""
        if self.mesh is None:
            return lambda grads: grads
        return make_grad_sync(self.strategy, self.axes, average=self.average)

    def allreduce(self, grads):
        """Full LGR reduction of a (g, t[, d], ...) gradient grid over the
        attached instance mesh."""
        if self.mesh is None:
            raise ValueError(
                "Communicator has no instance mesh attached — build with "
                "from_layout(..., with_mesh=True) or pass mesh=")
        return lgr_allreduce(grads, self.mesh, self.strategy,
                             average=self.average)

    def reduce_host(self, grads_per_instance):
        """Host-staged MPR reduction (submesh/MIG-like backend)."""
        return mpr_host(grads_per_instance, average=self.average)

    # ------------------------------------------- measured-cost feedback ---
    def observe(self, seconds: float, nbytes: Optional[float] = None,
                strategy: Optional[str] = None):
        """Record one measured reduce round (EMA over rounds).  ``nbytes``
        defaults to the cost model's bytes-per-round when the caller
        cannot cheaply size the gradient tree.

        The FIRST observation per strategy is provisional: on any jitted
        path it is the compile round — exactly the stale one-off sample
        the ``switch()`` docstring warns about — so the second observation
        RESEEDS the EMA instead of averaging against it (a 100x compile
        round would otherwise contaminate the EMA for ~7 half-lives).
        Only steady-state samples (second onward) feed the calibrator.
        """
        s = strategy or self.strategy
        if nbytes is None:
            nbytes = self.cost_model.bytes_per_round
        rec = self._measured.get(s)
        if rec is None:
            self._measured[s] = [float(seconds), float(nbytes), 1]
            return
        if rec[2] == 1:
            # discard the provisional compile-round sample entirely
            self._measured[s] = [float(seconds), float(nbytes), 2]
        else:
            a = 0.5                          # smooth but responsive
            rec[0] = (1 - a) * rec[0] + a * float(seconds)
            rec[1] = (1 - a) * rec[1] + a * float(nbytes)
            rec[2] += 1
        if self.calibrator is not None and self.grid is not None:
            self.calibrator.add(s, self.grid, seconds, float(nbytes))

    def observe_transfer(self, seconds: float, nbytes: float):
        """Feed one per-round channel-transfer timing (experience moved
        over the instance-level domain) into the calibration fit as B1
        evidence.  No-op unless calibration is enabled."""
        if self.calibrator is not None:
            self.calibrator.add_transfer(seconds, nbytes)

    def measured(self, strategy: Optional[str] = None) -> Optional[float]:
        rec = self._measured.get(strategy or self.strategy)
        return rec[0] if rec else None

    def measurements(self) -> Dict[str, Tuple[float, float, int]]:
        """Per-strategy ``(ema_seconds, ema_bytes, count)`` snapshot of
        the live table (telemetry/inspection; the calibrator is fed
        sample by sample from ``observe()``, not from these EMAs)."""
        return {s: (rec[0], rec[1], rec[2])
                for s, rec in self._measured.items()}

    # --------------------------------------------------- calibration ------
    def enable_calibration(self, **knobs) -> BandwidthCalibrator:
        """Attach a :class:`BandwidthCalibrator` (idempotent).  From here
        on, steady-state ``observe()`` samples and ``observe_transfer()``
        timings accumulate toward a measured-bandwidth fit, and
        ``estimate()``/``best()``/``propose_switch()`` re-score against
        the calibrated model the moment it is well conditioned."""
        if self.calibrator is None:
            self.calibrator = BandwidthCalibrator(base=self.cost_model,
                                                  **knobs)
        return self.calibrator

    def calibrated_cost_model(self) -> Optional[ReduceCostModel]:
        """The measured-bandwidth ``ReduceCostModel``, or ``None`` while
        calibration is disabled or the fit is still ill-conditioned.
        Cached per calibrator version — refitting is cheap but not free
        on the per-round path."""
        if self.calibrator is None:
            return None
        if self._calibrated_at != self.calibrator.version:
            self._calibrated = self.calibrator.calibrated_model()
            self._calibrated_at = self.calibrator.version
        return self._calibrated

    @property
    def calibrated(self) -> bool:
        return self.calibrated_cost_model() is not None

    @property
    def effective_cost_model(self) -> ReduceCostModel:
        """What scoring actually runs against: the calibrated model once
        one exists, the static-default ``cost_model`` until then."""
        cm = self.calibrated_cost_model()
        return cm if cm is not None else self.cost_model

    def candidates(self):
        if self.grid is None:
            return [self.strategy]
        return self.effective_cost_model.candidates(self.grid, self.uniform)

    def estimate(self, strategy: Optional[str] = None,
                 nbytes: Optional[float] = None) -> float:
        """Table-2 predicted reduce seconds on this grid — against the
        calibrated bandwidths once the fit is conditioned."""
        if self.grid is None:
            raise ValueError("Communicator has no instance grid")
        return self.effective_cost_model.time(
            strategy or self.strategy, self.grid, nbytes)

    def propose_switch(self, min_gain: float = 1.05,
                       min_count: int = 3) -> Optional[str]:
        """The strategy the measured evidence says we should be running,
        or ``None`` to stay put.

        Candidates with their own steady-state measurements answer with
        measured time; unmeasured candidates answer with the Table-2
        estimate (calibrated bandwidths once available) scaled by the
        current strategy's measured/modelled ratio (so the model's
        absolute bandwidth guesses cancel out and only the *relative*
        Table-2 structure is trusted).  A switch needs ``min_count``
        observations of the current strategy — one GC pause or compile
        round must never trigger a drain-free switch — and the current
        measured time to exceed the best alternative by ``min_gain``,
        the same hysteresis the controller applies to layout re-plans.
        """
        cur = self._measured.get(self.strategy)
        if cur is None or self.grid is None or cur[2] < min_count:
            return None
        t_cur, nbytes, _ = cur
        model_cur = self.estimate(self.strategy, nbytes)
        scale = t_cur / model_cur if model_cur > 0.0 else 1.0
        best, best_t = self.strategy, t_cur
        for s in self.candidates():
            if s == self.strategy:
                continue
            rec = self._measured.get(s)
            # a candidate's lone sample is its compile round: fall back
            # to the scaled model until it has a steady-state record
            t_s = rec[0] if rec and rec[2] >= 2 \
                else self.estimate(s, nbytes) * scale
            if t_s < best_t:
                best, best_t = s, t_s
        if best != self.strategy and t_cur > min_gain * best_t:
            return best
        return None

    def propose_probe(self) -> Optional[str]:
        """A feasible candidate strategy the calibration fit still lacks
        measurements for, or ``None``.  The controller schedules the
        probe as an in-place strategy switch (Algorithm 2's explore step
        applied to communication): without it a fit over a single
        strategy stays ill-conditioned forever.  ``None`` while the
        CURRENT strategy's calibration cell is still filling — a probe
        in progress is left alone until it has the samples it was
        scheduled for, so every candidate is visited once, not bounced
        to and revisited.  Only meaningful while calibration is on."""
        if self.calibrator is None or self.grid is None:
            return None
        cur = self._measured.get(self.strategy)
        if cur is None or cur[2] < 2:
            return None              # measure where we stand first
        if self.calibrator.samples(self.strategy, self.grid) \
                < self.calibrator.min_count:
            return None              # current probe still collecting
        for s in self.candidates():
            if s == self.strategy:
                continue
            if self.calibrator.samples(s, self.grid) \
                    < self.calibrator.min_count:
                return s
        return None

    def switch(self, strategy: str) -> "Communicator":
        """Swap the active reduction strategy in place (the grad-sync
        closure follows through :attr:`grad_sync_fn`).  Mesh and cost
        model persist, and nothing about the caller's model/optimizer
        state is involved.  Measurements of OTHER strategies are dropped:
        a stale one-off sample (compile round, GC pause) would otherwise
        outrank the model forever and permanently exclude a strategy that
        is never active to re-measure itself.  Calibration observations
        persist — the fit wants evidence from every strategy, and its
        conditioning checks guard it against sparse cells.  Returns
        self."""
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown reduction strategy {strategy!r}; "
                             f"expected one of {STRATEGIES}")
        if self.grid is not None and strategy not in self.candidates():
            raise ValueError(
                f"strategy {strategy!r} is not feasible on instance grid "
                f"{self.grid} (candidates: {self.candidates()})")
        self.strategy = strategy
        self._measured = {k: v for k, v in self._measured.items()
                          if k == strategy}
        return self

    def __repr__(self):
        calib = "off" if self.calibrator is None else \
            ("fit" if self.calibrated else "collecting")
        return (f"Communicator(strategy={self.strategy!r}, grid={self.grid},"
                f" axes={self.axes}, average={self.average}, "
                f"measured={sorted(self._measured)}, calibration={calib})")


def as_grad_sync(fn_or_comm):
    """Normalize a grad-sync argument: a Communicator yields its closure,
    a callable (or None) passes through — the duck-typing that lets every
    pre-existing ``grad_sync_fn=`` call site keep working."""
    return getattr(fn_or_comm, "grad_sync_fn", fn_or_comm)
