"""Measured-bandwidth calibration — closing the telemetry loop into the
Table-2 cost model (ROADMAP: "feed measured per-axis bandwidths back into
the cost model instead of the static defaults").

The :class:`~repro.comm.select.ReduceCostModel` ships with static per-axis
bandwidth defaults (B1 instance-level domain, B2 cross-GPU interconnect,
B3 intra-instance chip links).  §5 of the paper argues strategy selection
must track the *actual* interconnect, and on hosts where those defaults
are wrong the model mis-ranks strategies systematically (on this machine
the host-staged mpr baseline wins while the defaults say otherwise).  The
measurements to fix that already exist: the :class:`~repro.comm.api.
Communicator` accumulates per-strategy ``(seconds, nbytes, count)``
records in ``observe()``, and ``MultiChannelPipeline`` times its per-round
channel transfers.  This module inverts the Table-2 recurrences over that
telemetry.

Every ``lgr_time_*`` form is linear in the INVERSE bandwidths::

    time(strategy, grid, Mp) = c1/B1 + c2/B2 + c3/B3

with ``(c1, c2, c3) = ReduceCostModel.coeffs(strategy, grid, Mp)`` — so a
set of measured ``(strategy, grid, Mp, seconds)`` observations is a linear
system ``A x = y`` in ``x = (1/B1, 1/B2, 1/B3)``.  The calibrator solves
it by relative-error-weighted least squares (rows are scaled by
``1/seconds`` so a 26 us mpr round and a 1.2 ms har round constrain the
fit equally in *relative* terms) and refuses to emit a model until the
system is well conditioned:

* at least ``min_strategies`` distinct evidence kinds (strategies, plus
  the channel-transfer stream) — a single strategy cannot separate the
  axes it mixes;
* at least ``min_count`` steady-state samples per (strategy, grid) cell
  (the Communicator already discards the compile-round first sample);
* full column rank over the bandwidth axes the observations actually
  touch AND at least one redundant equation (``rows > active axes`` —
  an exactly-determined system has zero residual by construction, so
  noise-corrupted timings would be accepted blindly), every fitted
  bandwidth positive and finite, and relative residual below
  ``max_rel_residual`` (a fit that cannot explain its own inputs must
  not steer strategy selection).

Axes with no evidence (e.g. B3 on a grid with no dev axis) keep the base
model's value — the emitted model is calibrated where measured and
default elsewhere, and :class:`FitResult.solved` says which is which.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.comm.select import ReduceCostModel

_AXES = ("B1", "B2", "B3")


@dataclass(frozen=True)
class FitResult:
    """One least-squares inversion of the Table-2 system."""
    bw_intra: float                # fitted (or base) B1
    bw_gpu: float                  # fitted (or base) B2
    bw_dev: float                  # fitted (or base) B3
    solved: Tuple[str, ...]        # subset of ("B1","B2","B3") actually fit
    strategies: Tuple[str, ...]    # distinct strategies that contributed
    n_obs: int                     # steady-state samples behind the fit
    rel_residual: float            # ||Ax - y|| / ||y|| in relative units

    def bandwidth(self, axis: str) -> float:
        return {"B1": self.bw_intra, "B2": self.bw_gpu,
                "B3": self.bw_dev}[axis]


@dataclass
class _Cell:
    """Running mean of one (strategy, grid) measurement stream."""
    seconds_sum: float = 0.0
    bytes_sum: float = 0.0
    count: int = 0

    def add(self, seconds: float, nbytes: float, count: int = 1):
        self.seconds_sum += float(seconds) * count
        self.bytes_sum += float(nbytes) * count
        self.count += count


class BandwidthCalibrator:
    """Fit effective B1/B2/B3 from measured reduce + transfer timings.

    ``base`` supplies the Table-2 coefficient forms and the fallback
    bandwidths for axes the observations cannot constrain; it is a plain
    attribute so a :class:`~repro.comm.api.Communicator` can keep it in
    sync across layout rebinds (observations survive a rebind — bandwidths
    are machine properties, not layout properties, and every observation
    carries the grid it was measured on).

    Knobs: ``min_count`` steady-state samples per cell before it enters
    the fit, ``min_strategies`` distinct evidence kinds before any fit is
    attempted, ``max_rel_residual`` refusal threshold on the relative
    residual, ``transfer_weight`` down-weight on channel-transfer rows
    (they carry pack/dispatch overhead the reduce rows do not).
    """

    def __init__(self, base: Optional[ReduceCostModel] = None, *,
                 min_count: int = 2, min_strategies: int = 2,
                 max_rel_residual: float = 0.35,
                 transfer_weight: float = 0.25,
                 use_transfers: bool = True):
        self.base = base if base is not None else ReduceCostModel()
        self.min_count = int(min_count)
        self.min_strategies = int(min_strategies)
        self.max_rel_residual = float(max_rel_residual)
        self.transfer_weight = float(transfer_weight)
        self.use_transfers = bool(use_transfers)
        self._obs: Dict[Tuple[str, Tuple[int, ...]], _Cell] = {}
        self._transfers = _Cell()
        # bumped on every new observation so consumers can cache fits
        self.version = 0

    # ---------------------------------------------------------- feeding ---
    def add(self, strategy: str, grid, seconds: float, nbytes: float,
            count: int = 1) -> None:
        """One steady-state reduce measurement of ``strategy`` on
        ``grid`` (callers are responsible for discarding compile-round
        samples — the Communicator's ``observe()`` does)."""
        if seconds <= 0.0 or nbytes <= 0.0:
            return
        key = (strategy, tuple(int(s) for s in grid))
        self._obs.setdefault(key, _Cell()).add(seconds, nbytes, count)
        self.version += 1

    def add_transfer(self, seconds: float, nbytes: float) -> None:
        """One per-round channel-transfer timing (MultiChannelPipeline):
        ``nbytes`` moved over the instance-level domain in ``seconds`` —
        direct (down-weighted) evidence on B1."""
        if seconds <= 0.0 or nbytes <= 0.0:
            return
        self._transfers.add(seconds, nbytes)
        self.version += 1

    # ------------------------------------------------------- inspection ---
    def samples(self, strategy: str, grid) -> int:
        cell = self._obs.get((strategy, tuple(int(s) for s in grid)))
        return cell.count if cell else 0

    @property
    def transfer_count(self) -> int:
        return self._transfers.count

    @property
    def n_obs(self) -> int:
        return sum(c.count for c in self._obs.values())

    def conditioned(self) -> bool:
        return self.fit() is not None

    # ------------------------------------------------------------- fit ----
    def _rows(self) -> Tuple[List, List, List, set]:
        rows, targets, weights, kinds = [], [], [], set()
        for (strat, grid), cell in sorted(self._obs.items()):
            if cell.count < self.min_count:
                continue
            sec = cell.seconds_sum / cell.count
            mp = cell.bytes_sum / cell.count
            if sec <= 0.0 or mp <= 0.0:
                continue
            try:
                c = self.base.coeffs(strat, grid, mp)
            except ValueError:      # e.g. har3 record against a d=1 base
                continue
            rows.append(c)
            targets.append(sec)
            weights.append(math.sqrt(cell.count))
            kinds.add(strat)
        if self.use_transfers and self._transfers.count >= self.min_count:
            sec = self._transfers.seconds_sum / self._transfers.count
            mp = self._transfers.bytes_sum / self._transfers.count
            if sec > 0.0 and mp > 0.0:
                rows.append((mp, 0.0, 0.0))
                targets.append(sec)
                weights.append(self.transfer_weight
                               * math.sqrt(self._transfers.count))
                kinds.add("transfer")
        return rows, targets, weights, kinds

    def fit(self) -> Optional[FitResult]:
        """Invert the observed Table-2 system; ``None`` while the system
        is ill-conditioned (see the class docstring for the criteria)."""
        rows, targets, weights, kinds = self._rows()
        if len(kinds) < self.min_strategies or not rows:
            return None
        A = np.asarray(rows, dtype=np.float64)
        y = np.asarray(targets, dtype=np.float64)
        # scale each equation by weight/target so the lstsq minimizes
        # weighted RELATIVE error: (A_i/y_i) x = 1, weighted
        w = np.asarray(weights, dtype=np.float64)
        Aw = A * (w / y)[:, None]
        yw = w
        active = [j for j in range(3) if np.any(np.abs(A[:, j]) > 0.0)]
        if not active or len(rows) <= len(active):
            # exactly-determined systems solve with zero residual no
            # matter how noisy the timings — demand redundancy so the
            # residual gate below can actually reject a poisoned fit
            return None
        Aa = Aw[:, active]
        if np.linalg.matrix_rank(Aa) < len(active):
            return None
        x, *_ = np.linalg.lstsq(Aa, yw, rcond=None)
        if not np.all(np.isfinite(x)) or np.any(x <= 0.0):
            return None
        resid = float(np.linalg.norm(Aa @ x - yw)
                      / max(np.linalg.norm(yw), 1e-300))
        if resid > self.max_rel_residual:
            return None
        bw = [self.base.bw_intra, self.base.bw_gpu, self.base.bw_dev]
        for j, xv in zip(active, x):
            bw[j] = 1.0 / float(xv)
        return FitResult(
            bw_intra=bw[0], bw_gpu=bw[1], bw_dev=bw[2],
            solved=tuple(_AXES[j] for j in active),
            strategies=tuple(sorted(kinds - {"transfer"})),
            n_obs=self.n_obs + self._transfers.count,
            rel_residual=resid)

    def calibrated_model(self) -> Optional[ReduceCostModel]:
        """A ``ReduceCostModel`` carrying the fitted bandwidths (base
        values on unsolved axes), or ``None`` while ill-conditioned."""
        fit = self.fit()
        if fit is None:
            return None
        return replace(self.base, bw_intra=fit.bw_intra,
                       bw_gpu=fit.bw_gpu, bw_dev=fit.bw_dev)

    def __repr__(self):
        cells = {f"{s}@{g}": c.count for (s, g), c in sorted(self._obs.items())}
        return (f"BandwidthCalibrator(cells={cells}, "
                f"transfers={self._transfers.count}, "
                f"conditioned={self.conditioned()})")
