"""repro.comm — the unified communication subsystem (paper §4.1).

Public surface:

* schedules — N-level LGR reduction schedules (``lgr_allreduce``,
  ``make_grad_sync``, ``flat_psum``, ``hierarchical_psum``, ``mpr_host``)
  over 2-axis (gpu, inst) and 3-axis (gpu, inst, dev) instance meshes;
* select — Algorithm-1 shape selection with an optional Table-2
  ``ReduceCostModel`` layered on top (``select_reduction_strategy``);
* api — the :class:`Communicator` object every training layer consumes
  instead of string-passing strategy names.

``repro.core.lgr`` remains as a thin deprecation shim over this package.
"""
from repro.comm.api import Communicator, as_grad_sync  # noqa: F401
from repro.comm.schedules import (STRATEGIES, flat_psum,  # noqa: F401
                                  hierarchical_psum, lgr_allreduce,
                                  make_grad_sync, mpr_host)
from repro.comm.select import (ReduceCostModel, algorithm1,  # noqa: F401
                               select_reduction_strategy)
