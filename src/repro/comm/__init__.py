"""repro.comm — the unified communication subsystem (paper §4.1).

Public surface:

* schedules — N-level LGR reduction schedules (``lgr_allreduce``,
  ``make_grad_sync``, ``flat_psum``, ``hierarchical_psum``, ``mpr_host``)
  over 2-axis (gpu, inst) and 3-axis (gpu, inst, dev) instance meshes;
* select — Algorithm-1 shape selection with an optional Table-2
  ``ReduceCostModel`` layered on top (``select_reduction_strategy``);
* api — the :class:`Communicator` object every training layer consumes
  instead of string-passing strategy names;
* calibrate — the :class:`BandwidthCalibrator` that inverts the Table-2
  recurrences over live telemetry, replacing the model's static per-axis
  bandwidth defaults with measured ones.

Calibration knobs
-----------------
``Communicator(..., calibrate=True)`` (or ``enable_calibration()``, or
``make_async_runner(..., calibrate=True)`` at the launch layer) attaches a
:class:`BandwidthCalibrator`.  From then on:

* every steady-state ``observe()`` sample (the compile-round first sample
  per strategy is discarded) and every ``observe_transfer()`` channel
  timing accumulates toward a least-squares fit of effective B1
  (instance-level domain), B2 (cross-GPU), and B3 (intra-instance dev)
  bandwidths — ``time = c1/B1 + c2/B2 + c3/B3`` per Table 2;
* ``calibrated_cost_model()`` returns the fitted ``ReduceCostModel`` once
  the system is well conditioned — and ``estimate()``, ``candidates()``,
  and ``propose_switch()`` silently re-score against it — or ``None``
  while it is not;
* ``propose_probe()`` names a feasible strategy the fit still lacks
  evidence for; the online controller schedules it as an in-place
  measurement, one visit per candidate — a probe in progress is left
  alone until its cell fills (Algorithm 2's explore step for
  communication).

``BandwidthCalibrator`` knobs: ``min_count`` (steady-state samples per
(strategy, grid) cell before it enters the fit, default 2),
``min_strategies`` (distinct evidence kinds before any fit, default 2 —
a single strategy cannot separate the axes it mixes),
``max_rel_residual`` (refuse fits that cannot explain their own inputs,
default 0.35), ``transfer_weight``/``use_transfers`` (down-weight or
disable the channel-transfer B1 evidence, defaults 0.25/on).

The old ``repro.core.lgr`` shim is gone; import from here directly.
"""
from repro.comm.api import Communicator, as_grad_sync  # noqa: F401
from repro.comm.calibrate import (BandwidthCalibrator,  # noqa: F401
                                  FitResult)
from repro.comm.schedules import (STRATEGIES, flat_psum,  # noqa: F401
                                  hierarchical_psum, lgr_allreduce,
                                  make_grad_sync, mpr_host)
from repro.comm.select import (ReduceCostModel, algorithm1,  # noqa: F401
                               select_reduction_strategy)
