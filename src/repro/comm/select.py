"""Reduction-strategy selection: Algorithm 1 (shape) + Table 2 (cost).

The paper's Algorithm 1 picks MPR/MRR/HAR from the trainer-GMI placement
list alone — a static shape test.  That is kept verbatim in
:func:`algorithm1` and remains the default.  Layered on top is a
Table-2-backed cost estimate (:class:`ReduceCostModel`): candidates that
are *feasible* for the layout are scored with measured bytes-per-round and
per-axis bandwidths, which is what lets the online controller revisit the
choice from live reduce-time measurements (the communication/compute
balance is workload-dependent — arXiv:2012.04210 — so strategy choice
belongs in the measured-cost loop, not a one-shot shape test).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.comm.schedules import STRATEGIES

# NOTE: repro.comm sits BELOW repro.core in the layering (core.placement
# imports this module), so the Table-2 time functions from
# repro.core.cost_model are imported lazily inside ReduceCostModel.time.


# ----------------------------------------------------------- Algorithm 1 ---
def algorithm1(mpl: List[List[int]]) -> str:
    """Paper Algorithm 1, verbatim logic.

    mpl[g] = list of (trainer) GMI ids on GPU g.
    Returns one of "mpr" | "mrr" | "har".
    """
    if not mpl or not any(mpl):
        # no trainer GMIs at all: there is no gradient to reduce, and
        # answering "mpr" would let a serving-only layout silently wire
        # up a reduction schedule
        raise ValueError(
            "empty MPL — a layout with no trainer GMIs has no reduction "
            "strategy")
    gmi_per_gpu = set()
    # all GMIs on the same GPU -> plain multi-process reduction
    if len(mpl) <= 1:
        return "mpr"
    for gmi_li in mpl:
        gmi_per_gpu.add(len(gmi_li))
    # different GPUs host different numbers of GMIs
    if len(gmi_per_gpu) > 1:
        return "har"
    # more GMIs per GPU than GPUs: MRR's final ring would need >1 endpoint
    # on one GPU ("multiple CUDA streams error" in NCCL; one ICI ring
    # endpoint per chip here)
    if gmi_per_gpu.pop() > len(mpl):
        return "har"
    return "mrr"


# -------------------------------------------------------- Table-2 scoring --
@dataclass(frozen=True)
class ReduceCostModel:
    """Table-2 reduce-time estimates over the strategy candidates.

    Bandwidths follow the repo's Table-2 convention: ``bw_intra`` (B1) is
    the instance-level domain (host-staged / shared-GPU traffic between
    GMIs), ``bw_gpu`` (B2) the cross-GPU interconnect, and ``bw_dev``
    (B3) the intra-instance chip links — the fastest tier, which only the
    3-level schedule exploits.  ``bytes_per_round`` is Mp, ideally the
    *measured* delivered gradient bytes per reduction round;
    ``dev_per_inst`` is the trailing ``dev``-axis size of the instance
    grid (1 for single-chip GMIs).
    """
    bw_intra: float = 5e9        # B1: inst-level (host/shared-GPU) domain
    bw_gpu: float = 200e9        # B2: cross-GPU interconnect
    bw_dev: float = 400e9        # B3: intra-instance chip links
    bytes_per_round: float = 4 * 1.5e6   # Mp: SH policy, f32 (Table 7/8)
    dev_per_inst: int = 1

    def candidates(self, grid: Sequence[int],
                   uniform: bool = True) -> List[str]:
        """Strategies feasible for a (g, t[, d]) instance grid.  MRR keeps
        Algorithm 1's one-ring-endpoint-per-chip constraint (t·d ≤ g and a
        rectangular layout); HAR3 needs a real dev axis."""
        g, t, d = _grid3(grid)
        cands = ["mpr"]
        if g > 1:
            cands.append("har")
            if uniform and t * d <= g:
                cands.append("mrr")
            if uniform and d > 1:
                cands.append("har3")
        return cands

    def coeffs(self, strategy: str, grid: Sequence[int],
               nbytes: Optional[float] = None) -> Tuple[float, float, float]:
        """Per-axis coefficients ``(c1, c2, c3)`` of the Table-2 form
        ``time == c1/bw_intra + c2/bw_gpu + c3/bw_dev`` on one grid, with
        the same axis-merging conventions as :meth:`time` (the 2-level
        forms run on the merged (inst, dev) plane).  This is the design
        row the :class:`~repro.comm.calibrate.BandwidthCalibrator`
        inverts — prediction and calibration share one source of truth.
        """
        from repro.core.cost_model import lgr_coeffs
        g, t, d = _grid3(grid)
        mp = float(nbytes if nbytes is not None else self.bytes_per_round)
        if strategy == "har3":
            if d <= 1:
                raise ValueError("har3 needs a dev axis (dev_per_inst > 1)")
            return lgr_coeffs("har3", g, t, d, mp)
        if strategy in ("mpr", "mrr", "har"):
            # 2-level: the merged (inst, dev) plane is the intra domain
            return lgr_coeffs(strategy, g, t * d, 1, mp)
        raise ValueError(f"unknown reduction strategy {strategy!r}; "
                         f"expected one of {STRATEGIES}")

    def time(self, strategy: str, grid: Sequence[int],
             nbytes: Optional[float] = None) -> float:
        """Predicted reduce seconds for one strategy on one grid."""
        c1, c2, c3 = self.coeffs(strategy, grid, nbytes)
        return c1 / self.bw_intra + c2 / self.bw_gpu + c3 / self.bw_dev

    def best(self, grid: Sequence[int], uniform: bool = True,
             nbytes: Optional[float] = None) -> str:
        return min(self.candidates(grid, uniform),
                   key=lambda s: self.time(s, grid, nbytes))


def _grid3(grid: Sequence[int]) -> Tuple[int, int, int]:
    g, t = int(grid[0]), int(grid[1])
    d = int(grid[2]) if len(grid) > 2 else 1
    return g, t, max(d, 1)


# --------------------------------------------------------- public entry ----
def select_reduction_strategy(mpl: List[List[int]],
                              cost_model: Optional[ReduceCostModel] = None) \
        -> str:
    """Pick the reduction strategy for a trainer placement list.

    ``cost_model=None`` (every pre-existing caller) is Algorithm 1
    verbatim.  With a :class:`ReduceCostModel`, the (g, t) grid is read
    off the MPL, the dev axis off the model, and the cheapest *feasible*
    candidate wins — mpr/mrr/har/har3 scored with Table-2 times over the
    model's bytes-per-round and per-axis bandwidths.  Non-rectangular
    layouts keep Algorithm 1's constraint set (mpr/har only: the axis
    backend cannot even build a mesh for them).
    """
    shape_choice = algorithm1(mpl)          # also rejects an empty MPL
    if cost_model is None:
        return shape_choice
    g = len(mpl)
    per_gpu = {len(row) for row in mpl}
    uniform = len(per_gpu) == 1
    grid = (g, max(per_gpu), cost_model.dev_per_inst)
    if not uniform:
        # a ragged layout cannot build an axis mesh at all: candidates()
        # already restricts to the host-staged baseline and the
        # host-orchestrated hierarchy (mpr/har)
        feasible = cost_model.candidates(grid, uniform=False)
        return min(feasible, key=lambda s: cost_model.time(s, grid))
    return cost_model.best(grid, uniform=True)
