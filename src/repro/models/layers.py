"""Core NN layers: RMSNorm, RoPE, embeddings, MLPs (pure-functional JAX).

Params are plain nested dicts of jnp arrays; every layer is an
``init_*(key, ...) -> params`` / ``apply(params, x, ...)`` pair so stacks can
be built with ``jax.lax.scan`` over stacked parameter pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import lecun_init


# ---------------------------------------------------------------- norms ----
def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


def init_layernorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ----------------------------------------------------------------- rope ----
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., seq, hd/2)
    sin = jnp.sin(ang)[..., None, :]                  # broadcast over heads
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ softcap ------
def softcap(x, cap):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ------------------------------------------------------------ linear -------
def init_linear(key, d_in: int, d_out: int, bias: bool = False):
    p = {"w": lecun_init(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------- mlp ------
def init_mlp(key, d: int, d_ff: int, act: str = "silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("silu", "swiglu"):
        return {"wi": lecun_init(k1, (d, d_ff)),
                "wg": lecun_init(k2, (d, d_ff)),
                "wo": lecun_init(k3, (d_ff, d))}
    return {"wi": lecun_init(k1, (d, d_ff)),
            "wo": lecun_init(k3, (d_ff, d))}


def mlp(params, x, act: str = "silu"):
    dt = x.dtype
    h = x @ params["wi"].astype(dt)
    if "wg" in params:
        g = x @ params["wg"].astype(dt)
        h = jax.nn.silu(g) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.silu(h)
    return h @ params["wo"].astype(dt)


# ------------------------------------------------------- embeddings --------
def init_embedding(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d)) * 0.02}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    return x @ params["table"].T.astype(x.dtype)
