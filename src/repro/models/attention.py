"""GQA attention with sliding-window, logit softcap, QKV-bias, KV caches.

Two execution paths:
  * ``direct``  — materializes (…, Sq, Skv) scores; used for small sequences
    and as the oracle.
  * ``chunked`` — flash-style double-blocked online softmax expressed with
    ``jax.lax.scan`` (O(block²) live scores); used for long sequences so the
    32k/500k dry-run shapes fit HBM.  The Pallas kernel in
    ``repro.kernels.flash_attention`` is the TPU-tiled version of the same
    algorithm.

Caches:
  * full cache  — (B, S, n_kv, hd) k/v with write index = absolute position.
  * ring cache  — (B, W, n_kv, hd) sliding-window ring buffer plus a
    ``slot_pos`` (B, W) absolute-position map, for ``long_500k`` decode.
  * paged cache — a batch-free pool of fixed-size pages
    (num_pages, page, n_kv, hd) addressed through a per-request page table
    (B, M): virtual page v of a request holds absolute positions
    ``[v*page, (v+1)*page)`` regardless of any sliding window (the window
    applies purely through ``_mask``), so a gathered table row reproduces
    the full-depth cache layout exactly.  Page 0 is the trash page: writes
    from idle rows and unmapped virtual pages land there and stay masked
    (its ``slot_pos`` is only ever written -1).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, init_linear, linear, softcap

NEG_INF = -1e30


class KVCache(NamedTuple):
    """KV cache; ring-buffer and linear caches are unified: writes always go
    to slot ``pos % W`` and masking always reads absolute positions from
    ``slot_pos`` (for a full-length cache pos % W == pos)."""
    k: jax.Array          # (B, S_or_W, n_kv, hd)
    v: jax.Array
    slot_pos: jax.Array   # (B, S_or_W) absolute position in each slot (-1 empty)


class PagedKVCache(NamedTuple):
    """Paged KV cache: a shared physical pool of fixed-size pages plus the
    absolute position each page slot holds.  Batch-free — requests address
    it through a page table (B, M) owned by the serving engine."""
    k_pages: jax.Array     # (num_pages, page, n_kv, hd)
    v_pages: jax.Array
    slot_pos: jax.Array    # (num_pages, page) absolute position (-1 empty)


def init_attention_params(key, d_model: int, num_heads: int, num_kv_heads: int,
                          head_dim: int, qkv_bias: bool = False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_linear(kq, d_model, num_heads * head_dim, qkv_bias),
        "wk": init_linear(kk, d_model, num_kv_heads * head_dim, qkv_bias),
        "wv": init_linear(kv, d_model, num_kv_heads * head_dim, qkv_bias),
        "wo": init_linear(ko, num_heads * head_dim, d_model, False),
    }


def make_cache(batch: int, seq: int, n_kv: int, head_dim: int,
               window: Optional[int] = None, dtype=jnp.float32) -> KVCache:
    size = min(seq, window) if window else seq
    return KVCache(
        k=jnp.zeros((batch, size, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, size, n_kv, head_dim), dtype),
        slot_pos=jnp.full((batch, size), -1, jnp.int32),
    )


def make_paged_cache(num_pages: int, page: int, n_kv: int, head_dim: int,
                     dtype=jnp.float32) -> PagedKVCache:
    return PagedKVCache(
        k_pages=jnp.zeros((num_pages, page, n_kv, head_dim), dtype),
        v_pages=jnp.zeros((num_pages, page, n_kv, head_dim), dtype),
        slot_pos=jnp.full((num_pages, page), -1, jnp.int32),
    )


def paged_write(cache: PagedKVCache, page_table, positions, k, v):
    """Scatter k/v (B, S, KH, hd) at absolute ``positions`` (B, S) into the
    pool through ``page_table`` (B, M).  Negative positions and unmapped
    virtual pages route to the trash page 0 with slot_pos -1."""
    P = cache.k_pages.shape[1]
    M = page_table.shape[-1]
    ok = positions >= 0
    safe = jnp.where(ok, positions, 0)
    vp = jnp.clip(safe // P, 0, M - 1)
    off = safe % P
    phys = jnp.take_along_axis(page_table, vp, axis=1)       # (B, S)
    ok &= phys >= 0
    phys = jnp.where(ok, phys, 0)
    ck = cache.k_pages.at[phys, off].set(k.astype(cache.k_pages.dtype))
    cv = cache.v_pages.at[phys, off].set(v.astype(cache.v_pages.dtype))
    cp = cache.slot_pos.at[phys, off].set(jnp.where(ok, positions, -1))
    return PagedKVCache(ck, cv, cp)


def paged_gather(cache: PagedKVCache, page_table):
    """Gather each row's pages into position order: (B, M*page, KH, hd)
    k/v plus (B, M*page) kpos (-1 where the virtual page is unmapped).
    Row j of the gathered view is absolute position j, so it reproduces
    the dense full-depth cache layout exactly."""
    P = cache.k_pages.shape[1]
    B, M = page_table.shape
    tsafe = jnp.maximum(page_table, 0)
    KH, hd = cache.k_pages.shape[2], cache.k_pages.shape[3]
    k = cache.k_pages[tsafe].reshape(B, M * P, KH, hd)
    v = cache.v_pages[tsafe].reshape(B, M * P, KH, hd)
    kpos = jnp.where(jnp.repeat(page_table >= 0, P, axis=1),
                     cache.slot_pos[tsafe].reshape(B, M * P), -1)
    return k, v, kpos


# --------------------------------------------------------------------------
def _mask(qpos, kpos, causal: bool, window):
    """qpos: (..., Sq), kpos: (..., Skv) -> bool (..., Sq, Skv)."""
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    valid = k >= 0
    if causal:
        valid &= k <= q
    if window is not None:
        valid &= k > q - window
    return valid


def _direct_attention(q, k, v, qpos, kpos, causal, window, cap, scale):
    """q: (B,Sq,H,hd)  k/v: (B,Skv,KH,hd).

    k/v stay in their storage dtype (casting a 32k-deep KV cache to f32
    costs GiBs of HBM per layer); the MXU accumulates in f32 via
    ``preferred_element_type``."""
    B, Sq, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    qf = (q * scale).astype(k.dtype).reshape(B, Sq, KH, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, k,
                        preferred_element_type=jnp.float32)
    scores = softcap(scores, cap)
    m = _mask(qpos, kpos, causal, window)              # (B?,Sq,Skv)
    m = m[:, None, None] if m.ndim == 3 else m[None, None, None]
    scores = jnp.where(m, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _chunked_attention(q, k, v, qpos, kpos, causal, window, cap, scale,
                       q_block: int = 512, kv_block: int = 1024):
    """Flash-style blocked attention with online softmax (pure lax.scan)."""
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    nq = -(-Sq // qb)
    nk = -(-Skv // kb)
    pq = nq * qb - Sq
    pk = nk * kb - Skv
    # pad; padded key slots get kpos = -1 so the mask kills them
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qposp = jnp.pad(qpos, [(0, 0)] * (qpos.ndim - 1) + [(0, pq)])
    kposp = jnp.pad(kpos, [(0, 0)] * (kpos.ndim - 1) + [(0, pk)],
                    constant_values=-1)
    qp = qp.reshape(B, nq, qb, H, hd).transpose(1, 0, 2, 3, 4)
    kp = kp.reshape(B, nk, kb, KH, hd).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(B, nk, kb, KH, hd).transpose(1, 0, 2, 3, 4)
    qposp = jnp.broadcast_to(qposp, (B, nq * qb)).reshape(B, nq, qb).transpose(1, 0, 2)
    kposp = jnp.broadcast_to(kposp, (B, nk * kb)).reshape(B, nk, kb).transpose(1, 0, 2)

    def q_step(_, qc):
        qi, qpi = qc                                    # (B,qb,H,hd), (B,qb)
        qf = (qi * scale).astype(k.dtype).reshape(B, qb, KH, G, hd)

        def kv_step(carry, kc):
            m_prev, l_prev, acc = carry
            ki, vi, kpi = kc
            s = jnp.einsum("bqkgd,bskd->bkgqs", qf, ki,
                           preferred_element_type=jnp.float32)
            s = softcap(s, cap)
            msk = _mask(qpi, kpi, causal, window)[:, None, None]
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, KH, G, qb), NEG_INF, jnp.float32),
                jnp.zeros((B, KH, G, qb), jnp.float32),
                jnp.zeros((B, KH, G, qb, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (kp, vp, kposp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4).reshape(B, qb, H, hd)

    _, outs = jax.lax.scan(q_step, None, (qp, qposp))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * qb, H, hd)
    return out[:, :Sq].astype(q.dtype)


# --------------------------------------------------------------------------
def attention(params, x, *, num_heads: int, num_kv_heads: int, head_dim: int,
              positions, causal: bool = True, window: Optional[int] = None,
              attn_cap: Optional[float] = None, rope_theta: float = 10_000.0,
              cache: Optional[KVCache] = None,
              chunked_threshold: int = 4096,
              use_rope: bool = True,
              page_table=None, paged_kernel: bool = False):
    """Full attention block.  x: (B, S, D); positions: (B, S) or (S,).

    If ``cache`` is given and S == 1 this is a decode step: write k/v into the
    cache at ``positions`` and attend over the cache.  If cache is given with
    S > 1 (prefill) the cache is filled and returned.

    A :class:`PagedKVCache` requires ``page_table`` (B, M) and supports both
    S == 1 (paged decode: write the step's k/v through the table, attend
    over the gathered pages) and S > 1 (chunked prefill: write the whole
    chunk at absolute positions, then attend the chunk's queries over the
    gathered pages — the just-written in-chunk keys included, with the
    causal mask handling intra-chunk order).  ``paged_kernel=True`` routes
    the S == 1 paged read through the Pallas gather-decode kernel
    (``repro.kernels.paged_decode``) instead of the jnp gather.
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None], (B, S))
    q = linear(params["wq"], x).reshape(B, S, num_heads, head_dim)
    k = linear(params["wk"], x).reshape(B, S, num_kv_heads, head_dim)
    v = linear(params["wv"], x).reshape(B, S, num_kv_heads, head_dim)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    scale = head_dim ** -0.5

    new_cache = cache
    if isinstance(cache, PagedKVCache):
        if page_table is None:
            raise ValueError("paged cache requires a page_table")
        new_cache = paged_write(cache, page_table, positions, k, v)
        if S == 1 and paged_kernel:
            from repro.kernels import ops
            o = ops.paged_attention(
                q[:, 0], new_cache.k_pages, new_cache.v_pages,
                new_cache.slot_pos, page_table, positions[:, 0],
                window=window, softcap=attn_cap, scale=scale)
            out = linear(params["wo"], o.reshape(B, 1, num_heads * head_dim))
            return out, new_cache
        k_all, v_all, kpos = paged_gather(new_cache, page_table)
    elif cache is not None and S == 1:
        # decode: write this step's k/v into its ring slot, attend over cache
        W = cache.k.shape[1]
        slots = positions % W                                # (B,1)
        bidx = jnp.arange(B)[:, None]
        ck = cache.k.at[bidx, slots].set(k.astype(cache.k.dtype))
        cv = cache.v.at[bidx, slots].set(v.astype(cache.v.dtype))
        cp = cache.slot_pos.at[bidx, slots].set(positions)
        new_cache = KVCache(ck, cv, cp)
        k_all, v_all, kpos = ck, cv, cp
    elif cache is not None:
        # prefill: attend over the fresh in-context k/v (a ring cache cannot
        # hold S > W simultaneous writes); persist only the last W positions,
        # which is exactly what windowed decode will ever read.
        W = cache.k.shape[1]
        n = min(S, W)
        k_tail, v_tail, p_tail = k[:, -n:], v[:, -n:], positions[:, -n:]
        slots = p_tail % W
        bidx = jnp.arange(B)[:, None]
        ck = cache.k.at[bidx, slots].set(k_tail.astype(cache.k.dtype))
        cv = cache.v.at[bidx, slots].set(v_tail.astype(cache.v.dtype))
        cp = cache.slot_pos.at[bidx, slots].set(p_tail)
        new_cache = KVCache(ck, cv, cp)
        k_all, v_all, kpos = k, v, positions
    else:
        k_all, v_all, kpos = k, v, positions

    Skv = k_all.shape[1]
    if max(S, Skv) > chunked_threshold and S > 1:
        out = _chunked_attention(q, k_all, v_all, positions, kpos,
                                 causal, window, attn_cap, scale)
    else:
        out = _direct_attention(q, k_all, v_all, positions, kpos,
                                causal, window, attn_cap, scale)
    out = linear(params["wo"], out.reshape(B, S, num_heads * head_dim))
    return out, new_cache
