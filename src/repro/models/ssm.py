"""Recurrent blocks: mLSTM / sLSTM (xLSTM, arXiv:2405.04517) and Mamba2
(SSD, used by zamba2, arXiv:2411.15242).

Both mLSTM and Mamba2 share a chunkwise-parallel skeleton ("masked linear
attention inside a chunk + recurrent state across chunks"), giving O(S·L)
memory instead of O(S²).  Decode uses the exact recurrent update; tests
assert chunkwise == recurrent.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import lecun_init

LOG_EPS = -1e30


# ---------------------------------------------------------------- conv -----
def init_conv1d(key, channels: int, width: int):
    return {"w": lecun_init(key, (width, channels), fan_in=width),
            "b": jnp.zeros((channels,), jnp.float32)}


def causal_conv1d(params, x, state=None):
    """Depthwise causal conv.  x: (B,S,C).  state: (B,W-1,C) prior inputs.

    Returns (y, new_state) where new_state holds the last W-1 inputs.
    """
    W = params["w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    # depthwise: y[t] = sum_j w[j] * xp[t+j]
    y = sum(xp[:, j:j + x.shape[1]] * params["w"][j].astype(x.dtype)
            for j in range(W))
    y = y + params["b"].astype(x.dtype)
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return jax.nn.silu(y), new_state


# ================================================================= mLSTM ===
class MLSTMState(NamedTuple):
    C: jax.Array      # (B,H,dh,dh) matrix memory
    n: jax.Array      # (B,H,dh)
    m: jax.Array      # (B,H) log-space stabilizer
    conv: jax.Array   # (B,W-1,Di) conv state


def init_mlstm(key, d_model: int, num_heads: int, expansion: int = 2,
               conv_width: int = 4):
    di = d_model * expansion
    ks = jax.random.split(key, 8)
    return {
        "norm": {"scale": jnp.ones((d_model,), jnp.float32)},
        "w_up": lecun_init(ks[0], (d_model, 2 * di)),          # x path + z gate
        "conv": init_conv1d(ks[1], di, conv_width),
        "wq": lecun_init(ks[2], (di, di)),
        "wk": lecun_init(ks[3], (di, di)),
        "wv": lecun_init(ks[4], (di, di)),
        "w_if": lecun_init(ks[5], (di, 2 * num_heads)),        # i,f gate preacts
        "b_if": jnp.zeros((2 * num_heads,), jnp.float32),
        "gnorm": {"scale": jnp.ones((di,), jnp.float32)},
        "w_down": lecun_init(ks[6], (di, d_model)),
    }


def mlstm_init_state(batch: int, num_heads: int, dh: int, di: int,
                     conv_width: int = 4, dtype=jnp.float32) -> MLSTMState:
    return MLSTMState(
        C=jnp.zeros((batch, num_heads, dh, dh), jnp.float32),
        n=jnp.zeros((batch, num_heads, dh), jnp.float32),
        m=jnp.full((batch, num_heads), 0.0, jnp.float32),
        conv=jnp.zeros((batch, conv_width - 1, di), dtype))


def _mlstm_chunk(q, k, v, log_i, log_f, state_C, state_n, state_m):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,L,dh); log_i/log_f: (B,H,L).
    Returns (h (B,H,L,dh), C', n', m').
    """
    B, H, L, dh = q.shape
    b = jnp.cumsum(log_f, axis=-1)                            # (B,H,L) inclusive
    # intra-chunk log weights: D[t,s] = b_t - b_s + log_i_s  (s <= t)
    lw = b[..., :, None] - b[..., None, :] + log_i[..., None, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    lw = jnp.where(causal, lw, LOG_EPS)
    # inter-chunk log weight for reading the carried state
    inter = state_m[..., None] + b                             # (B,H,L)
    m_t = jnp.maximum(inter, jnp.max(lw, axis=-1))             # (B,H,L)
    w_intra = jnp.exp(lw - m_t[..., None])                     # (B,H,L,L)
    w_inter = jnp.exp(inter - m_t)                             # (B,H,L)
    scale = dh ** -0.5
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale * w_intra
    # C stores v⊗k (C[d,e] = v_d k_e); reading contracts q with the k-dim (e)
    h_num = jnp.einsum("bhts,bhsd->bhtd", scores, v) \
        + w_inter[..., None] * jnp.einsum("bhte,bhde->bhtd", q * scale, state_C)
    n_t = jnp.einsum("bhts,bhsd->bhtd", w_intra, k) \
        + w_inter[..., None] * state_n[..., None, :]
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", q * scale, n_t)),
                        jnp.exp(-m_t))
    h = h_num / denom[..., None]
    # carry state to chunk end (position L-1, inclusive decay b[...,-1])
    bl = b[..., -1]                                            # (B,H)
    m_new = jnp.maximum(state_m + bl, jnp.max(log_i + (bl[..., None] - b),
                                              axis=-1))
    w_c = jnp.exp(log_i + bl[..., None] - b - m_new[..., None])   # (B,H,L)
    C_new = jnp.exp(state_m + bl - m_new)[..., None, None] * state_C + \
        jnp.einsum("bhs,bhsd,bhse->bhde", w_c, v, k)
    n_new = jnp.exp(state_m + bl - m_new)[..., None] * state_n + \
        jnp.einsum("bhs,bhsd->bhd", w_c, k)
    return h, C_new, n_new, m_new


def mlstm_apply(params, x, *, num_heads: int, state: MLSTMState = None,
                chunk: int = 256, expansion: int = 2):
    """mLSTM block.  x: (B,S,D) -> (out, new_state)."""
    B, S, D = x.shape
    from repro.models.layers import rms_norm
    di = D * expansion
    dh = di // num_heads
    h_in = rms_norm(params["norm"], x)
    up = h_in @ params["w_up"].astype(x.dtype)
    x_in, z = jnp.split(up, 2, axis=-1)
    conv_state = state.conv if state is not None else None
    x_c, conv_new = causal_conv1d(params["conv"], x_in, conv_state)

    def heads(t, w):
        return (t @ w.astype(t.dtype)).reshape(B, S, num_heads, dh).transpose(0, 2, 1, 3)

    q = heads(x_c, params["wq"]).astype(jnp.float32)
    k = heads(x_c, params["wk"]).astype(jnp.float32)
    v = heads(x_in, params["wv"]).astype(jnp.float32)
    if_pre = (x_c @ params["w_if"].astype(x.dtype)) + params["b_if"].astype(x.dtype)
    if_pre = if_pre.reshape(B, S, 2, num_heads).transpose(0, 3, 1, 2).astype(jnp.float32)
    log_i = if_pre[..., 0]                                     # (B,H,S)
    log_f = jax.nn.log_sigmoid(if_pre[..., 1])

    if state is None:
        state = mlstm_init_state(B, num_heads, dh, di, params["conv"]["w"].shape[0],
                                 x.dtype)

    L = min(chunk, S)
    if S % L:
        raise ValueError(f"seq {S} not divisible by chunk {L}")
    nc = S // L

    def step(carry, xs):
        C, n, m = carry
        qc, kc, vc, lic, lfc = xs
        h, C, n, m = _mlstm_chunk(qc, kc, vc, lic, lfc, C, n, m)
        return (C, n, m), h

    xs = tuple(t.reshape(B, num_heads, nc, L, -1).transpose(2, 0, 1, 3, 4)
               for t in (q, k, v)) + tuple(
        t.reshape(B, num_heads, nc, L).transpose(2, 0, 1, 3)
        for t in (log_i, log_f))
    (C, n, m), hs = jax.lax.scan(step, (state.C, state.n, state.m), xs)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, num_heads, S, dh)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    h = rms_norm(params["gnorm"], h)
    out = (h * jax.nn.silu(z)) @ params["w_down"].astype(x.dtype)
    return x + out, MLSTMState(C, n, m, conv_new)


def mlstm_decode_step(params, x, state: MLSTMState, *, num_heads: int,
                      expansion: int = 2):
    """Exact recurrent single step.  x: (B,1,D)."""
    B, _, D = x.shape
    from repro.models.layers import rms_norm
    di = D * expansion
    dh = di // num_heads
    h_in = rms_norm(params["norm"], x)
    up = h_in @ params["w_up"].astype(x.dtype)
    x_in, z = jnp.split(up, 2, axis=-1)
    x_c, conv_new = causal_conv1d(params["conv"], x_in, state.conv)

    def head(t, w):
        return (t @ w.astype(t.dtype)).reshape(B, num_heads, dh)

    q = head(x_c[:, 0], params["wq"]).astype(jnp.float32) * dh ** -0.5
    k = head(x_c[:, 0], params["wk"]).astype(jnp.float32)
    v = head(x_in[:, 0], params["wv"]).astype(jnp.float32)
    if_pre = (x_c[:, 0] @ params["w_if"].astype(x.dtype)) + params["b_if"].astype(x.dtype)
    if_pre = if_pre.reshape(B, 2, num_heads).astype(jnp.float32)
    log_i, log_f = if_pre[:, 0], jax.nn.log_sigmoid(if_pre[:, 1])
    m_new = jnp.maximum(log_f + state.m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state.m - m_new)
    C = f_s[..., None, None] * state.C + i_s[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", v, k)
    n = f_s[..., None] * state.n + i_s[..., None] * k
    num = jnp.einsum("bhe,bhde->bhd", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, di).astype(x.dtype)
    h = rms_norm(params["gnorm"], h)
    out = (h * jax.nn.silu(z)) @ params["w_down"].astype(x.dtype)
    return x + out, MLSTMState(C, n, m_new, conv_new)


# ================================================================= sLSTM ===
class SLSTMState(NamedTuple):
    c: jax.Array   # (B,H,dh)
    n: jax.Array
    h: jax.Array
    m: jax.Array   # (B,H,dh)


def init_slstm(key, d_model: int, num_heads: int):
    dh = d_model // num_heads
    ks = jax.random.split(key, 4)
    return {
        "norm": {"scale": jnp.ones((d_model,), jnp.float32)},
        "w": lecun_init(ks[0], (d_model, 4 * d_model)),        # i,f,z,o preacts
        "r": lecun_init(ks[1], (num_heads, dh, 4 * dh), fan_in=dh),  # recurrent
        "b": jnp.zeros((4 * d_model,), jnp.float32),
        "gnorm": {"scale": jnp.ones((d_model,), jnp.float32)},
        "w_up": lecun_init(ks[2], (d_model, 2 * d_model)),
        "w_down": lecun_init(ks[3], (d_model, d_model)),
    }


def slstm_init_state(batch: int, num_heads: int, dh: int) -> SLSTMState:
    z = jnp.zeros((batch, num_heads, dh), jnp.float32)
    return SLSTMState(z, z, z, z)


def _slstm_cell(params, x_pre, state: SLSTMState, num_heads: int):
    """x_pre: (B, 4*D) input preactivations for one timestep."""
    B = x_pre.shape[0]
    D4 = x_pre.shape[-1]
    dh = D4 // 4 // num_heads
    rec = jnp.einsum("bhd,hde->bhe", state.h, params["r"].astype(jnp.float32))
    pre = x_pre.astype(jnp.float32).reshape(B, num_heads, 4 * dh) + rec
    i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(jax.nn.log_sigmoid(f_p) + state.m, i_p)
    i_g = jnp.exp(i_p - m_new)
    f_g = jnp.exp(jax.nn.log_sigmoid(f_p) + state.m - m_new)
    c = f_g * state.c + i_g * jnp.tanh(z_p)
    n = f_g * state.n + i_g
    h = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, h, m_new)


def slstm_apply(params, x, *, num_heads: int, state: SLSTMState = None):
    """sLSTM block (inherently sequential).  x: (B,S,D) -> (out, state)."""
    B, S, D = x.shape
    from repro.models.layers import rms_norm
    dh = D // num_heads
    if state is None:
        state = slstm_init_state(B, num_heads, dh)
    h_in = rms_norm(params["norm"], x)
    x_pre = h_in @ params["w"].astype(x.dtype) + params["b"].astype(x.dtype)

    def step(st, xp):
        st = _slstm_cell(params, xp, st, num_heads)
        return st, st.h

    state, hs = jax.lax.scan(step, state, x_pre.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    h = rms_norm(params["gnorm"], h)
    up, gate = jnp.split(h @ params["w_up"].astype(x.dtype), 2, axis=-1)
    out = (up * jax.nn.gelu(gate)) @ params["w_down"].astype(x.dtype)
    return x + out, state


# ================================================================= Mamba2 ==
class Mamba2State(NamedTuple):
    h: jax.Array      # (B,H,dh,N) ssm state
    conv: jax.Array   # (B,W-1,C) conv state


def init_mamba2(key, d_model: int, state_dim: int, *, expansion: int = 2,
                head_dim: int = 64, conv_width: int = 4):
    di = d_model * expansion
    nheads = di // head_dim
    ks = jax.random.split(key, 5)
    conv_ch = di + 2 * state_dim
    return {
        "norm": {"scale": jnp.ones((d_model,), jnp.float32)},
        # projects to [z(di), x(di), B(N), C(N), dt(nheads)]
        "w_in": lecun_init(ks[0], (d_model, 2 * di + 2 * state_dim + nheads)),
        "conv": init_conv1d(ks[1], conv_ch, conv_width),
        "A_log": jnp.zeros((nheads,), jnp.float32),            # A = -exp(A_log)
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "gnorm": {"scale": jnp.ones((di,), jnp.float32)},
        "w_out": lecun_init(ks[2], (di, d_model)),
    }


def mamba2_init_state(batch: int, di: int, state_dim: int, head_dim: int = 64,
                      conv_width: int = 4, dtype=jnp.float32) -> Mamba2State:
    nheads = di // head_dim
    return Mamba2State(
        h=jnp.zeros((batch, nheads, head_dim, state_dim), jnp.float32),
        conv=jnp.zeros((batch, conv_width - 1, di + 2 * state_dim), dtype))


def _mamba2_proj(params, x, di, state_dim, nheads):
    h_in_norm = x
    zxbcdt = h_in_norm @ params["w_in"].astype(x.dtype)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * state_dim]
    dt_pre = zxbcdt[..., -nheads:]
    return z, xbc, dt_pre


def mamba2_apply(params, x, *, state_dim: int, state: Mamba2State = None,
                 expansion: int = 2, head_dim: int = 64, chunk: int = 256):
    """Mamba2 (SSD) block.  x: (B,S,D) -> (out, new_state)."""
    B, S, D = x.shape
    from repro.models.layers import rms_norm
    di = D * expansion
    nheads = di // head_dim
    N = state_dim
    h_in = rms_norm(params["norm"], x)
    z, xbc, dt_pre = _mamba2_proj(params, h_in, di, N, nheads)
    conv_state = state.conv if state is not None else None
    xbc_c, conv_new = causal_conv1d(params["conv"], xbc, conv_state)
    xs = xbc_c[..., :di].astype(jnp.float32)
    Bmat = xbc_c[..., di:di + N].astype(jnp.float32)           # (B,S,N)
    Cmat = xbc_c[..., di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) +
                         params["dt_bias"])                     # (B,S,H)
    A = -jnp.exp(params["A_log"])                               # (H,)
    log_decay = (dt * A).transpose(0, 2, 1)                     # (B,H,S)
    xh = xs.reshape(B, S, nheads, head_dim).transpose(0, 2, 1, 3)  # (B,H,S,dh)
    xh_dt = xh * dt.transpose(0, 2, 1)[..., None]

    if state is None:
        state = mamba2_init_state(B, di, N, head_dim,
                                  params["conv"]["w"].shape[0], x.dtype)

    L = min(chunk, S)
    if S % L:
        raise ValueError(f"seq {S} not divisible by chunk {L}")
    nc = S // L

    def step(h_prev, xs_c):
        xc, bc, cc, ld = xs_c          # (B,H,L,dh),(B,L,N),(B,L,N),(B,H,L)
        b = jnp.cumsum(ld, axis=-1)                             # (B,H,L)
        # intra: scores[t,s] = C_t·B_s * exp(b_t - b_s), s<=t
        lw = b[..., :, None] - b[..., None, :]
        causal = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(causal, jnp.exp(lw), 0.0)                 # (B,H,L,L)
        cb = jnp.einsum("btn,bsn->bts", cc, bc)                 # (B,L,L)
        y_intra = jnp.einsum("bhts,bts,bhsd->bhtd", w, cb, xc)
        # inter: read carried state
        y_inter = jnp.exp(b)[..., None] * jnp.einsum(
            "bhdn,btn->bhtd", h_prev, cc)
        y = y_intra + y_inter
        # state update
        bl = b[..., -1:]                                        # (B,H,1)
        w_state = jnp.exp(bl - b)                               # decay s->L
        h_new = jnp.exp(bl)[..., None] * h_prev + jnp.einsum(
            "bhs,bhsd,bsn->bhdn", w_state, xc, bc)
        return h_new, y

    xs_chunks = (
        xh_dt.reshape(B, nheads, nc, L, head_dim).transpose(2, 0, 1, 3, 4),
        Bmat.reshape(B, nc, L, N).transpose(1, 0, 2, 3),
        Cmat.reshape(B, nc, L, N).transpose(1, 0, 2, 3),
        log_decay.reshape(B, nheads, nc, L).transpose(2, 0, 1, 3),
    )
    h_state, ys = jax.lax.scan(step, state.h, xs_chunks)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, nheads, S, head_dim)
    y = y + params["D"][None, :, None, None] * xh
    y = y.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    y = rms_norm(params["gnorm"], y)
    out = (y * jax.nn.silu(z)) @ params["w_out"].astype(x.dtype)
    return x + out, Mamba2State(h_state, conv_new)


def mamba2_decode_step(params, x, state: Mamba2State, *, state_dim: int,
                       expansion: int = 2, head_dim: int = 64):
    """Exact recurrent single step.  x: (B,1,D)."""
    B, _, D = x.shape
    from repro.models.layers import rms_norm
    di = D * expansion
    nheads = di // head_dim
    N = state_dim
    h_in = rms_norm(params["norm"], x)
    z, xbc, dt_pre = _mamba2_proj(params, h_in, di, N, nheads)
    xbc_c, conv_new = causal_conv1d(params["conv"], xbc, state.conv)
    xs = xbc_c[:, 0, :di].astype(jnp.float32)
    Bv = xbc_c[:, 0, di:di + N].astype(jnp.float32)
    Cv = xbc_c[:, 0, di + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_pre[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                                     # (B,H)
    xh = xs.reshape(B, nheads, head_dim)
    h_new = decay[..., None, None] * state.h + jnp.einsum(
        "bhd,bn->bhdn", xh * dt[..., None], Bv)
    y = jnp.einsum("bhdn,bn->bhd", h_new, Cv) + params["D"][None, :, None] * xh
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(params["gnorm"], y)
    out = (y * jax.nn.silu(z)) @ params["w_out"].astype(x.dtype)
    return x + out, Mamba2State(h_new, conv_new)
