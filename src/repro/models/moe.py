"""Mixture-of-Experts layer: top-k router + capacity-bucketed dispatch.

Dispatch is *grouped*: tokens are routed within their (sharded) batch row.
The scatter/gather is expressed BATCHED (leading B dim everywhere, no vmap)
with explicit sharding constraints on every buffer — GSPMD cannot propagate
the batch sharding through a scatter with computed indices, and without the
constraints the expert intermediates materialize group-REPLICATED
(measured: 8.75 GiB f32[8,256,1280,896] tensors on mixtral train_4k,
~80 GiB/device total; see EXPERIMENTS.md §Perf Pair A).

Expert FFN weights carry the expert dim and are tensor-parallel over the
``model`` axis inside each expert (E rarely divides the 16-wide model
axis); FSDP placement options are in ``repro.dist.partition.param_specs``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils import lecun_init

# ---------------------------------------------------------------------------
# sharding hook (set by the launcher, like transformer.set_activation_sharding)
_GROUP_AXIS = None
_MODEL_AXIS = None


def set_moe_sharding(group_axis, model_axis="model"):
    """group_axis: mesh axis (or tuple) the batch/group dim shards over;
    model_axis: TP axis the expert hidden dim (F) shards over."""
    global _GROUP_AXIS, _MODEL_AXIS
    _GROUP_AXIS = group_axis
    _MODEL_AXIS = model_axis if group_axis is not None else None


def _constrain(x, *tail):
    """tail entries: None or "model" (resolved to the configured TP axis).
    NOTE a PartitionSpec constraint is TOTAL — None dims force replication,
    so the F dim must be named here or GSPMD computes the full unsharded
    expert hidden per device (measured 3.1x dot-FLOPs on mixtral)."""
    if _GROUP_AXIS is None:
        return x
    spec = [_GROUP_AXIS] + [(_MODEL_AXIS if t == "model" else t)
                            for t in tail[:x.ndim - 1]]
    return jax.lax.with_sharding_constraint(x, P(*spec))


def init_moe(key, d_model: int, d_ff: int, num_experts: int):
    kr, ki, kg, ko = jax.random.split(key, 4)
    return {
        "router": lecun_init(kr, (d_model, num_experts)),
        "wi": lecun_init(ki, (num_experts, d_model, d_ff), fan_in=d_model),
        "wg": lecun_init(kg, (num_experts, d_model, d_ff), fan_in=d_model),
        "wo": lecun_init(ko, (num_experts, d_ff, d_model), fan_in=d_ff),
    }


def _route_group(x, logits, top_k: int, capacity: int, num_experts: int):
    """Per-group routing.  x: (S, D); logits: (S, E).

    Returns (slot (S,k), gate (S,k), valid (S,k)) where slot indexes a flat
    (E*capacity) dispatch buffer.
    """
    S = x.shape[0]
    gate_all = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, expert = jax.lax.top_k(gate_all, top_k)            # (S,k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    # flatten in token-major order => earlier tokens win capacity slots
    flat_e = expert.reshape(-1)                               # (S*k,)
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                      # (S*k, E)
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    valid = pos < capacity
    slot = jnp.where(valid, flat_e * capacity + pos, num_experts * capacity)
    return slot.reshape(S, top_k), gate.astype(x.dtype), valid.reshape(S, top_k)


def _dispatch(params, x, logits, num_experts: int, top_k: int,
              capacity_factor: float):
    """Capacity-bucketed dispatch over groups = leading dim.  x: (B, S, D),
    logits: (B, S, E) -> out (B, S, D)."""
    B, S, D = x.shape
    E, k = num_experts, top_k
    dt = x.dtype
    capacity = max(int(S * k / E * capacity_factor), k)

    # per-group index math (cheap int ops; vmap only over routing)
    slot, gate, valid = jax.vmap(
        lambda xg, lg: _route_group(xg, lg, k, capacity, E))(x, logits)
    flat_slot = slot.reshape(B, S * k)                        # (B,S*k)

    # batched scatter into the (E*capacity) dispatch buffer per group
    xk = jnp.repeat(x, k, axis=1)                             # (B,S*k,D)
    bidx = jnp.arange(B)[:, None]
    buf = jnp.zeros((B, E * capacity + 1, D), dt)
    buf = buf.at[bidx, flat_slot].add(xk)
    buf = _constrain(buf, None, None)
    bufe = buf[:, :-1].reshape(B, E, capacity, D)
    bufe = _constrain(bufe, None, None, None)

    h = jnp.einsum("becd,edf->becf", bufe, params["wi"].astype(dt))
    g = jnp.einsum("becd,edf->becf", bufe, params["wg"].astype(dt))
    h = _constrain(jax.nn.silu(g) * h, None, None, "model")
    out_buf = jnp.einsum("becf,efd->becd", h, params["wo"].astype(dt))
    out_buf = _constrain(out_buf, None, None, None)

    out_flat = jnp.concatenate(
        [out_buf.reshape(B, E * capacity, D),
         jnp.zeros((B, 1, D), dt)], axis=1)
    y = jnp.take_along_axis(out_flat, flat_slot[..., None], axis=1)
    y = y.reshape(B, S, k, D)
    w = (gate * valid.astype(gate.dtype))[..., None]
    out = jnp.sum(y * w.astype(y.dtype), axis=2)
    return _constrain(out, None, None)


def moe_apply(params, x, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25, aux_coef: float = 0.01,
              route_block: int = 0):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar).

    Routing groups = batch rows (B is the sharded data axis).  With
    ``route_block`` R > 0 capacity competition is further confined to
    R-token blocks within each row (the row end-pads up to a multiple of
    R; pads sit AFTER real tokens, and token-major slot priority means
    they can only take leftover capacity).  Because block boundaries are
    at fixed multiples of R from the row start, routing becomes identical
    whether a prompt is prefilled whole or in chunks whose starts are
    multiples of R — and a single decode token (S == 1) always gets its
    full top-k (one token can't exhaust capacity >= k), so decode routing
    is batch-composition independent either way.
    """
    B, S, D = x.shape
    E = num_experts
    dt = x.dtype
    logits = x @ params["router"].astype(dt)                  # (B,S,E)

    R = route_block
    if R and R > 0 and S > 1:
        nb = -(-S // R)
        pad = nb * R - S
        xg = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
        lg = jnp.pad(logits, ((0, 0), (0, pad), (0, 0))) if pad else logits
        out = _dispatch(params, xg.reshape(B * nb, R, D),
                        lg.reshape(B * nb, R, E), E, top_k, capacity_factor)
        out = out.reshape(B, nb * R, D)[:, :S]
    else:
        out = _dispatch(params, x, logits, E, top_k, capacity_factor)

    # Switch-style load-balance auxiliary loss (always on the original
    # unpadded logits so route_block leaves training numerics alone).
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32),
                           axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = aux_coef * E * jnp.sum(frac_tokens * frac_probs)
    return out, aux
