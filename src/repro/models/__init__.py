from repro.models import attention, layers, moe, policy, ssm, transformer  # noqa: F401
