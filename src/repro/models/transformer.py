"""Model assembly: embeds + layer stacks (attention / MoE / SSM / hybrid)
with scan-over-layers, KV/state caches, prefill & decode entry points.

Layer stacking strategy
-----------------------
* homogeneous stacks (dense/moe/audio/vlm): one stacked params pytree with
  leading dim = num_layers, applied with ``jax.lax.scan`` so the compiled HLO
  contains ONE layer body regardless of depth (critical for the 80 dry-run
  compiles on a single CPU core).
* patterned stacks (xlstm: 7×mlstm+1×slstm; zamba2: 8×mamba2+1×shared-attn):
  scan over ``num_super`` super-blocks; inside the scan body the pattern is
  unrolled (static, short).  zamba2's shared attention block reuses ONE weight
  set at every application (the paper's parameter-sharing trick) but carries a
  distinct KV cache per application.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.attention import (KVCache, PagedKVCache, attention,
                                    make_cache, make_paged_cache)
from repro.models.layers import (embed, init_embedding, init_linear, init_mlp,
                                 init_rmsnorm, linear, mlp, rms_norm, softcap,
                                 unembed)
from repro.models.moe import init_moe, moe_apply

BIG_WINDOW = 1 << 30  # "no window" sentinel usable as a dynamic operand

# --------------------------------------------------------------------------
# Activation-sharding hook (sequence-parallel style): when set (by the
# launcher, under a mesh context), the scan-carried hidden state is
# constrained to this PartitionSpec at every layer boundary so the remat
# stash is sharded instead of replicated over the model axis.
_ACT_SPEC = None


def set_activation_sharding(spec):
    global _ACT_SPEC
    _ACT_SPEC = spec


def _constrain(x):
    if _ACT_SPEC is not None and x.ndim >= 3:
        x = jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    return x


# ======================================================================
# init
# ======================================================================
def _init_attn_layer(key, cfg: ModelConfig):
    from repro.models.attention import init_attention_params
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": init_attention_params(k1, cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.resolved_head_dim,
                                      cfg.qkv_bias),
        "ln2": init_rmsnorm(cfg.d_model),
    }
    if cfg.num_experts:
        p["moe"] = init_moe(k2, cfg.d_model, cfg.d_ff, cfg.num_experts)
    else:
        p["mlp"] = init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act)
    return p


def _init_block(key, kind: str, cfg: ModelConfig):
    if kind in ("attn", "attn_shared"):
        return _init_attn_layer(key, cfg)
    if kind == "mlstm":
        return ssm.init_mlstm(key, cfg.d_model, cfg.num_heads,
                              expansion=cfg.ssm_expansion,
                              conv_width=cfg.conv_width)
    if kind == "slstm":
        return ssm.init_slstm(key, cfg.d_model, cfg.num_heads)
    if kind == "mamba2":
        return ssm.init_mamba2(key, cfg.d_model, cfg.ssm_state_dim,
                               conv_width=cfg.conv_width)
    raise ValueError(kind)


def init_model(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {"final_norm": init_rmsnorm(cfg.d_model)}

    if cfg.frontend == "audio":
        params["frontend_proj"] = init_linear(keys[0], cfg.frontend_feat_dim,
                                              cfg.d_model)
        params["head"] = init_linear(keys[1], cfg.d_model, cfg.vocab_size)
    else:
        params["embed"] = init_embedding(keys[0], cfg.vocab_size, cfg.d_model)
        if not cfg.tie_embeddings:
            params["unembed"] = init_linear(keys[1], cfg.d_model,
                                            cfg.vocab_size)
    if cfg.frontend == "vision":
        params["patch_proj"] = init_linear(keys[2], cfg.frontend_feat_dim,
                                           cfg.d_model)

    if cfg.block_pattern:
        sup: Dict[str, Any] = {}
        pat = cfg.block_pattern
        for i, kind in enumerate(pat):
            if kind == "attn_shared":
                continue
            ks = jax.random.split(jax.random.fold_in(keys[3], i),
                                  cfg.num_super)
            sup[f"{kind}_{i}"] = jax.vmap(
                lambda k: _init_block(k, kind, cfg))(jnp.stack(ks))
        params["super"] = sup
        if "attn_shared" in pat:
            params["shared_attn"] = _init_block(keys[4], "attn", cfg)
    else:
        ks = jax.random.split(keys[3], cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_block(k, "attn", cfg))(jnp.stack(ks))
    return params


def init_abstract(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the params, in cfg.dtype — no allocation."""
    shapes = jax.eval_shape(lambda: init_model(jax.random.key(0), cfg))
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dt if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        shapes)


# ======================================================================
# caches
# ======================================================================
def _stack_cache(make_one, n: int):
    one = make_one()
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape),
                        one) if not isinstance(one, tuple) else jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               window_override: Optional[int] = None, dtype=None,
               per_layer: bool = False):
    """Stacked per-layer caches for decode.  Leading dim = layers/super.

    ``per_layer=True`` (local/global archs, unrolled decode only): returns a
    LIST of per-layer caches, each sized to ITS OWN window — gemma2's local
    layers then hold a 4096-slot ring instead of the full 32k context
    (half the KV memory on a 46-layer stack)."""
    dt = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    di_mlstm = cfg.d_model * cfg.ssm_expansion
    di = cfg.d_model * 2                      # mamba2 expansion fixed at 2

    def attn_cache(window):
        return make_cache(batch, max_seq, cfg.num_kv_heads, hd, window, dt)

    if cfg.block_pattern:
        caches: Dict[str, Any] = {}
        for i, kind in enumerate(cfg.block_pattern):
            if kind == "mlstm":
                one = ssm.mlstm_init_state(batch, cfg.num_heads,
                                           di_mlstm // cfg.num_heads,
                                           di_mlstm, cfg.conv_width, dt)
            elif kind == "slstm":
                one = ssm.slstm_init_state(batch, cfg.num_heads,
                                           cfg.d_model // cfg.num_heads)
            elif kind == "mamba2":
                one = ssm.mamba2_init_state(batch, di, cfg.ssm_state_dim,
                                            64, cfg.conv_width, dt)
            else:  # attn_shared: window per cfg
                w = window_override if window_override else cfg.sliding_window
                one = attn_cache(w)
            caches[f"{kind}_{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (cfg.num_super,) + x.shape).copy(), one)
        return caches

    # homogeneous attention stack; per-layer window possible (gemma2)
    windows = layer_windows(cfg, window_override)
    if per_layer:
        return [attn_cache(None if w == BIG_WINDOW else w) for w in windows]
    uniform = all(w == windows[0] for w in windows)
    if uniform:
        one = attn_cache(windows[0] if windows[0] != BIG_WINDOW else None)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.num_layers,) + x.shape).copy(), one)
    # mixed local/global: all caches sized max window (ring semantics only if
    # every layer is windowed).  Local layers still mask to their window.
    maxw = max(w for w in windows)
    one = attn_cache(None if maxw == BIG_WINDOW else maxw)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(
            x[None], (cfg.num_layers,) + x.shape).copy(), one)


def init_paged_cache(cfg: ModelConfig, batch: int, max_seq: int,
                     window_override: Optional[int] = None, dtype=None, *,
                     page_size: int, num_pages: int):
    """Paged variant of :func:`init_cache`: every attention node becomes a
    batch-free :class:`PagedKVCache` pool shared by all decode slots
    (page 0 = trash), addressed through an engine-owned page table.  Pages
    hold absolute positions (full depth — sliding windows apply purely via
    masking), so the per-node ring-vs-full distinction disappears.
    Recurrent (mLSTM/sLSTM/Mamba2) states are fixed-size per slot and stay
    batched exactly as in :func:`init_cache`."""
    dt = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim

    def paged_node(n):
        one = make_paged_cache(num_pages, page_size, cfg.num_kv_heads, hd, dt)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape).copy(), one)

    if cfg.block_pattern:
        caches = init_cache(cfg, batch, max_seq, window_override, dt)
        for i, kind in enumerate(cfg.block_pattern):
            if kind in ("attn", "attn_shared"):
                caches[f"{kind}_{i}"] = paged_node(cfg.num_super)
        return caches
    return paged_node(cfg.num_layers)


def layer_windows(cfg: ModelConfig, window_override: Optional[int] = None):
    """Static per-layer attention window list (BIG_WINDOW = unlimited)."""
    if cfg.block_pattern:
        n = sum(1 for k in cfg.layer_kinds if k == "attn_shared")
        w = window_override or cfg.sliding_window or BIG_WINDOW
        return [w] * n
    out = []
    for i in range(cfg.num_layers):
        if cfg.local_global:
            # even layers local (sliding window), odd layers global
            if i % 2 == 0:
                out.append(cfg.sliding_window or BIG_WINDOW)
            else:
                out.append(window_override or BIG_WINDOW)
        elif cfg.sliding_window:
            out.append(cfg.sliding_window)
        else:
            out.append(window_override or BIG_WINDOW)
    return out


# ======================================================================
# blocks
# ======================================================================
def _attn_block(lp, x, cfg: ModelConfig, positions, window, cache,
                page_table=None, paged_kernel: bool = False):
    h = rms_norm(lp["ln1"], x, cfg.norm_eps)
    a, new_cache = attention(
        lp["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, positions=positions, causal=cfg.causal,
        window=window, attn_cap=cfg.attn_softcap, rope_theta=cfg.rope_theta,
        cache=cache, page_table=page_table, paged_kernel=paged_kernel)
    x = x + a
    h = rms_norm(lp["ln2"], x, cfg.norm_eps)
    if "moe" in lp:
        m, aux = moe_apply(
            lp["moe"], h, num_experts=cfg.num_experts,
            top_k=cfg.experts_per_token, aux_coef=cfg.router_aux_coef,
            capacity_factor=cfg.moe_capacity_factor,
            route_block=cfg.moe_route_block)
    else:
        m, aux = mlp(lp["mlp"], h, cfg.act), jnp.float32(0.0)
    return x + m, new_cache, aux


def _freeze_idle(old, new, positions):
    """Pin recurrent state for decode rows at negative positions.

    A paged engine parks idle and still-prefilling slots at position -1;
    their attention writes fall into the trash page, and this is the
    recurrent-state counterpart: without it every batched decode step
    would advance (i.e. corrupt) the state a chunked prefill is building
    in that row.  Dense engines park idle rows at position 0, which keeps
    their legacy advance-and-overwrite behavior byte-identical."""
    keep = positions[:, 0] >= 0
    return jax.tree.map(
        lambda o, n: jnp.where(
            keep.reshape((-1,) + (1,) * (n.ndim - 1)), n, o), old, new)


def _apply_kind(kind, lp, x, cfg, positions, window, cache,
                page_table=None, paged_kernel: bool = False):
    """Dispatch one block; returns (x, new_cache, aux)."""
    S = x.shape[1]
    if kind in ("attn", "attn_shared"):
        return _attn_block(lp, x, cfg, positions, window, cache,
                           page_table, paged_kernel)
    if kind == "mlstm":
        if S == 1 and cache is not None:
            y, st = ssm.mlstm_decode_step(lp, x, cache,
                                          num_heads=cfg.num_heads,
                                          expansion=cfg.ssm_expansion)
            st = _freeze_idle(cache, st, positions)
        else:
            y, st = ssm.mlstm_apply(lp, x, num_heads=cfg.num_heads,
                                    state=cache, chunk=min(256, S),
                                    expansion=cfg.ssm_expansion)
        return y, st, jnp.float32(0.0)
    if kind == "slstm":
        y, st = ssm.slstm_apply(lp, x, num_heads=cfg.num_heads, state=cache)
        if S == 1 and cache is not None:
            st = _freeze_idle(cache, st, positions)
        return y, st, jnp.float32(0.0)
    if kind == "mamba2":
        if S == 1 and cache is not None:
            y, st = ssm.mamba2_decode_step(lp, x, cache,
                                           state_dim=cfg.ssm_state_dim)
            st = _freeze_idle(cache, st, positions)
        else:
            y, st = ssm.mamba2_apply(lp, x, state_dim=cfg.ssm_state_dim,
                                     state=cache, chunk=min(256, S))
        return y, st, jnp.float32(0.0)
    raise ValueError(kind)


# ======================================================================
# stack
# ======================================================================
def apply_stack(params, cfg: ModelConfig, x, positions, caches=None,
                window_override: Optional[int] = None, remat: bool = False,
                unroll: bool = False, page_table=None,
                paged_kernel: bool = False):
    """Run the whole layer stack.  Returns (x, new_caches, aux_total).

    ``page_table`` (B, M) is closed over by the layer scan (like
    ``positions``) when the caches are paged — every paged node shares the
    ONE physical page-id space, so one table addresses them all."""
    if cfg.block_pattern:
        return _apply_patterned(params, cfg, x, positions, caches,
                                window_override, remat, page_table,
                                paged_kernel)
    if unroll and caches is not None:
        win_list = layer_windows(cfg, window_override)
        aux = jnp.float32(0.0)
        if isinstance(caches, list):
            # per-layer caches (heterogeneous sizes: local ring + global)
            new_list = []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda t: t[i], params["layers"])
                x, nc, a = _attn_block(lp, x, cfg, positions,
                                       win_list[i], caches[i],
                                       page_table, paged_kernel)
                aux = aux + a
                new_list.append(nc)
            return x, new_list, aux
        # unrolled decode: per-layer cache slices update in place (XLA can
        # alias the donated cache; the scan form double-buffers the whole
        # stacked cache as a loop carry — +13 GiB/dev on qwen decode_32k)
        new_caches = caches
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            ci = jax.tree.map(lambda t: t[i], new_caches)
            x, nc, a = _attn_block(lp, x, cfg, positions,
                                   win_list[i], ci, page_table, paged_kernel)
            aux = aux + a
            # write the layer's updated cache back in place: chained DUS on
            # the (donated) stacked cache aliases instead of double-buffering
            new_caches = jax.tree.map(
                lambda full, piece: jax.lax.dynamic_update_index_in_dim(
                    full, piece, i, 0), new_caches, nc)
        return x, new_caches, aux
    windows = jnp.asarray(layer_windows(cfg, window_override), jnp.int32)

    def body(carry, xs):
        h, aux = carry
        lp, window, cache = xs
        h = _constrain(h)
        h2, new_cache, a = _attn_block(lp, h, cfg, positions, window, cache,
                                       page_table, paged_kernel)
        return (h2, aux + a), new_cache

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), new_caches = jax.lax.scan(
        body_fn, (x, jnp.float32(0.0)),
        (params["layers"], windows, caches))
    return x, new_caches, aux


def _apply_patterned(params, cfg, x, positions, caches, window_override,
                     remat, page_table=None, paged_kernel: bool = False):
    pat = cfg.block_pattern
    w_attn = window_override or cfg.sliding_window or BIG_WINDOW

    def body(carry, xs):
        h, aux = carry
        sup_params, sup_caches = xs
        h = _constrain(h)
        new_caches = {}
        for i, kind in enumerate(pat):
            key = f"{kind}_{i}"
            lp = params["shared_attn"] if kind == "attn_shared" \
                else sup_params[key]
            cache = sup_caches.get(key) if sup_caches else None
            h, nc, a = _apply_kind(kind, lp, h, cfg, positions, w_attn, cache,
                                   page_table, paged_kernel)
            aux = aux + a
            new_caches[key] = nc if nc is not None else jnp.float32(0)
        return (h, aux), new_caches

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), new_caches = jax.lax.scan(
        body_fn, (x, jnp.float32(0.0)), (params["super"], caches))
    return x, new_caches, aux


# ======================================================================
# model entry points
# ======================================================================
def _embed_inputs(params, cfg: ModelConfig, batch):
    """batch dict -> (x (B,S,D), positions (B,S) or (S,), text_mask)."""
    if cfg.frontend == "audio":
        x = linear(params["frontend_proj"], batch["features"])
        S = x.shape[1]
        return x, jnp.arange(S, dtype=jnp.int32), None
    if cfg.frontend == "vision" and "patches" in batch:
        pe = linear(params["patch_proj"], batch["patches"])
        te = embed(params["embed"], batch["tokens"])
        x = jnp.concatenate([pe.astype(te.dtype), te], axis=1)
        S = x.shape[1]
        P = pe.shape[1]
        text_mask = jnp.concatenate(
            [jnp.zeros((P,), bool), jnp.ones((te.shape[1],), bool)])
        return x, jnp.arange(S, dtype=jnp.int32), text_mask
    x = embed(params["embed"], batch["tokens"])
    return x, jnp.arange(x.shape[1], dtype=jnp.int32), None


def _logits(params, cfg: ModelConfig, h):
    if cfg.frontend == "audio":
        lg = linear(params["head"], h)
    elif cfg.tie_embeddings:
        lg = unembed(params["embed"], h)
    else:
        lg = linear(params["unembed"], h)
    return softcap(lg, cfg.final_softcap)


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            window_override: Optional[int] = None):
    """Full forward pass -> (logits (B,S,V), aux)."""
    x, positions, _ = _embed_inputs(params, cfg, batch)
    x = x.astype(jnp.dtype(cfg.dtype))
    h, _, aux = apply_stack(params, cfg, x, positions, caches=None,
                            window_override=window_override, remat=remat)
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    return _logits(params, cfg, h), aux


def _chunked_xent(h, cfg, params, labels, mask, chunk: int = 512):
    """Cross-entropy without materializing (B,S,V): scan over seq chunks."""
    B, S, D = h.shape
    L = min(chunk, S)
    nc = -(-S // L)
    pad = nc * L - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    mp = jnp.pad(mask, ((0, 0), (0, pad)))
    hp = hp.reshape(B, nc, L, D).transpose(1, 0, 2, 3)
    lp = lp.reshape(B, nc, L).transpose(1, 0, 2)
    mp = mp.reshape(B, nc, L).transpose(1, 0, 2)

    def step(acc, xs):
        hc, lc, mc = xs
        logits = _logits(params, cfg, hc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * mc
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (hp, lp, mp))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = True,
            window_override: Optional[int] = None):
    """Training loss (causal LM / masked prediction / text-only VLM)."""
    x, positions, text_mask = _embed_inputs(params, cfg, batch)
    x = x.astype(jnp.dtype(cfg.dtype))
    h, _, aux = apply_stack(params, cfg, x, positions, caches=None,
                            window_override=window_override, remat=remat)
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    B, S, _ = h.shape

    if cfg.frontend == "audio":
        labels = batch["targets"]
        mask = batch["mask"].astype(jnp.float32)
        loss = _chunked_xent(h, cfg, params, labels, mask)
        return loss + aux

    if cfg.frontend == "vision" and "patches" in batch:
        T = batch["tokens"].shape[1]
        labels = jnp.pad(batch["labels"], ((0, 0), (S - T, 0)))
        mask = jnp.broadcast_to(text_mask[None], (B, S)).astype(jnp.float32)
        # next-token: positions predicting text tokens only
        h_shift = h[:, :-1]
        loss = _chunked_xent(h_shift, cfg, params, labels[:, 1:],
                             mask[:, 1:])
        return loss + aux

    labels = batch["labels"]
    mask = jnp.ones_like(labels, jnp.float32)
    loss = _chunked_xent(h[:, :-1], cfg, params, labels[:, 1:], mask[:, 1:])
    return loss + aux


def prefill(params, cfg: ModelConfig, batch, max_seq: int,
            window_override: Optional[int] = None,
            per_layer_cache: bool = False):
    """Prefill -> (last-position logits, filled caches)."""
    x, positions, _ = _embed_inputs(params, cfg, batch)
    x = x.astype(jnp.dtype(cfg.dtype))
    caches = init_cache(cfg, x.shape[0], max_seq, window_override,
                        jnp.dtype(cfg.dtype), per_layer=per_layer_cache)
    h, caches, _ = apply_stack(params, cfg, x, positions, caches=caches,
                               window_override=window_override,
                               unroll=per_layer_cache)
    h = rms_norm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    return _logits(params, cfg, h)[:, 0], caches


def decode_step(params, cfg: ModelConfig, token, pos, caches,
                window_override: Optional[int] = None,
                unroll: bool = False, page_table=None,
                paged_kernel: bool = False):
    """One decode step.  token: (B,) int32; pos: (B,) int32 absolute.
    ``page_table`` (B, M) is required when the caches are paged.

    Returns (logits (B,V), new_caches).
    """
    if cfg.frontend == "audio":
        raise ValueError("encoder-only model has no decode step")
    x = embed(params["embed"], token[:, None]).astype(jnp.dtype(cfg.dtype))
    positions = pos[:, None]
    h, caches, _ = apply_stack(params, cfg, x, positions, caches=caches,
                               window_override=window_override,
                               unroll=unroll, page_table=page_table,
                               paged_kernel=paged_kernel)
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    return _logits(params, cfg, h)[:, 0], caches


def _is_cache_node(n):
    return isinstance(n, (KVCache, PagedKVCache))


def prefill_chunk(params, cfg: ModelConfig, tokens, positions, caches, slot,
                  page_table, window_override: Optional[int] = None,
                  paged_kernel: bool = False):
    """One B=1 prefill chunk for decode slot ``slot`` running directly
    against the engine's BATCHED cache tree: recurrent-state leaves are
    sliced out at the slot (batch axis 1) and written back, while paged
    attention nodes are batch-free and written in place through
    ``page_table`` (M,) — so chunked prefill never touches other slots'
    pages and interleaves with batched decode without copying caches.

    tokens/positions: (C,) int32 (absolute positions — chunk k >= 1 of a
    prompt passes positions starting at its chunk offset).  Returns
    (last-position logits (1, V), updated caches)."""
    x = embed(params["embed"], tokens[None]).astype(jnp.dtype(cfg.dtype))

    def view(n):
        if isinstance(n, PagedKVCache):
            return n
        if isinstance(n, KVCache):
            return jax.tree.map(
                lambda t: jax.lax.dynamic_slice_in_dim(t, slot, 1, 1), n)
        return jax.lax.dynamic_slice_in_dim(n, slot, 1, 1)

    view_caches = jax.tree.map(view, caches, is_leaf=_is_cache_node)
    h, new_view, _ = apply_stack(params, cfg, x, positions[None],
                                 caches=view_caches,
                                 window_override=window_override,
                                 page_table=page_table[None],
                                 paged_kernel=paged_kernel)

    def back(full, new):
        if isinstance(full, PagedKVCache):
            return new
        if isinstance(full, KVCache):
            return jax.tree.map(
                lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                    f, o, slot, 1), full, new)
        return jax.lax.dynamic_update_slice_in_dim(full, new, slot, 1)

    caches = jax.tree.map(back, caches, new_view, is_leaf=_is_cache_node)
    h = rms_norm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    return _logits(params, cfg, h)[:, 0], caches
