"""DRL policy networks — the paper's Table 6 MLP policies.

Each benchmark uses an MLP ``in_dim:hidden...:out_dim`` actor with a value
head off the last hidden layer (standard PPO actor-critic).  The actor
outputs a diagonal-Gaussian action distribution (continuous control, as in
Isaac Gym).  The fused Pallas kernel in ``repro.kernels.fused_policy_mlp``
executes the same trunk in one VMEM-resident pass.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.utils import he_init


def init_policy(key, dims: Sequence[int]):
    """dims = [in, h1, ..., hk, act_dim] (paper Table 6 format)."""
    keys = jax.random.split(key, len(dims))
    layers = []
    for i in range(len(dims) - 2):
        layers.append({"w": he_init(keys[i], (dims[i], dims[i + 1])),
                       "b": jnp.zeros((dims[i + 1],), jnp.float32)})
    p = {
        "trunk": layers,
        "mu": {"w": he_init(keys[-2], (dims[-2], dims[-1])) * 0.01,
               "b": jnp.zeros((dims[-1],), jnp.float32)},
        "log_std": jnp.zeros((dims[-1],), jnp.float32),
        "value": {"w": he_init(keys[-1], (dims[-2], 1)),
                  "b": jnp.zeros((1,), jnp.float32)},
    }
    return p


def policy_trunk(params, obs):
    h = obs
    for lyr in params["trunk"]:
        h = jnp.tanh(h @ lyr["w"] + lyr["b"])
    return h


def policy_apply(params, obs) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """obs (..., in_dim) -> (mu, log_std, value)."""
    h = policy_trunk(params, obs)
    mu = h @ params["mu"]["w"] + params["mu"]["b"]
    value = (h @ params["value"]["w"] + params["value"]["b"])[..., 0]
    log_std = jnp.broadcast_to(params["log_std"], mu.shape)
    return mu, log_std, value


def sample_action(key, mu, log_std):
    return mu + jnp.exp(log_std) * jax.random.normal(key, mu.shape)


def log_prob(mu, log_std, action):
    var = jnp.exp(2 * log_std)
    lp = -0.5 * (jnp.square(action - mu) / var
                 + 2 * log_std + jnp.log(2 * jnp.pi))
    return jnp.sum(lp, axis=-1)


def entropy(log_std):
    return jnp.sum(log_std + 0.5 * jnp.log(2 * jnp.pi * jnp.e), axis=-1)
