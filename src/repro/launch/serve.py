"""Serving launcher — a thin CLI over the ``repro.serve`` engine
(batched prefill + continuous-batching decode for any architecture;
reduced configs run for real on this host, full configs via dryrun).

``--disagg`` serves through the disaggregated front instead: prefill
specialists feeding decode engines over the cache-migration channel,
with the per-request migrate-vs-local decision priced by the Table-2
cost model (see ``repro.serve.disagg``).

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b \
      --batch 4 --prompt-len 64 --gen 32
  PYTHONPATH=src python -m repro.launch.serve --disagg \
      --decode-engines 2 --prefill-gmis 1 --batch 8
"""
from __future__ import annotations

import argparse

from repro.configs import get_reduced
from repro.configs.base import InputShape
from repro.data import make_batch
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--disagg", action="store_true",
                    help="serve through the disaggregated prefill/decode "
                         "front (cache migration over repro.comm)")
    ap.add_argument("--decode-engines", type=int, default=2,
                    help="decode GMIs behind the router (--disagg)")
    ap.add_argument("--prefill-gmis", type=int, default=1,
                    help="prefill-specialist GMIs (--disagg)")
    args = ap.parse_args()

    import jax

    cfg = get_reduced(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving "
                         f"(see DESIGN.md shape/skip matrix)")
    params = T.init_model(jax.random.key(args.seed), cfg)
    shape = InputShape("serve", args.prompt_len, args.batch, "prefill")
    batch = make_batch(cfg, shape, seed=args.seed)

    requests = []
    for i in range(args.batch):
        extras = {"patches": batch["patches"][i]} \
            if cfg.frontend == "vision" else None
        requests.append(Request(tokens=batch["tokens"][i],
                                max_new_tokens=args.gen,
                                temperature=args.temperature,
                                seed=args.seed + i, extras=extras))

    if args.disagg:
        from repro.launch.steps import make_disagg_front
        front = make_disagg_front(
            cfg, params, decode_engines=args.decode_engines,
            prefill_gmis=args.prefill_gmis, max_slots=args.batch,
            max_seq=args.prompt_len + args.gen + 8)
        done = front.serve(requests)
        load = front.take_epoch()
        pl = front.planner
        print(f"arch={args.arch} batch={args.batch} disagg: "
              f"{args.prefill_gmis} prefill + {args.decode_engines} "
              f"decode GMI(s)")
        print(f"migrated={pl.migrated} local={pl.kept_local} "
              f"bw={pl.bandwidth/1e9:.2f} GB/s "
              f"prefill_rate={pl.prefill_tok_s:,.0f} tok/s")
        print(f"tokens={load.tokens} p50={load.p50_s*1e3:.1f} ms "
              f"p95={load.p95_s*1e3:.1f} ms")
        first = next(c for c in done if c.rid == requests[0].rid)
        print("sample token ids:", first.tokens[:16])
        return

    engine = ServeEngine(cfg, params, max_slots=args.batch,
                         max_seq=args.prompt_len + args.gen + 8)
    done = engine.serve(requests)

    tel = engine.telemetry
    B = args.batch
    prompt_tokens = len(requests[0].tokens) + (
        cfg.num_patches if cfg.frontend == "vision" else 0)
    gen_tokens = tel.total_tokens - B          # B first tokens are prefill's
    first = next(c for c in done if c.rid == requests[0].rid)
    print(f"arch={args.arch} batch={B} prompt={prompt_tokens} "
          f"gen={args.gen}")
    print(f"prefill: {tel.total_prefill_s*1e3:.1f} ms "
          f"({tel.total_prompt_tokens/max(tel.total_prefill_s,1e-9):,.0f} "
          f"tok/s)")
    print(f"decode:  {tel.total_decode_s*1e3:.1f} ms "
          f"({gen_tokens/max(tel.total_decode_s,1e-9):,.0f} tok/s)")
    print("sample token ids:", first.tokens[:16])


if __name__ == "__main__":
    main()
