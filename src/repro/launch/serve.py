"""Serving launcher: batched prefill + decode for any architecture
(reduced configs run for real on this host; full configs via dryrun).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.configs.base import InputShape
from repro.data import make_batch
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving "
                         f"(see DESIGN.md shape/skip matrix)")
    key = jax.random.key(args.seed)
    params = T.init_model(key, cfg)
    shape = InputShape("serve", args.prompt_len, args.batch, "prefill")
    batch = make_batch(cfg, shape, seed=args.seed)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    max_seq = args.prompt_len + args.gen + 8

    prefill = jax.jit(lambda p, b: T.prefill(p, cfg, b, max_seq))
    decode = jax.jit(lambda p, t, pos, c: T.decode_step(p, cfg, t, pos, c))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    B = args.batch
    prompt_tokens = batch["tokens"].shape[1] + (
        cfg.num_patches if cfg.frontend == "vision" else 0)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((B,), prompt_tokens + i, jnp.int32)
        logits, caches = decode(params, tok, pos, caches)
        if args.temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(sk, logits / args.temperature)
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], 1)
    print(f"arch={args.arch} batch={B} prompt={prompt_tokens} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({B*prompt_tokens/t_prefill:,.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({B*(args.gen-1)/max(t_decode,1e-9):,.0f} tok/s)")
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
