"""Serving launcher — a thin CLI over the ``repro.serve`` engine
(batched prefill + continuous-batching decode for any architecture;
reduced configs run for real on this host, full configs via dryrun).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-1.3b \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse

from repro.configs import get_reduced
from repro.configs.base import InputShape
from repro.data import make_batch
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    cfg = get_reduced(args.arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving "
                         f"(see DESIGN.md shape/skip matrix)")
    params = T.init_model(jax.random.key(args.seed), cfg)
    shape = InputShape("serve", args.prompt_len, args.batch, "prefill")
    batch = make_batch(cfg, shape, seed=args.seed)

    engine = ServeEngine(cfg, params, max_slots=args.batch,
                         max_seq=args.prompt_len + args.gen + 8)
    requests = []
    for i in range(args.batch):
        extras = {"patches": batch["patches"][i]} \
            if cfg.frontend == "vision" else None
        requests.append(Request(tokens=batch["tokens"][i],
                                max_new_tokens=args.gen,
                                temperature=args.temperature,
                                seed=args.seed + i, extras=extras))
    done = engine.serve(requests)

    tel = engine.telemetry
    B = args.batch
    prompt_tokens = len(requests[0].tokens) + (
        cfg.num_patches if cfg.frontend == "vision" else 0)
    gen_tokens = tel.total_tokens - B          # B first tokens are prefill's
    first = next(c for c in done if c.rid == requests[0].rid)
    print(f"arch={args.arch} batch={B} prompt={prompt_tokens} "
          f"gen={args.gen}")
    print(f"prefill: {tel.total_prefill_s*1e3:.1f} ms "
          f"({tel.total_prompt_tokens/max(tel.total_prefill_s,1e-9):,.0f} "
          f"tok/s)")
    print(f"decode:  {tel.total_decode_s*1e3:.1f} ms "
          f"({gen_tokens/max(tel.total_decode_s,1e-9):,.0f} tok/s)")
    print("sample token ids:", first.tokens[:16])


if __name__ == "__main__":
    main()
