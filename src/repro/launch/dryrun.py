import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run; smoke tests
# and benchmarks see the real single device.

# Multi-pod dry-run: lower + compile every (architecture × input-shape ×
# mesh) combination against the production mesh and record the roofline
# inputs (FLOPs / bytes / collective traffic / memory analysis).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
#       --mesh both --out artifacts/dryrun
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
#       --shape train_4k --mesh single --lgr har

import argparse
import json
import time
import traceback

import jax

from repro.configs import (ARCHS, INPUT_SHAPES, get_config,
                           long_context_window, shape_skips)
from repro.configs.base import TrainConfig
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import analyze
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)


def run_one(arch: str, shape_name: str, multi_pod: bool, lgr: str = "har",
            act_sharding: str = "dmodel", save_hlo: str = "",
            cache_layout: str = "heads", serve_fsdp: bool = False,
            cfg_overrides: dict = None, moe_spec: str = "contract",
            decode_unroll: bool = False, microbatches: int = 1,
            per_layer_cache: bool = False) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "lgr": lgr, "act_sharding": act_sharding,
           "cache_layout": cache_layout, "moe_spec": moe_spec,
           "status": "skip"}
    if cfg_overrides:
        rec["cfg_overrides"] = cfg_overrides
    skips = shape_skips(arch)
    if shape_name in skips:
        rec["reason"] = skips[shape_name]
        return rec
    window = long_context_window(arch) if shape_name == "long_500k" else None
    if window:
        rec["window_override"] = window

    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        if shape.mode == "train":
            fn, sds = make_train_step(
                cfg, mesh, shape, TrainConfig(microbatches=microbatches),
                lgr=lgr, act_sharding=act_sharding, moe_spec=moe_spec)
        elif shape.mode == "prefill":
            fn, sds = make_prefill_step(cfg, mesh, shape, window,
                                        act_sharding=act_sharding)
        else:
            fn, sds = make_serve_step(cfg, mesh, shape, window,
                                      cache_layout=cache_layout,
                                      params_fsdp=serve_fsdp,
                                      unroll=decode_unroll,
                                      per_layer_cache=per_layer_cache)
        lowered = fn.lower(*sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    hlo = compiled.as_text()
    hl = analyze(hlo, total_devices=mesh.devices.size)
    if save_hlo:
        import gzip
        with gzip.open(save_hlo, "wt") as f:
            f.write(hlo)
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "chips": mesh.devices.size,
        # per-device numbers (post-SPMD module)
        "hlo_flops_costan": float(ca.get("flops", 0.0)),
        "hlo_dot_flops": hl["dot_flops"],
        "hlo_traffic_bytes": hl["traffic_bytes"],
        "collective_bytes": hl["collective_bytes"],
        "coll_by_op": hl["coll_by_op"],
        "coll_counts": hl["coll_counts"],
        "mem_argument_bytes": ma.argument_size_in_bytes,
        "mem_output_bytes": ma.output_size_in_bytes,
        "mem_temp_bytes": ma.temp_size_in_bytes,
        "mem_alias_bytes": ma.alias_size_in_bytes,
    })
    # live bytes per device: args + temps (aliased outputs reuse arg space)
    rec["mem_per_device_bytes"] = (ma.argument_size_in_bytes
                                   + ma.temp_size_in_bytes)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--lgr", default="har", choices=["har", "mrr"])
    ap.add_argument("--act-sharding", default="dmodel",
                    choices=["dmodel", "seq", "none"])
    ap.add_argument("--cache-layout", default="heads",
                    choices=["heads", "seq"])
    ap.add_argument("--serve-fsdp", action="store_true")
    ap.add_argument("--moe-spec", default="contract",
                    choices=["contract", "expert", "tp_both"])
    ap.add_argument("--decode-unroll", action="store_true")
    ap.add_argument("--per-layer-cache", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--cfg-override", default="",
                    help="JSON dict of ModelConfig overrides (perf exps)")
    ap.add_argument("--preset", action="store_true",
                    help="use the best-known knobs per (arch x shape)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args()
    overrides = json.loads(args.cfg_override) if args.cfg_override else None

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    ok = failed = skipped = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape}_{'multi' if multi else 'single'}" \
                      f"_{args.lgr}_{args.act_sharding}"
                if args.preset:
                    tag = (f"{arch}_{shape}_"
                           f"{'multi' if multi else 'single'}_preset")
                if args.cache_layout != "heads":
                    tag += f"_cache{args.cache_layout}"
                if args.serve_fsdp:
                    tag += "_sfsdp"
                if args.moe_spec != "contract":
                    tag += f"_moe{args.moe_spec}"
                if args.decode_unroll:
                    tag += "_unroll"
                if args.per_layer_cache:
                    tag += "_plc"
                if args.microbatches > 1:
                    tag += f"_mb{args.microbatches}"
                if overrides:
                    tag += "_ovr" + "".join(sorted(overrides))[:24]
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[cached] {tag}")
                    ok += 1
                    continue
                try:
                    if args.preset:
                        from repro.configs.presets import preset
                        kw = preset(arch, shape)
                        rec = run_one(arch, shape, multi,
                                      kw["lgr"], kw["act_sharding"],
                                      args.save_hlo, kw["cache_layout"],
                                      False, overrides, kw["moe_spec"],
                                      kw["decode_unroll"],
                                      kw["microbatches"],
                                      kw.get("per_layer_cache", False))
                        rec["preset"] = True
                    else:
                        rec = run_one(arch, shape, multi, args.lgr,
                                      args.act_sharding, args.save_hlo,
                                      args.cache_layout, args.serve_fsdp,
                                      overrides, args.moe_spec,
                                      args.decode_unroll, args.microbatches,
                                      args.per_layer_cache)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    ok += 1
                    print(f"[ok] {tag}: compile={rec['compile_s']}s "
                          f"mem/dev={rec['mem_per_device_bytes']/2**30:.2f}GiB "
                          f"dotTF={rec['hlo_dot_flops']/1e12:.2f} "
                          f"collGB={rec['collective_bytes']/2**30:.3f}")
                elif rec["status"] == "skip":
                    skipped += 1
                    print(f"[skip] {tag}: {rec['reason']}")
                else:
                    failed += 1
                    print(f"[FAIL] {tag}: {rec['error']}")
    print(f"\ndry-run summary: ok={ok} skipped={skipped} failed={failed}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
