"""Training launcher.

Two modes:
* ``--workload drl``  — the paper's workload: multi-instance PPO with GMI
  layout templates and LGR gradient sync across instances (runs for real on
  this host's devices).
* ``--workload lm``   — LLM-architecture training on a local mesh with the
  reduced config (for full-size production meshes use
  ``repro.launch.dryrun``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --workload drl --env Ant \
      --num-gpus 2 --gmi-per-gpu 2 --iters 20
  PYTHONPATH=src python -m repro.launch.train --workload lm \
      --arch mixtral-8x7b --steps 10 --batch 4 --seq 128
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def run_drl(args):
    import jax
    import jax.numpy as jnp
    from repro.core.placement import plan_tcg_ex_training
    from repro.envs import make_env
    from repro.rl.ppo import PPOConfig, init_train, make_train_step

    n_dev = len(jax.devices())
    layout = plan_tcg_ex_training(
        args.num_gpus, args.gmi_per_gpu,
        devices=list(range(max(n_dev, args.num_gpus * args.gmi_per_gpu))),
        devices_per_gpu=args.gmi_per_gpu)
    # the Communicator owns mesh + strategy + grad-sync for this layout
    # (Algorithm 1 selection; Table-2 cost-scored when a cost model is
    # attached) — all downstream layers consume it, not a strategy string
    comm = layout.communicator()
    print(layout.manager.summary())
    print(f"LGR strategy (Algorithm 1 via repro.comm): {comm.strategy}")

    env = make_env(args.env)
    cfg = PPOConfig(num_steps=args.rollout, lr=3e-4)
    n_inst = args.num_gpus * args.gmi_per_gpu
    # data-parallel holistic instances: vmapped instance dimension, gradient
    # sync = mean across instances (the communicator's sync closure is the
    # identity on a single host device; multi-device runs reduce through
    # repro.comm's LGR schedules)
    import functools

    key = jax.random.key(args.seed)
    keys = jax.random.split(key, n_inst)
    states = []
    step_fns = []
    grad_sync = comm if n_inst == 1 else None
    for i in range(n_inst):
        p, o, es, ob = init_train(keys[i], env, env.spec.policy_dims,
                                  num_envs=args.num_env // n_inst)
        states.append([p, o, es, ob, jax.random.PRNGKey(args.seed + i)])
        step_fns.append(make_train_step(env, cfg, grad_sync_fn=grad_sync))

    t0 = time.time()
    total_steps = 0
    for it in range(args.iters):
        metrics = []
        for i in range(n_inst):
            p, o, es, ob, k = states[i]
            p, o, es, ob, k, m = step_fns[i](p, o, es, ob, k)
            states[i] = [p, o, es, ob, k]
            metrics.append(m)
        # cross-instance gradient consistency: average params (equivalent to
        # averaged gradients for identical optimizer states)
        if n_inst > 1:
            mean_p = jax.tree.map(lambda *xs: sum(xs) / n_inst,
                                  *[s[0] for s in states])
            for s in states:
                s[0] = mean_p
        total_steps += cfg.num_steps * args.num_env
        if it % max(args.iters // 10, 1) == 0:
            rm = float(np.mean([m["reward_mean"] for m in metrics]))
            print(f"iter {it:4d} reward_mean={rm:8.3f} "
                  f"steps/s={total_steps / (time.time() - t0):,.0f}")
    print(f"done: {total_steps:,} env steps in {time.time()-t0:.1f}s "
          f"({total_steps/(time.time()-t0):,.0f} steps/s)")


def run_lm(args):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.configs.base import InputShape, TrainConfig
    from repro.data import make_batch
    from repro.models import transformer as T
    from repro.optim import adam_init, adam_update
    from repro.checkpoint import save

    cfg = get_reduced(args.arch)
    shape = InputShape("cli", args.seq, args.batch, "train")
    key = jax.random.key(args.seed)
    params = T.init_model(key, cfg)
    opt = adam_init(params)
    tc = TrainConfig(learning_rate=args.lr)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, remat=False))(params)
        params, opt = adam_update(grads, opt, params, lr=tc.learning_rate,
                                  grad_clip=tc.grad_clip)
        return params, opt, loss

    t0 = time.time()
    for i in range(args.steps):
        batch = make_batch(cfg, shape, seed=args.seed + i)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, loss = step(params, opt, batch)
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:4d} loss={float(loss):.4f}")
    print(f"done in {time.time()-t0:.1f}s; final loss {float(loss):.4f}")
    if args.ckpt:
        save(args.ckpt, {"params": params, "opt": opt}, step=args.steps)
        print("checkpoint saved to", args.ckpt)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["drl", "lm"], default="drl")
    ap.add_argument("--env", default="Ant")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--num-gpus", type=int, default=2)
    ap.add_argument("--gmi-per-gpu", type=int, default=2)
    ap.add_argument("--num-env", type=int, default=256)
    ap.add_argument("--rollout", type=int, default=16)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    if args.workload == "drl":
        run_drl(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
