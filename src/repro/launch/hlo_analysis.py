"""Loop-aware HLO accounting for the roofline.

``compiled.cost_analysis()`` on this backend counts each ``while`` body ONCE
(scan-over-layers would be undercounted ~num_layers x) and reports no
collective traffic at all.  This module parses the post-SPMD, per-device HLO
text into a call graph and propagates three metrics with known trip counts:

* ``dot_flops``          — 2 * prod(result_dims) * contraction_size per dot
* ``collective_bytes``   — result-shape bytes of all-reduce / all-gather /
                           reduce-scatter / all-to-all / collective-permute
* ``traffic_bytes``      — operand + result bytes of every top-level
                           instruction (post-fusion ⇒ a reasonable HBM-
                           traffic proxy; intra-fusion temporaries excluded)

Known limitations (documented for EXPERIMENTS.md): non-dot FLOPs
(convolutions, transcendentals) are not counted; dynamic trip counts
default to 1; all-reduce bytes are counted once (not 2x for its
reduce-scatter + all-gather decomposition).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start", "ragged-all-to-all"}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([a-z][\w\-]*)\((.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{"n":\s*"(\d+)"\}')
_CALLEE_ATTR_RE = re.compile(
    r"(?:body|to_apply|calls|condition)=%?([\w.\-]+)")
_CALLEE_LIST_RE = re.compile(
    r"(?:called_computations|branch_computations)=\{([^}]*)\}")


def crosses_pod(attr_text: str, pod_size: int = 256,
                total_devices: int = 0) -> bool:
    """True if any replica group spans the pod boundary (multi-pod DCN
    traffic).  Handles the iota form ``replica_groups=[G,S]<=[dims]``,
    the explicit list form, and EMPTY groups (= all devices)."""
    import numpy as np
    if "replica_groups={}" in attr_text:
        return total_devices > pod_size
    m = _RG_IOTA_RE.search(attr_text)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(g, s)
        pods = groups // pod_size
        return bool(np.any(pods.min(axis=1) != pods.max(axis=1)))
    m = _RG_LIST_RE.search(attr_text)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
                return True
    return False


def _shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _nbytes(text: str) -> int:
    total = 0
    for dt, dims in _shapes(text):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class Metrics:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    cross_pod_bytes: float = 0.0
    traffic_bytes: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, int] = field(default_factory=dict)

    def add(self, other: "Metrics", mult: float = 1.0,
            include_traffic: bool = True):
        self.dot_flops += mult * other.dot_flops
        self.collective_bytes += mult * other.collective_bytes
        self.cross_pod_bytes += mult * other.cross_pod_bytes
        if include_traffic:
            self.traffic_bytes += mult * other.traffic_bytes
        for k, v in other.coll_by_op.items():
            self.coll_by_op[k] = self.coll_by_op.get(k, 0.0) + mult * v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + int(mult * v)


# ops whose operands/results never touch HBM at the instruction boundary
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "iota", "after-all", "partition-id", "replica-id"}


@dataclass
class Computation:
    name: str
    metrics: Metrics = field(default_factory=Metrics)
    # (callee, multiplier, include_traffic): fusion bodies execute entirely
    # in registers/VMEM — their internal ops contribute FLOPs/collectives
    # but NOT HBM traffic (the call-site fusion op's operands/result do).
    calls: List[Tuple[str, float, bool]] = field(default_factory=list)
    is_entry: bool = False


def _parse_dot_flops(result_txt: str, args_txt: str,
                     symbols: Dict[str, int]) -> float:
    """FLOPs of a dot: 2 * prod(result) * contraction_size."""
    res_shapes = _shapes(result_txt)
    if not res_shapes:
        return 0.0
    _, rdims = res_shapes[0]
    out_elems = 1
    for d in rdims:
        out_elems *= d
    # contraction size: product of lhs contracting dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", args_txt)
    operands = re.findall(r"%([\w.\-]+)", args_txt)
    k = 1
    if m and operands:
        lhs_shape = symbols.get(operands[0])
        if lhs_shape:
            for ci in (int(x) for x in m.group(1).split(",") if x):
                if ci < len(lhs_shape):
                    k *= lhs_shape[ci]
    return 2.0 * out_elems * k


def parse_hlo(hlo_text: str,
              total_devices: int = 0) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    symbols: Dict[str, List[int]] = {}

    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        header = re.match(
            r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$", stripped)
        if header:
            current = Computation(header.group(2),
                                  is_entry=bool(header.group(1)))
            comps[current.name] = current
            symbols = {}
            continue
        if current is None or not stripped or stripped == "}":
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, result_txt, op, rest = m.groups()
        # record result bytes in the symbol table (tuples: total bytes)
        symbols[name] = _nbytes(result_txt)
        sym_shapes = getattr(current, "_shapes", None)
        if sym_shapes is None:
            sym_shapes = {}
            current._shapes = sym_shapes          # dims for dot contraction
        sh = _shapes(result_txt)
        if sh:
            sym_shapes[name] = sh[0][1]
        met = current.metrics
        # traffic: result write + operand reads (resolved via symbol table);
        # metadata/aliasing ops are free; slicing ops read/write only the
        # slice, not their full operand (a 32k-step scan would otherwise
        # count its whole xs buffer once per iteration)
        if op in ("dynamic-slice", "slice", "gather") or (
                op == "fusion" and ("slice" in name
                                    or "dynamic_slice" in rest
                                    or "dynamic_update_slice" in rest)):
            met.traffic_bytes += 2 * symbols[name]          # read + write
        elif op == "dynamic-update-slice":
            # writes only the update region ~ smallest operand, twice
            arg_head = rest.split(")")[0]
            opsz = [symbols.get(o, 0) for o in
                    re.findall(r"%([\w.\-]+)", arg_head)]
            met.traffic_bytes += 2 * min([s for s in opsz if s] or [0])
        elif op not in _FREE_OPS:
            met.traffic_bytes += symbols[name]
            arg_head = rest.split(")")[0]
            for opnd in re.findall(r"%([\w.\-]+)", arg_head):
                met.traffic_bytes += symbols.get(opnd, 0)
        if op == "dot":
            met.dot_flops += _parse_dot_flops(result_txt, rest, sym_shapes)
        if op in COLLECTIVES:
            base = op.replace("-start", "")
            b = _nbytes(result_txt)
            met.collective_bytes += b
            if crosses_pod(rest, total_devices=total_devices):
                met.cross_pod_bytes += b
            met.coll_by_op[base] = met.coll_by_op.get(base, 0.0) + b
            met.coll_counts[base] = met.coll_counts.get(base, 0) + 1
        # call edges with loop multipliers; fusion bodies don't add traffic
        trip = 1.0
        if op == "while":
            tm = _TRIP_RE.search(rest)
            if tm:
                trip = float(tm.group(1))
        in_vmem = op in ("fusion", "reduce", "map", "sort", "scatter",
                         "reduce-window", "select-and-scatter")
        for am in re.finditer(r"(body|condition|to_apply|calls)=%?([\w.\-]+)",
                              rest):
            attr, callee = am.groups()
            mult = trip if (op == "while" and attr in ("body", "condition")) \
                else 1.0
            current.calls.append((callee, mult, not in_vmem))
        lm = _CALLEE_LIST_RE.search(rest)
        if lm:
            for callee in re.findall(r"%?([\w.\-]+)", lm.group(1)):
                current.calls.append((callee, 1.0, not in_vmem))
    return comps


def aggregate(comps: Dict[str, Computation]) -> Metrics:
    memo: Dict[str, Metrics] = {}

    def total(name: str, stack=()) -> Metrics:
        if name in memo:
            return memo[name]
        out = Metrics()
        if name not in comps or name in stack:
            return out
        c = comps[name]
        out.add(c.metrics)
        for callee, mult, inc_traffic in c.calls:
            out.add(total(callee, stack + (name,)), mult, inc_traffic)
        memo[name] = out
        return out

    called = {c for comp in comps.values() for c, _, _ in comp.calls}
    roots = [c for c in comps if c not in called]
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    root = entry or (roots[0] if roots else next(iter(comps), None))
    return total(root) if root else Metrics()


def analyze(hlo_text: str, total_devices: int = 0) -> Dict:
    met = aggregate(parse_hlo(hlo_text, total_devices))
    return {
        "dot_flops": met.dot_flops,
        "collective_bytes": met.collective_bytes,
        "cross_pod_bytes": met.cross_pod_bytes,
        "traffic_bytes": met.traffic_bytes,
        "coll_by_op": met.coll_by_op,
        "coll_counts": met.coll_counts,
    }
