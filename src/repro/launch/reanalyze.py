"""Recompute HLO-derived fields of dry-run records from the saved
(gzipped) HLO text — lets the roofline parser evolve without recompiling.

  PYTHONPATH=src python -m repro.launch.reanalyze \
      --dryrun artifacts/dryrun --hlo artifacts/hlo
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from repro.launch.hlo_analysis import analyze


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="artifacts/dryrun")
    ap.add_argument("--hlo", default="artifacts/hlo")
    args = ap.parse_args()
    n = 0
    for jpath in sorted(glob.glob(os.path.join(args.dryrun, "*.json"))):
        with open(jpath) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        mesh_tag = "multi" if rec["mesh"] == "2x16x16" else "single"
        hpath = os.path.join(
            args.hlo, f"{rec['arch']}_{rec['shape']}_{mesh_tag}.hlo.gz")
        if not os.path.exists(hpath):
            continue
        with gzip.open(hpath, "rt") as f:
            hl = analyze(f.read(), total_devices=rec.get("chips", 0))
        rec.update({
            "hlo_dot_flops": hl["dot_flops"],
            "hlo_traffic_bytes": hl["traffic_bytes"],
            "collective_bytes": hl["collective_bytes"],
            "cross_pod_bytes": hl["cross_pod_bytes"],
            "coll_by_op": hl["coll_by_op"],
            "coll_counts": hl["coll_counts"],
        })
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"reanalyzed {n} records")


if __name__ == "__main__":
    main()
