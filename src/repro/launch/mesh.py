"""Production mesh construction (TPU v5e target).

Defined as FUNCTIONS — importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* any jax init).
"""
from __future__ import annotations

import jax

# hardware constants used by the roofline analysis (TPU v5e)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_chips(mesh) -> int:
    return mesh.devices.size
