# Launch layer: mesh.py (production mesh), dryrun.py (multi-pod lower+
# compile), train.py / serve.py (CLI drivers), steps.py (sharded step
# builders), hlo_analysis.py (roofline accounting).
#
# NOTE: do not import dryrun from here — it sets XLA_FLAGS at import time.
from repro.launch.mesh import make_production_mesh  # noqa: F401
