"""Step builders: jitted train / prefill / serve steps with full sharding
annotations, plus ShapeDtypeStruct input factories for the dry-run.

LGR on the production mesh (DESIGN.md §2): the gradient-reduction schedule
is selected through the parameter LAYOUT, exactly the paper's insight that
the layout determines the schedule —

* ``--lgr mrr`` (flat)        : params replicated over (pod, data); autodiff
  gradient sync lowers to ONE flat all-reduce ring over every chip.
* ``--lgr har`` (hierarchical): params FSDP-sharded over ``data``,
  replicated over ``pod``; gradient sync lowers to reduce-scatter(data/ICI)
  → cross-pod all-reduce on 1/16-size shards → all-gather(data/ICI) — the
  paper's intra-reduce → leader-ring → broadcast, with each chip the leader
  of its shard slice.  Cross-pod (DCN) bytes drop 16x.

MPR (host-staged) is not expressible inside one HLO; it exists at the DRL
layer (``repro.comm.mpr_host``) where the paper applies it.  The DRL
builders below consume ``repro.comm.Communicator`` objects — the unified
communication subsystem owning mesh + strategy + grad-sync — instead of
string-passing schedule names.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, TrainConfig
from repro.dist.partition import (batch_specs, cache_specs, param_specs,
                                  to_shardings)
from repro.launch.mesh import batch_axes
from repro.models import transformer as T
from repro.optim import AdamState, adam_init, adam_update


# ----------------------------------------------------------- input specs ---
def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    if shape.mode == "decode":
        return {"token": jax.ShapeDtypeStruct((B,), i32),
                "pos": jax.ShapeDtypeStruct((B,), i32)}
    if cfg.frontend == "audio":
        return {"features": jax.ShapeDtypeStruct((B, S, cfg.frontend_feat_dim), dt),
                "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_),
                "targets": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.frontend == "vision":
        Tt = S - cfg.num_patches
        return {"tokens": jax.ShapeDtypeStruct((B, Tt), i32),
                "labels": jax.ShapeDtypeStruct((B, Tt), i32),
                "patches": jax.ShapeDtypeStruct(
                    (B, cfg.num_patches, cfg.frontend_feat_dim), dt)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32)}


def abstract_train_state(cfg: ModelConfig):
    params = T.init_abstract(cfg)
    opt = jax.eval_shape(adam_init, params)
    return params, opt


def abstract_cache(cfg: ModelConfig, shape: InputShape,
                   window_override: Optional[int] = None,
                   per_layer: bool = False):
    return jax.eval_shape(
        functools.partial(T.init_cache, cfg, shape.global_batch,
                          shape.seq_len, window_override,
                          per_layer=per_layer))


# ------------------------------------------------------------- shardings ---
def _act_spec(mesh, mode: str, kind: str = "dmodel"):
    bt = batch_axes(mesh)
    ax = bt if len(bt) > 1 else bt[0]
    if kind == "none" or mode == "decode":
        return None
    if kind == "seq":
        return P(ax, "model", None)
    return P(ax, None, "model")


def make_train_step(cfg: ModelConfig, mesh, shape: InputShape,
                    train_cfg: TrainConfig = TrainConfig(),
                    lgr: str = "har", act_sharding: str = "dmodel",
                    moe_spec: str = "contract"):
    """Returns (jitted_fn, example_args (SDS), arg_shardings)."""
    fsdp = (lgr == "har")
    params_sds, opt_sds = abstract_train_state(cfg)
    pspecs = param_specs(params_sds, mesh, fsdp=fsdp, moe_spec=moe_spec)
    ospecs = AdamState(step=P(),
                       mu=param_specs(params_sds, mesh, fsdp=fsdp,
                                      moe_spec=moe_spec),
                       nu=param_specs(params_sds, mesh, fsdp=fsdp,
                                      moe_spec=moe_spec))
    batch_sds = input_specs(cfg, shape)
    bspecs = batch_specs(batch_sds, mesh, batch_axes=batch_axes(mesh))
    T.set_activation_sharding(_act_spec(mesh, shape.mode, act_sharding))
    from repro.models.moe import set_moe_sharding
    bt = batch_axes(mesh)
    set_moe_sharding(bt if len(bt) > 1 else bt[0])

    M = max(train_cfg.microbatches, 1)

    def train_step(params, opt_state, batch):
        def loss_of(b):
            return lambda p: T.loss_fn(p, cfg, b, remat=train_cfg.remat)

        if M == 1:
            lval, grads = jax.value_and_grad(
                lambda p: T.loss_fn(p, cfg, batch,
                                    remat=train_cfg.remat))(params)
        else:
            # gradient accumulation: scan over M microbatches; activation
            # memory scales 1/M, gradient-sync bytes unchanged (one sync)
            mb = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(carry, b):
                acc, ltot = carry
                lv, g = jax.value_and_grad(
                    lambda p: T.loss_fn(p, cfg, b,
                                        remat=train_cfg.remat))(params)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / M, acc, g)
                return (acc, ltot + lv / M), None

            (grads, lval), _ = jax.lax.scan(mb_step,
                                            (acc0, jnp.float32(0.0)), mb)
        params, opt_state = adam_update(
            grads, opt_state, params, lr=train_cfg.learning_rate,
            beta1=train_cfg.beta1, beta2=train_cfg.beta2,
            weight_decay=train_cfg.weight_decay,
            grad_clip=train_cfg.grad_clip)
        return params, opt_state, {"loss": lval.astype(jnp.float32)}

    fn = jax.jit(
        train_step,
        in_shardings=(to_shardings(pspecs, mesh),
                      to_shardings(ospecs, mesh),
                      to_shardings(bspecs, mesh)),
        out_shardings=(to_shardings(pspecs, mesh),
                       to_shardings(ospecs, mesh),
                       NamedSharding(mesh, P())),
        donate_argnums=(0, 1))
    return fn, (params_sds, opt_sds, batch_sds)


def make_prefill_step(cfg: ModelConfig, mesh, shape: InputShape,
                      window_override: Optional[int] = None,
                      act_sharding: str = "dmodel"):
    params_sds = T.init_abstract(cfg)
    pspecs = param_specs(params_sds, mesh, fsdp=False)
    batch_sds = input_specs(cfg, shape)
    bspecs = batch_specs(batch_sds, mesh, batch_axes=batch_axes(mesh))
    cache_sds = abstract_cache(cfg, shape, window_override)
    cspecs = cache_specs(cache_sds, mesh,
                         batch_shardable=shape.global_batch > 1)
    T.set_activation_sharding(_act_spec(mesh, shape.mode, act_sharding))
    from repro.models.moe import set_moe_sharding
    bt = batch_axes(mesh)
    set_moe_sharding(bt if len(bt) > 1 else bt[0])

    def prefill_step(params, batch):
        logits, caches = T.prefill(params, cfg, batch, shape.seq_len,
                                   window_override)
        return logits.astype(jnp.float32), caches

    fn = jax.jit(
        prefill_step,
        in_shardings=(to_shardings(pspecs, mesh),
                      to_shardings(bspecs, mesh)),
        out_shardings=(NamedSharding(mesh, P()),
                       to_shardings(cspecs, mesh)))
    return fn, (params_sds, batch_sds)


def make_serve_step(cfg: ModelConfig, mesh, shape: InputShape,
                    window_override: Optional[int] = None,
                    cache_layout: str = "heads", params_fsdp: bool = False,
                    unroll: bool = False, per_layer_cache: bool = False):
    """One decode step over a seq_len-deep KV/state cache."""
    per_layer_cache = per_layer_cache and cfg.local_global \
        and not cfg.block_pattern
    unroll = unroll or per_layer_cache
    params_sds = T.init_abstract(cfg)
    pspecs = param_specs(params_sds, mesh, fsdp=params_fsdp)
    cache_sds = abstract_cache(cfg, shape, window_override,
                               per_layer=per_layer_cache)
    cspecs = cache_specs(cache_sds, mesh,
                         batch_shardable=shape.global_batch > 1,
                         layout=cache_layout)
    tok_sds = input_specs(cfg, shape)
    bspecs = batch_specs(tok_sds, mesh, batch_axes=batch_axes(mesh))
    T.set_activation_sharding(None)
    from repro.models.moe import set_moe_sharding
    bt = batch_axes(mesh)
    nb = 1
    for a, s in zip(mesh.axis_names, mesh.axis_sizes):
        if a in bt:
            nb *= s
    set_moe_sharding((bt if len(bt) > 1 else bt[0])
                     if shape.global_batch % nb == 0 else None)

    def serve_step(params, caches, token, pos):
        logits, caches = T.decode_step(params, cfg, token, pos, caches,
                                       window_override,
                                       unroll=unroll and not cfg.block_pattern)
        return logits.astype(jnp.float32), caches

    fn = jax.jit(
        serve_step,
        in_shardings=(to_shardings(pspecs, mesh),
                      to_shardings(cspecs, mesh),
                      to_shardings(bspecs["token"], mesh),
                      to_shardings(bspecs["pos"], mesh)),
        out_shardings=(NamedSharding(mesh, P()),
                       to_shardings(cspecs, mesh)),
        donate_argnums=(1,))
    return fn, (params_sds, cache_sds, tok_sds["token"], tok_sds["pos"])


# ------------------------------------------------------------- DRL steps ---
# The DRL layer's launch entry points, mirroring the LLM builders above:
# the launcher (not the algorithm module) decides which hot path a step
# compiles to and how the experience pipeline is laid out over GMIs.

def make_communicator(layout, cost_model=None, *, average: bool = True,
                      with_mesh: bool = False, calibrate: bool = False):
    """The layout's ``repro.comm.Communicator``: instance grid off the
    trainer MPL (incl. the trailing ``dev`` axis for multi-device GMIs),
    strategy from Algorithm 1 — or Table-2 cost-scored when a
    ``ReduceCostModel`` is supplied.  ``None`` for serving-only layouts.
    ``calibrate=True`` attaches a ``BandwidthCalibrator`` so measured
    reduce/transfer timings replace the model's static per-axis
    bandwidth defaults once the Table-2 inversion is conditioned."""
    comm = layout.communicator(cost_model, average=average,
                               with_mesh=with_mesh)
    if comm is not None and calibrate:
        comm.enable_calibration()
    return comm


def make_drl_train_step(env, ppo_cfg=None, grad_sync_fn=None,
                        fused: Optional[bool] = None, communicator=None):
    """Jitted sync-PPO iteration with the fused Pallas hot path on by
    default: the gae_scan kernel (GAE + advantage normalization in one
    VMEM pass) and single-gather minibatch shuffling.  An explicit
    ``ppo_cfg`` keeps its own ``use_fused_kernels`` unless ``fused``
    explicitly overrides it.  Gradient sync comes from ``communicator``
    (a ``repro.comm.Communicator``) when given, else ``grad_sync_fn``."""
    from repro.rl.ppo import PPOConfig, make_train_step
    cfg = ppo_cfg if ppo_cfg is not None \
        else PPOConfig(use_fused_kernels=True)
    if fused is not None and fused != cfg.use_fused_kernels:
        cfg = cfg._replace(use_fused_kernels=fused)
    if communicator is not None and communicator.mesh is not None:
        # same guard AsyncRunner applies: this builder jits an eager
        # per-instance step, and a mesh-attached Communicator's sync
        # closure is SPMD-only — failing here beats an unbound-axis-name
        # error deep inside the first traced step
        raise TypeError(
            "make_drl_train_step builds a plain-jit per-instance step; a "
            "mesh-attached Communicator's sync closure is SPMD-only (use "
            "Communicator.allreduce in a shard_map launcher, or a "
            "mesh-less Communicator here)")
    sync = communicator if communicator is not None else grad_sync_fn
    return make_train_step(env, cfg, sync), cfg


def make_experience_pipeline(layout, batch_mode: str = "stack",
                             batch_envs: Optional[int] = None,
                             overlap: bool = False):
    """Device-resident MCC pipeline wired from an async placement layout:
    ring slots sized to the layout's serving GMIs and the per-GMI GPU map
    passed through so the Migrator can direct-forward same-GPU groups.
    ``overlap=True`` double-buffers the rings so a flush is a buffer swap
    — serving GMIs keep packing while trainer GMIs consume the previous
    flush (paper §4.1 serve/train overlap)."""
    from repro.core.channels import MultiChannelPipeline
    gmi_gpu = {g.gmi_id: g.gpu_id for g in layout.manager.gmis.values()}
    return MultiChannelPipeline(layout.serving_gmis, layout.trainer_gmis,
                                gmi_gpu=gmi_gpu, batch_mode=batch_mode,
                                batch_envs=batch_envs, overlap=overlap)


def make_online_controller(layout, num_env: int, controller_cfg=None,
                           communicator=None):
    """Online Algorithm-2 controller seeded from an async placement
    layout: the live (serving_gpus, gmi_per_gpu, num_env) become the
    first measured configuration; the controller then re-plans the
    layout between training epochs from measured throughput and ring
    occupancy (see ``repro.core.controller``).  With a ``communicator``
    attached, measured reduce times can additionally re-plan the LGR
    strategy."""
    from repro.core.controller import OnlineGMIController
    gmis = layout.manager.gmis.values()
    serving_gpus = {g.gpu_id for g in gmis if g.role == "serving"}
    all_gpus = {g.gpu_id for g in gmis}
    per_gpu: Dict[int, int] = {}
    for g in gmis:
        per_gpu[g.gpu_id] = per_gpu.get(g.gpu_id, 0) + 1
    return OnlineGMIController(
        num_gpu=len(all_gpus), serving_gpus=max(len(serving_gpus), 1),
        gmi_per_gpu=max(per_gpu.values()), num_env=num_env,
        cfg=controller_cfg, communicator=communicator)


def make_async_runner(env, layout, overlap: bool = False,
                      online_controller: bool = False,
                      controller_cfg=None, communicator=None,
                      calibrate: bool = False, megakernel: bool = False,
                      **kwargs):
    """Async A3C driver over ``make_experience_pipeline(layout)``.

    ``megakernel=True`` flips the env onto the fused megakernel step
    path (``VectorEnv.with_megakernel``); on blocking (non-overlap)
    pipelines the runner then produces experience straight into the
    channel-ring slots via ``rl.rollout.collect_ring`` — the zero-copy
    producer path.
    ``overlap=True`` runs the double-buffered serve-while-train pipeline;
    ``online_controller=True`` attaches an Algorithm-2 controller that
    re-plans the GMI layout between training epochs from live stats.
    ``communicator=True`` builds the layout's Communicator (gradient
    reduction through ``repro.comm``, timed per round); an explicit
    Communicator instance is used as-is.  ``calibrate=True`` enables
    measured-bandwidth calibration on the communicator (building one
    from the layout if none was asked for): live reduce and
    channel-transfer timings then feed the Table-2 inversion, and the
    controller's strategy decisions re-score against the fitted
    bandwidths instead of the static defaults."""
    from repro.rl.a3c import AsyncRunner
    if megakernel:
        env = env.with_megakernel(True)
    if communicator is True or (calibrate and communicator is None):
        communicator = make_communicator(layout, calibrate=calibrate)
    elif calibrate and communicator is not None:
        communicator.enable_calibration()
    controller = None
    layout_builder = None
    if online_controller:
        controller = make_online_controller(
            layout, num_env=kwargs.get("num_envs", 64),
            controller_cfg=controller_cfg, communicator=communicator)

        def layout_builder(decision):
            # re-plan inside the SAME device universe the seed layout
            # was built over (may be synthetic ids in tests/benchmarks)
            from repro.core.placement import plan_async
            return plan_async(controller.num_gpu, decision.serving_gpus,
                              decision.gmi_per_gpu,
                              devices=layout.manager.devices,
                              devices_per_gpu=layout.manager.devices_per_gpu)

    return AsyncRunner(env, layout.serving_gmis, layout.trainer_gmis,
                       pipeline=make_experience_pipeline(layout,
                                                         overlap=overlap),
                       overlap=overlap, controller=controller,
                       layout_builder=layout_builder,
                       communicator=communicator or None, **kwargs)


def make_disagg_front(cfg, params, *, decode_engines: int = 2,
                      prefill_gmis: int = 1, max_slots: int = 4,
                      max_seq: int = 128,
                      window_override: Optional[int] = None,
                      communicator=None, latency_s: float = 100e-6,
                      min_gain: float = 1.05):
    """Disaggregated serving front (ROADMAP item 2): ``decode_engines``
    continuous-batching decode GMIs behind a ``RequestRouter`` plus
    ``prefill_gmis`` prefill specialists, joined by a ``CacheChannel``,
    with the per-request migrate-vs-local decision priced by a
    ``MigrationPlanner`` in Table-2 units (a ``communicator`` supplies
    calibrated bandwidths; the channel's own measured transfers sharpen
    them).  Both sides get factories, so ONE controller decision can
    re-split prefill/decode at runtime.  Pass the front as ``router=`` to
    :func:`make_async_runner` / :func:`make_fleet_supervisor` to put it
    under the single Algorithm-2 arbiter."""
    from repro.serve import (DisaggFront, MigrationPlanner, PrefillEngine,
                             RequestRouter, ServeEngine)

    def engine_factory(i, slots=max_slots):
        return ServeEngine(cfg, params, max_slots=slots, max_seq=max_seq,
                           window_override=window_override,
                           name=f"decode{i}")

    def prefill_factory(i):
        return PrefillEngine(cfg, params, max_seq=max_seq,
                             window_override=window_override,
                             name=f"prefill{i}")

    router = RequestRouter(engine_factory=engine_factory,
                           num_engines=decode_engines)
    planner = MigrationPlanner(communicator=communicator,
                               latency_s=latency_s, min_gain=min_gain)
    return DisaggFront(
        router, [prefill_factory(i) for i in range(max(prefill_gmis, 1))],
        planner=planner, prefill_factory=prefill_factory)


def make_fleet_supervisor(env, layout, *, plan=None, router=None,
                          ckpt_dir: Optional[str] = None,
                          ckpt_every: int = 0, probation: int = 2,
                          max_retries: int = 2, overlap: bool = False,
                          online_controller: bool = False, **kwargs):
    """Fault-tolerant elastic fleet over an async placement layout: a
    ``make_async_runner`` runner wrapped in a
    :class:`repro.fault.FleetSupervisor` — injection hooks armed at every
    seam, per-round failure classification, GPU quarantine with
    probation-gated re-admission, lossless re-plans onto the surviving
    pool, and (with ``ckpt_dir``/``ckpt_every``) periodic preemption-safe
    checkpoints through the atomic ``repro.checkpoint`` writer.  ``plan``
    is an optional :class:`repro.fault.FaultPlan` (deterministic fault
    schedule); ``router`` an optional serving front (``RequestRouter`` or
    ``DisaggFront``) to supervise too — it is ALSO handed to the runner,
    so the one controller instance arbitrating trainers and rollout
    actors folds the serving epochs into the same Algorithm-2 loop."""
    from repro.fault import FleetSupervisor
    runner = make_async_runner(env, layout, overlap=overlap,
                               online_controller=online_controller,
                               router=router, **kwargs)
    return FleetSupervisor(runner, layout, plan=plan, router=router,
                           ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                           probation=probation, max_retries=max_retries)
