from repro.optim.adam import (AdamState, SGDState, adam_init, adam_update,
                              cosine_warmup, sgd_init, sgd_update)  # noqa: F401
