"""Pure-JAX optimizers (no optax dependency): Adam/AdamW/SGD + schedules +
global-norm clipping, pytree-native so states shard like params under pjit.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils import global_norm


class AdamState(NamedTuple):
    step: jax.Array
    mu: object
    nu: object


def adam_init(params) -> AdamState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=zeros(params),
                     nu=zeros(params))


def adam_update(grads, state: AdamState, params, *, lr, beta1: float = 0.9,
                beta2: float = 0.95, eps: float = 1e-8,
                weight_decay: float = 0.0, grad_clip: float = 0.0):
    """Returns (new_params, new_state).  ``lr`` may be a scalar or callable
    of the step."""
    step = state.step + 1
    if callable(lr):
        lr_t = lr(step)
    else:
        lr_t = lr
    if grad_clip:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
    b1c = 1.0 - beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = beta1 * m + (1 - beta1) * g32
        v = beta2 * v + (1 - beta2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step, new_m, new_v)


class SGDState(NamedTuple):
    step: jax.Array
    mom: object


def sgd_init(params) -> SGDState:
    return SGDState(jnp.zeros((), jnp.int32),
                    jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                 params))


def sgd_update(grads, state: SGDState, params, *, lr, momentum: float = 0.9):
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    def upd(g, m, p):
        m = momentum * m + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), m

    pairs = jax.tree.map(upd, grads, state.mom, params)
    new_p = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, SGDState(step, new_m)


def cosine_warmup(base_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)
    return sched
