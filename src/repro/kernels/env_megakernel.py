"""Fused on-device environment megakernel (WarpDrive-style, ROADMAP 3).

One Pallas kernel advances a whole block of envs by one policy step:
chain-physics substep loop x ``spec.substeps``, reward, episode
bookkeeping, *predicated* auto-reset (fresh states are computed only when
some env in the block is done, from the counter-based PRNG in
``envs/physics.py`` — no per-step ``jax.random.split``), the next
observation, AND the producer-side experience write: obs/action/reward/
done land directly in the ``ChannelRing`` slot layout that
``kernels/channel_pack.py`` owns, so a rollout never stages a Trajectory
for ``pack_channels`` to re-copy.

Slot-write contract: for ring buffers shaped ``(T, S*N, ...)`` and a
rollout writing slot ``s``, the kernel invoked at step ``t`` over env
block ``i`` (of ``N // block_envs``) writes rows
``[t, s*N + i*BE : s*N + (i+1)*BE]`` — the obs the policy acted on, the
raw sampled action, and the step's reward/done.  ``(t, slot, N)`` ride
the scalar-prefetch operand so neither retraces the kernel.

The grid runs over env blocks; per-env state arrays are blocked
``(block_envs, ...)`` while the four ring buffers pass through as full
aliased blocks updated with dynamic stores (the ``channel_pack`` idiom —
untouched slots survive the call).  ``mega_step`` is the identically
fused XLA program (shared ``_step_core``) used off-TPU, exactly like
``pack_channels_xla`` backs ``pack_channels``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.envs.physics import counter_normal


def _step_core(q, qd, root, prev_action, t, seed, resets, action, sensor,
               tgt, masses, lengths, idx, *, chain, task, substeps, dt,
               max_episode_len):
    """Batched fused env step on (B, ...) arrays.

    Physics follows ``envs/physics.py::substep`` op for op (neighbor
    coupling via shifts instead of ``jnp.pad`` — same values, friendlier
    lowering), then reward/done/predicated-reset/observation.  Returns
    ``((q, qd, root, prev_action, t, resets), obs, reward, done)`` with
    ``obs`` observed AFTER any auto-reset, matching the vmap oracle.
    """
    (damping, coupling, stiffness, max_qd, gravity, torque_scale,
     ground_k, ground_c) = chain
    w_fwd, w_up, w_ctrl, w_tgt, fall_z = task
    a = jnp.clip(action, -1.0, 1.0)
    inertia = masses * jnp.square(lengths) + 1e-3
    h = dt / substeps

    def body(_, carry):
        q, qd, root = carry
        left = jnp.concatenate([q[:, :1], q[:, :-1]], axis=1)
        right = jnp.concatenate([q[:, 1:], q[:, -1:]], axis=1)
        lap = left - 2.0 * q + right
        grav = gravity * masses * lengths * jnp.sin(q)
        qdd = (torque_scale * a - damping * qd - stiffness * q - grav
               + coupling * lap) / inertia
        qd = jnp.clip(qd + h * qdd, -max_qd, max_qd)
        q = q + h * qd
        tip_h = root[:, 2] + jnp.sum(
            lengths * jnp.cos(jnp.cumsum(q, axis=1)), axis=1)
        pen = jnp.maximum(-tip_h, 0.0)
        contact_f = ground_k * pen - ground_c * jnp.minimum(
            root[:, 5], 0.0) * (pen > 0)
        thrust = jnp.stack([
            jnp.mean(jnp.sin(q) * a, axis=1) * torque_scale,
            0.1 * jnp.mean(jnp.cos(2 * q) * a, axis=1),
            contact_f - gravity * 0.5,
        ], axis=1)
        vel = (root[:, 3:] + h * thrust) * (1.0 - 0.02)
        pos = root[:, :3] + h * vel
        pos = jnp.concatenate(
            [pos[:, :2], jnp.maximum(pos[:, 2:3], 0.05)], axis=1)
        return q, qd, jnp.concatenate([pos, vel], axis=1)

    q, qd, root = jax.lax.fori_loop(0, substeps, body, (q, qd, root))
    upright = jnp.cos(jnp.mean(q, axis=1))
    reward = (w_fwd * root[:, 3]
              + w_up * upright
              - w_ctrl * jnp.sum(jnp.square(a), axis=1)
              - w_tgt * jnp.mean(jnp.square(q - tgt), axis=1)
              + 0.5)                                     # alive bonus
    t = t + 1
    done = (t >= max_episode_len) | (root[:, 2] < fall_z)

    def do_reset(state):
        q, qd, root, pa, t, resets = state
        # fresh draws only materialize under the predicate — the whole
        # point of counter-based reset (same values as reset_fn)
        fresh_q = 0.1 * counter_normal(seed[:, None], (resets + 1)[:, None],
                                       idx)
        d = done[:, None]
        # suite reset root pose [0, 0, 0.6, 0, 0, 0] built via iota so the
        # kernel body captures no constant arrays
        cidx = jax.lax.broadcasted_iota(jnp.int32, root.shape, 1)
        root0 = jnp.where(cidx == 2, 0.6, 0.0).astype(root.dtype)
        return (jnp.where(d, fresh_q, q),
                jnp.where(d, 0.0, qd),
                jnp.where(d, root0, root),
                jnp.where(d, 0.0, pa),
                jnp.where(done, 0, t),
                jnp.where(done, resets + 1, resets))

    q, qd, root, pa, t, resets = jax.lax.cond(
        jnp.any(done), do_reset, lambda s: s, (q, qd, root, a, t, resets))

    tip_h = root[:, 2] + jnp.sum(
        lengths * jnp.cos(jnp.cumsum(q, axis=1)), axis=1)
    raw = jnp.concatenate([
        root,
        jnp.sin(q), jnp.cos(q), qd,
        pa,
        jnp.stack([tip_h, root[:, 2] - 0.6,
                   jnp.mean(jnp.abs(qd), axis=1)], axis=1),
    ], axis=1)
    obs = jnp.tanh(raw @ sensor)
    return (q, qd, root, pa, t, resets), obs, reward, done


@functools.partial(jax.jit, static_argnames=("chain", "task", "substeps",
                                             "dt", "max_episode_len"))
def mega_step(q, qd, root, prev_action, t, seed, resets, action, sensor,
              tgt, masses, lengths, *, chain, task, substeps, dt,
              max_episode_len):
    """Fused XLA env step (no ring write): the off-TPU lowering of the
    megakernel, one jitted dispatch for physics + reward + bookkeeping +
    predicated reset + observation.  Returns
    ``(q, qd, root, prev_action, t, seed, resets, obs, reward, done)``."""
    idx = jnp.arange(q.shape[1], dtype=jnp.uint32)[None, :]
    (q, qd, root, pa, t, resets), obs, reward, done = _step_core(
        q, qd, root, prev_action, t, seed, resets, action, sensor, tgt,
        masses, lengths, idx, chain=chain, task=task, substeps=substeps,
        dt=dt, max_episode_len=max_episode_len)
    return q, qd, root, pa, t, seed, resets, obs, reward, done


def _mega_kernel(ts_ref, q_ref, qd_ref, root_ref, pa_ref, t_ref, seed_ref,
                 resets_ref, act_ref, obs_ref, sensor_ref, tgt_ref, m_ref,
                 l_ref, obuf_i, abuf_i, rbuf_i, dbuf_i,
                 q_o, qd_o, root_o, pa_o, t_o, seed_o, resets_o, obs_o,
                 rew_o, done_o, obuf_o, abuf_o, rbuf_o, dbuf_o, *,
                 chain, task, substeps, dt, max_episode_len, block_envs):
    del obuf_i, abuf_i, rbuf_i, dbuf_i        # aliased to outputs
    i = pl.program_id(0)
    step_t = ts_ref[0]
    col = ts_ref[1] * ts_ref[2] + i * block_envs    # slot * N + block base
    # experience write (the obs the policy acted on + the raw action)
    obuf_o[pl.ds(step_t, 1), pl.ds(col, block_envs), :] = obs_ref[...][None]
    abuf_o[pl.ds(step_t, 1), pl.ds(col, block_envs), :] = act_ref[...][None]
    idx = jax.lax.broadcasted_iota(jnp.uint32,
                                   (block_envs, q_ref.shape[1]), 1)
    (q, qd, root, pa, t, resets), obs, reward, done = _step_core(
        q_ref[...], qd_ref[...], root_ref[...], pa_ref[...], t_ref[...],
        seed_ref[...], resets_ref[...], act_ref[...], sensor_ref[...],
        tgt_ref[...], m_ref[...], l_ref[...], idx, chain=chain, task=task,
        substeps=substeps, dt=dt, max_episode_len=max_episode_len)
    done_f = done.astype(jnp.float32)
    rbuf_o[pl.ds(step_t, 1), pl.ds(col, block_envs)] = reward[None]
    dbuf_o[pl.ds(step_t, 1), pl.ds(col, block_envs)] = done_f[None]
    q_o[...] = q
    qd_o[...] = qd
    root_o[...] = root
    pa_o[...] = pa
    t_o[...] = t
    seed_o[...] = seed_ref[...]
    resets_o[...] = resets
    obs_o[...] = obs
    rew_o[...] = reward
    done_o[...] = done_f


def env_mega_step(q, qd, root, prev_action, t, seed, resets, action, obs,
                  bufs, step_t, slot, sensor, tgt, masses, lengths, *,
                  chain, task, substeps, dt, max_episode_len,
                  block_envs=None, interpret: bool = False):
    """One fused env step over all N envs, grid over env blocks, writing
    the experience row straight into the ring slot (see module docstring).

    ``bufs`` is the ``{obs, actions, rewards, dones}`` subset of a
    ``ChannelRing`` allocation; the four buffers are aliased input ->
    output so untouched slots/rows survive.  Returns the ``mega_step``
    tuple followed by the updated ring dict."""
    N, J = q.shape
    be = block_envs or min(N, 256)
    assert N % be == 0, (N, be)
    nb = N // be
    grid = (nb,)
    ts = jnp.stack([jnp.asarray(step_t, jnp.int32),
                    jnp.asarray(slot, jnp.int32),
                    jnp.int32(N)])

    def blk(shape):
        return pl.BlockSpec((be,) + shape, lambda i, ts: (i,) + (0,) * len(shape))

    def full(shape):
        return pl.BlockSpec(shape, lambda i, ts: (0,) * len(shape))

    state_specs = [blk((J,)), blk((J,)), blk((6,)), blk((J,)),
                   blk(()), blk(()), blk(())]
    ring_keys = ("obs", "actions", "rewards", "dones")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=state_specs + [blk((J,)), blk((obs.shape[1],)),
                                full(sensor.shape), full(tgt.shape),
                                full(masses.shape), full(lengths.shape)]
        + [full(bufs[c].shape) for c in ring_keys],
        out_specs=state_specs + [blk((obs.shape[1],)), blk(()), blk(())]
        + [full(bufs[c].shape) for c in ring_keys],
    )
    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype)
                 for x in (q, qd, root, prev_action, t, seed, resets, obs)]
    out_shape += [jax.ShapeDtypeStruct((N,), jnp.float32),
                  jax.ShapeDtypeStruct((N,), jnp.float32)]
    out_shape += [jax.ShapeDtypeStruct(bufs[c].shape, bufs[c].dtype)
                  for c in ring_keys]
    # alias indices count the scalar-prefetch operand: ring inputs sit at
    # 14..17 (ts + 13 arrays ahead of them), ring outputs at 10..13
    out = pl.pallas_call(
        functools.partial(_mega_kernel, chain=chain, task=task,
                          substeps=substeps, dt=dt,
                          max_episode_len=max_episode_len, block_envs=be),
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases={14 + k: 10 + k for k in range(4)},
        interpret=interpret,
    )(ts, q, qd, root, prev_action, t, seed, resets, action, obs,
      sensor, tgt, masses, lengths, *[bufs[c] for c in ring_keys])
    return tuple(out[:10]) + (dict(zip(ring_keys, out[10:])),)


def mega_step_ring(q, qd, root, prev_action, t, seed, resets, action,
                   obs, bufs, step_t, slot, sensor, tgt, masses,
                   lengths, *, chain, task, substeps, dt,
                   max_episode_len):
    """The identically fused XLA program (un-jitted, scan-composable):
    the ``_step_core`` step + dynamic-update-slice ring writes.  Same
    signature/contract as :func:`env_mega_step`; the off-TPU producer
    path, called inside ``rl.rollout.collect_ring``'s jitted scan."""
    N = q.shape[0]
    col = jnp.asarray(slot, jnp.int32) * N
    st = jnp.asarray(step_t, jnp.int32)
    z = jnp.int32(0)
    idx = jnp.arange(q.shape[1], dtype=jnp.uint32)[None, :]
    (q2, qd2, root2, pa, t2, resets2), obs2, reward, done = _step_core(
        q, qd, root, prev_action, t, seed, resets, action, sensor, tgt,
        masses, lengths, idx, chain=chain, task=task, substeps=substeps,
        dt=dt, max_episode_len=max_episode_len)
    out = (q2, qd2, root2, pa, t2, seed, resets2, obs2)
    bufs = {
        "obs": jax.lax.dynamic_update_slice(bufs["obs"], obs[None],
                                            (st, col, z)),
        "actions": jax.lax.dynamic_update_slice(bufs["actions"],
                                                action[None], (st, col, z)),
        "rewards": jax.lax.dynamic_update_slice(bufs["rewards"],
                                                reward[None], (st, col)),
        "dones": jax.lax.dynamic_update_slice(
            bufs["dones"], done.astype(jnp.float32)[None], (st, col)),
    }
    return out + (reward, done.astype(jnp.float32)) + (bufs,)


env_mega_step_xla = functools.partial(
    jax.jit, donate_argnums=(9,),
    static_argnames=("chain", "task", "substeps", "dt",
                     "max_episode_len"))(mega_step_ring)
