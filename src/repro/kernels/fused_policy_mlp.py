"""Fused policy-MLP Pallas TPU kernel.

The paper's agent-inference hot spot is a chain of SMALL GEMMs
(e.g. ShadowHand 211:512:512:512:256) interleaved with simulation — each
layer individually underutilizes the device and round-trips activations
through HBM.  The GPU fix is spatial multiplexing; the TPU-native rethink
is FUSION: the whole trunk runs in ONE pallas_call with every weight matrix
resident in VMEM (a few MB), grid only over batch blocks — zero HBM traffic
between layers, one kernel launch per action batch.

Grid: (num_batch_blocks,)
  x block: (block_n, in_dim) VMEM; weights/biases: full, VMEM.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(*refs, num_layers):
    x_ref = refs[0]
    o_ref = refs[-1]
    ws = refs[1:1 + num_layers]
    bs = refs[1 + num_layers:1 + 2 * num_layers]
    h = x_ref[...].astype(jnp.float32)
    for w_ref, b_ref in zip(ws, bs):
        h = jax.lax.dot(h, w_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        h = jnp.tanh(h + b_ref[...].astype(jnp.float32))
    o_ref[...] = h.astype(o_ref.dtype)


def fused_policy_mlp(x, weights: Sequence, biases: Sequence, *,
                     block_n: int = 256, interpret: bool = False):
    """x: (N, in_dim); weights[i]: (d_i, d_{i+1}); tanh after every layer.

    Returns (N, out_dim).  The whole weight set must fit VMEM (true for all
    Table-6 policies: ShadowHand is the largest at ~2.6 MB f32).
    """
    N, d_in = x.shape
    L = len(weights)
    assert len(biases) == L
    d_out = weights[-1].shape[1]
    bn = min(block_n, N)
    grid = (pl.cdiv(N, bn),)

    in_specs = [pl.BlockSpec((bn, d_in), lambda i: (i, 0))]
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
    for b in biases:
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))

    out = pl.pallas_call(
        functools.partial(_kernel, num_layers=L),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bn, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d_out), x.dtype),
        interpret=interpret,
    )(x, *weights, *biases)
    return out
