"""Paged gather-decode attention Pallas TPU kernel.

One decode step (Sq == 1 per batch row) reading a slot's KV cache THROUGH
its page table: the physical cache is a shared pool of fixed-size pages
``(num_pages, page, n_kv, hd)`` and each batch row owns a row of page ids
``table (B, M)`` mapping virtual page v (absolute positions
``[v*page, (v+1)*page)``) to a physical page (-1 = unmapped).  The table
and the per-row absolute positions ride in as scalar-prefetch operands, so
the k/v BlockSpec index maps dereference the table directly — the kernel
never materializes the gathered (B, M*page, ...) view the jnp fallback in
``repro.models.attention`` builds.

Grid: (batch * kv_heads, M) — the page axis is innermost/sequential, so
the online-softmax accumulators live in VMEM scratch across it exactly as
in ``flash_attention.py``.  Invalid pages (table < 0) index the trash page
0 and are fully masked via the prefetched table; empty page slots are
masked by ``slot_pos < 0``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_BIG_WINDOW = 1 << 30


def _kernel(tbl_ref, pos_ref, win_ref, q_ref, k_ref, v_ref, sp_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale, softcap, kv_heads, num_pages):
    h = pl.program_id(0)
    mi = pl.program_id(1)
    b = h // kv_heads

    @pl.when(mi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (page, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, page)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qpos = pos_ref[b]
    kpos = sp_ref[0]                                  # (page,)
    valid = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - win_ref[0])
    valid &= tbl_ref[b, mi] >= 0
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    v = v_ref[0, 0].astype(jnp.float32)               # (page, hd)
    v = jnp.where(valid[:, None], v, 0.0)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(mi == num_pages - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, slot_pos, table, positions,
                           *, window=None, softcap=None, scale=None,
                           interpret: bool = False):
    """q: (B, H, hd) one decode token per row; k/v pages: (N, page, KH, hd);
    slot_pos: (N, page) absolute position per page slot (-1 empty); table:
    (B, M) physical page per virtual page (-1 unmapped); positions: (B,)
    absolute q position per row.  Returns (B, H, hd)."""
    B, H, hd = q.shape
    N, page, KH, _ = k_pages.shape
    M = table.shape[1]
    assert H % KH == 0, "GQA requires q heads to be a multiple of kv heads"
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    # window may be a traced scalar (per-layer windows under scan)
    win = jnp.full((1,), _BIG_WINDOW, jnp.int32) if window is None \
        else jnp.asarray(window, jnp.int32).reshape(1)

    qh = q.reshape(B * KH, G, hd)                     # head h = kh*G + g
    kp = k_pages.transpose(0, 2, 1, 3)                # (N, KH, page, hd)
    vp = v_pages.transpose(0, 2, 1, 3)

    def page_row(h, m, tbl, pos, w):
        return jnp.maximum(tbl[h // KH, m], 0), h % KH, 0, 0

    kernel = functools.partial(_kernel, scale=scale, softcap=softcap,
                               kv_heads=KH, num_pages=M)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B * KH, M),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda h, m, tbl, pos, w: (h, 0, 0)),
            pl.BlockSpec((1, 1, page, hd), page_row),
            pl.BlockSpec((1, 1, page, hd), page_row),
            pl.BlockSpec((1, page),
                         lambda h, m, tbl, pos, w:
                         (jnp.maximum(tbl[h // KH, m], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda h, m, tbl, pos, w:
                               (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KH, G, hd), q.dtype),
        interpret=interpret,
    )(table, positions, win, qh, kp, vp, slot_pos)
    return out.reshape(B, H, hd)
