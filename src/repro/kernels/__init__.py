# Pallas TPU kernels for the compute hot spots (validated in interpret mode
# on CPU against the ref.py oracles; compile to Mosaic on TPU backends):
#   flash_attention.py  — GQA/causal/window/softcap blocked online softmax
#   fused_policy_mlp.py — whole Table-6 policy trunk in one VMEM-resident call
#   mlstm_scan.py       — chunkwise mLSTM matrix-memory recurrence
from repro.kernels import ops, ref  # noqa: F401
