"""Fused GAE + advantage-normalization Pallas kernel, and its A3C
sibling: the fused n-step discounted-return reverse scan
(:func:`nstep_scan`).

The PPO hot path runs generalized advantage estimation as an unfused
``lax.scan`` followed by a separate mean/std normalization — three HBM
round-trips over the same (T, N) tensors.  This kernel keeps the whole
trajectory block resident in VMEM and does everything in one pass:

  1. reverse scan  adv_t = delta_t + gamma*lam*(1-d_t) * adv_{t+1}
  2. returns_t     = adv_t + v_t
  3. advs          = (advs - mean) / (std + eps)   over the full T*N block

Grid is (1,): trajectory blocks for the paper's workloads (T<=64,
N<=4096 f32) are well under VMEM; the normalization is global over the
batch so blocking N would force a cross-block reduction for no win.

Numerics note: normalizing once over the whole batch (not per minibatch)
is the standard large-batch PPO formulation; the unfused path keeps the
per-minibatch normalization, so the two paths are shape-compatible but not
bit-identical — by design.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, v_ref, d_ref, last_ref, adv_ref, ret_ref, *,
            gamma: float, lam: float, eps: float):
    T = r_ref.shape[0]
    last = last_ref[...]                              # (1, N)

    def step(i, carry):
        adv, v_next = carry
        t = T - 1 - i
        r = r_ref[pl.ds(t, 1), :]
        v = v_ref[pl.ds(t, 1), :]
        nonterm = 1.0 - d_ref[pl.ds(t, 1), :]
        delta = r + gamma * v_next * nonterm - v
        adv = delta + gamma * lam * nonterm * adv
        adv_ref[pl.ds(t, 1), :] = adv
        ret_ref[pl.ds(t, 1), :] = adv + v
        return (adv, v)

    jax.lax.fori_loop(0, T, step, (jnp.zeros_like(last), last))

    a = adv_ref[...]
    mean = jnp.mean(a)
    std = jnp.sqrt(jnp.maximum(jnp.mean((a - mean) ** 2), 0.0))
    adv_ref[...] = (a - mean) / (std + eps)


def gae_scan(rewards, values, dones, last_value, *, gamma: float = 0.99,
             lam: float = 0.95, eps: float = 1e-8,
             interpret: bool = False):
    """rewards/values/dones: (T, N); last_value: (N,).

    Returns (normalized_advantages, returns), both (T, N) float32.
    """
    T, N = rewards.shape
    f32 = jnp.float32
    last = jnp.asarray(last_value, f32).reshape(1, N)

    def full(shape):
        return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    advs, rets = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, lam=lam, eps=eps),
        grid=(1,),
        in_specs=[full((T, N))] * 3 + [full((1, N))],
        out_specs=[full((T, N)), full((T, N))],
        out_shape=[jax.ShapeDtypeStruct((T, N), f32),
                   jax.ShapeDtypeStruct((T, N), f32)],
        interpret=interpret,
    )(rewards.astype(f32), values.astype(f32), dones.astype(f32), last)
    return advs, rets


# ------------------------------------------------------ A3C n-step scan ----
def _nstep_kernel(r_ref, d_ref, boot_ref, ret_ref, *, gamma: float):
    T = r_ref.shape[0]

    def step(i, carry):
        t = T - 1 - i
        r = r_ref[pl.ds(t, 1), :]
        nonterm = 1.0 - d_ref[pl.ds(t, 1), :]
        g = r + gamma * carry * nonterm
        ret_ref[pl.ds(t, 1), :] = g
        return g

    jax.lax.fori_loop(0, T, step, boot_ref[...])


def nstep_scan(rewards, dones, bootstrap, *, gamma: float = 0.99,
               interpret: bool = False):
    """Fused A3C n-step discounted returns: the whole (T, N) trajectory
    block stays in VMEM for the reverse scan
    ``G_t = r_t + gamma * (1 - d_t) * G_{t+1}`` bootstrapped from the
    actor's last value estimate.

    rewards/dones: (T, N); bootstrap: (N,).  Returns (T, N) float32.
    """
    T, N = rewards.shape
    f32 = jnp.float32
    boot = jnp.asarray(bootstrap, f32).reshape(1, N)

    def full(shape):
        return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    return pl.pallas_call(
        functools.partial(_nstep_kernel, gamma=gamma),
        grid=(1,),
        in_specs=[full((T, N)), full((T, N)), full((1, N))],
        out_specs=full((T, N)),
        out_shape=jax.ShapeDtypeStruct((T, N), f32),
        interpret=interpret,
    )(rewards.astype(f32), dones.astype(f32), boot)
