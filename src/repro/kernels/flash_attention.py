"""Flash attention Pallas TPU kernel: blocked online softmax with GQA,
causal / sliding-window masking, and logit softcap.

TPU adaptation (DESIGN.md §2/§4): VMEM-tiled q/k/v blocks with MXU-aligned
(multiples-of-128) block shapes; the innermost grid axis (kv blocks) is
sequential on TPU, so the running max / denominator / accumulator live in
VMEM scratch across that axis — the same algorithm as
``repro.models.attention._chunked_attention``, tiled for the hardware.

Grid: (batch * q_heads, num_q_blocks, num_kv_blocks)
  q block:   (block_q, head_dim)      VMEM
  k/v block: (block_k, head_dim)      VMEM   (kv row = b*KH + q_head//G)
  scratch:   acc (block_q, head_dim) f32, m/l (block_q,) f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, window, softcap, block_q, block_k, num_kb,
            seq_q, seq_kv):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = (qpos < seq_q) & (kpos < seq_kv)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    v = v_ref[0].astype(jnp.float32)
    # zero padded kv rows: p is 0 there, but 0 * NaN-padding = NaN
    vmask = (kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k, 1), 0)) < seq_kv
    v = jnp.where(vmask, v, 0.0)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kj == num_kb - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    softcap=None, scale=None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, Sq, H, hd); k/v: (B, Skv, KH, hd).  Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    assert H % KH == 0, "GQA requires q heads to be a multiple of kv heads"
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Skv, bk)

    # layout: fold (B, heads) into the first grid axis
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KH, Skv, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KH, Skv, hd)

    def kv_row(h, i, j):
        # grid row h = b * H + q_head  ->  kv row = b * KH + q_head // G
        return (h // H) * KH + (h % H) // G, j, 0

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, num_kb=nk, seq_q=Sq, seq_kv=Skv)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), kv_row),
            pl.BlockSpec((1, bk, hd), kv_row),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
