"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                  scale=None):
    """q: (B, Sq, H, hd); k/v: (B, Skv, KH, hd) with H % KH == 0."""
    B, Sq, H, hd = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KH, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def paged_attention_ref(q, k_pages, v_pages, slot_pos, table, positions, *,
                        window=None, softcap=None, scale=None):
    """Gather-decode oracle for ``paged_decode.paged_decode_attention``.

    q: (B, H, hd) one decode token per row; k/v pages: (N, page, KH, hd);
    slot_pos: (N, page) absolute positions (-1 empty); table: (B, M)
    physical page ids (-1 unmapped -> masked); positions: (B,) absolute q
    position per row.  Gathers each row's pages into position order and
    runs plain masked softmax attention."""
    B, H, hd = q.shape
    N, page, KH, _ = k_pages.shape
    M = table.shape[1]
    G = H // KH
    scale = scale if scale is not None else hd ** -0.5
    tsafe = jnp.maximum(table, 0)
    k = k_pages[tsafe].reshape(B, M * page, KH, hd).astype(jnp.float32)
    v = v_pages[tsafe].reshape(B, M * page, KH, hd).astype(jnp.float32)
    kpos = jnp.where(jnp.repeat(table >= 0, page, axis=1),
                     slot_pos[tsafe].reshape(B, M * page), -1)
    qf = (q.astype(jnp.float32) * scale).reshape(B, KH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qpos = positions[:, None]
    valid = (kpos >= 0) & (kpos <= qpos)
    if window is not None:
        valid &= kpos > qpos - window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # zero invalid v rows: garbage pool values must not leak through the
    # uniform-softmax degrade of fully-masked rows
    o = jnp.einsum("bkgs,bskd->bkgd", p,
                   jnp.where(valid[:, :, None, None], v, 0.0))
    return o.reshape(B, H, hd).astype(q.dtype)


def policy_mlp_ref(x, weights, biases):
    """x: (N, in); tanh MLP trunk: h = tanh(h @ w + b) per layer."""
    h = x.astype(jnp.float32)
    for w, b in zip(weights, biases):
        h = jnp.tanh(h @ w.astype(jnp.float32) + b.astype(jnp.float32))
    return h.astype(x.dtype)


def gae_norm_ref(rewards, values, dones, last_value, gamma: float = 0.99,
                 lam: float = 0.95, eps: float = 1e-8):
    """Fused-GAE oracle: reverse scan + global advantage normalization.

    rewards/values/dones: (T, N); last_value: (N,).  Returns
    (normalized_advs, returns), both (T, N) float32."""
    r = rewards.astype(jnp.float32)
    v = values.astype(jnp.float32)
    d = dones.astype(jnp.float32)
    last = last_value.astype(jnp.float32)

    def step(carry, xs):
        adv_next, v_next = carry
        rt, vt, dt = xs
        nonterm = 1.0 - dt
        delta = rt + gamma * v_next * nonterm - vt
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, vt), adv

    (_, _), advs = jax.lax.scan(step, (jnp.zeros_like(last), last),
                                (r, v, d), reverse=True)
    returns = advs + v
    advs = (advs - advs.mean()) / (advs.std() + eps)
    return advs, returns


def nstep_returns_ref(rewards, dones, bootstrap, gamma: float = 0.99):
    """Fused n-step-return oracle: reverse discounted scan bootstrapped
    from the last value.  rewards/dones: (T, N); bootstrap: (N,).
    Returns (T, N) float32."""
    r = rewards.astype(jnp.float32)
    d = dones.astype(jnp.float32)

    def step(carry, xs):
        rt, dt = xs
        g = rt + gamma * carry * (1.0 - dt)
        return g, g

    _, rets = jax.lax.scan(step, bootstrap.astype(jnp.float32), (r, d),
                           reverse=True)
    return rets


def pack_channels_ref(bufs, payloads, slot):
    """Ring-pack oracle via functional .at[] updates (same layout as
    ``channel_pack``: slot-aligned columns / rows)."""
    T, N = payloads["rewards"].shape
    col = slot * N
    boot = jnp.asarray(payloads["bootstrap"]).reshape(1, N)
    ver = jnp.asarray(payloads["actor_version"], jnp.int32).reshape(1, 1)
    return {
        "obs": bufs["obs"].at[:, col:col + N, :].set(payloads["obs"]),
        "actions": bufs["actions"].at[:, col:col + N, :].set(
            payloads["actions"]),
        "rewards": bufs["rewards"].at[:, col:col + N].set(
            payloads["rewards"]),
        "dones": bufs["dones"].at[:, col:col + N].set(payloads["dones"]),
        "bootstrap": bufs["bootstrap"].at[slot:slot + 1, :].set(boot),
        "actor_version": bufs["actor_version"].at[slot:slot + 1, :].set(ver),
    }


def env_mega_step_ref(q, qd, root, prev_action, t, seed, resets, action,
                      obs, bufs, step_t, slot, sensor, tgt, masses,
                      lengths, *, chain, task, substeps, dt,
                      max_episode_len):
    """Env-megakernel oracle: the *vmapped per-env* composition of
    ``envs/physics.py::rollout_substeps`` + suite reward/bookkeeping with
    a MATERIALIZED counter-based auto-reset (fresh state computed for
    every env, selected by ``jnp.where``), plus functional ``.at[]`` ring
    writes in the ``channel_pack`` slot layout.  ``step_t``/``slot`` are
    concrete ints here.  Returns the ``env_mega_step`` tuple:
    ``(q, qd, root, prev_action, t, seed, resets, obs, reward, done_f32,
    bufs)``."""
    from repro.envs.physics import (ChainParams, counter_normal,
                                    rollout_substeps, tip_height)
    params = ChainParams(masses, lengths, *chain)
    w_fwd, w_up, w_ctrl, w_tgt, fall_z = task
    J = q.shape[1]
    root0 = jnp.array([0., 0., 0.6, 0., 0., 0.])

    def one(q, qd, root, pa, t, seed, resets, a_raw):
        a = jnp.clip(a_raw, -1.0, 1.0)
        q, qd, root = rollout_substeps(q, qd, root, a, params, dt, substeps)
        reward = (w_fwd * root[3]
                  + w_up * jnp.cos(jnp.mean(q))
                  - w_ctrl * jnp.sum(jnp.square(a))
                  - w_tgt * jnp.mean(jnp.square(q - tgt))
                  + 0.5)
        t = t + 1
        done = (t >= max_episode_len) | (root[2] < fall_z)
        fresh_q = 0.1 * counter_normal(seed, resets + 1,
                                       jnp.arange(J, dtype=jnp.uint32))
        q = jnp.where(done, fresh_q, q)
        qd = jnp.where(done, 0.0, qd)
        root = jnp.where(done, root0, root)
        pa = jnp.where(done, 0.0, a)
        t = jnp.where(done, 0, t)
        resets = jnp.where(done, resets + 1, resets)
        tip = tip_height(q, root[2], params)
        raw = jnp.concatenate([
            root, jnp.sin(q), jnp.cos(q), qd, pa,
            jnp.array([tip, root[2] - 0.6, jnp.mean(jnp.abs(qd))]),
        ])
        return q, qd, root, pa, t, resets, jnp.tanh(raw @ sensor), \
            reward, done

    q, qd, root, pa, t, resets, obs2, reward, done = jax.vmap(one)(
        q, qd, root, prev_action, t, seed, resets, action)
    N = q.shape[0]
    col = slot * N
    done_f = done.astype(jnp.float32)
    bufs = {
        "obs": bufs["obs"].at[step_t, col:col + N, :].set(obs),
        "actions": bufs["actions"].at[step_t, col:col + N, :].set(action),
        "rewards": bufs["rewards"].at[step_t, col:col + N].set(reward),
        "dones": bufs["dones"].at[step_t, col:col + N].set(done_f),
    }
    return (q, qd, root, pa, t, seed, resets, obs2, reward, done_f, bufs)


def mlstm_chunkwise_ref(q, k, v, log_i, log_f, chunk: int = 64):
    """q/k/v: (B, H, S, dh); log_i/log_f: (B, H, S).  Chunkwise-parallel
    stabilized mLSTM, zero initial state.  Returns h: (B, H, S, dh)."""
    from repro.models.ssm import _mlstm_chunk
    B, H, S, dh = q.shape
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    C = jnp.zeros((B, H, dh, dh), jnp.float32)
    n = jnp.zeros((B, H, dh), jnp.float32)
    m = jnp.zeros((B, H), jnp.float32)
    outs = []
    for c in range(nc):
        sl = slice(c * L, (c + 1) * L)
        h, C, n, m = _mlstm_chunk(
            q[:, :, sl].astype(jnp.float32), k[:, :, sl].astype(jnp.float32),
            v[:, :, sl].astype(jnp.float32), log_i[:, :, sl], log_f[:, :, sl],
            C, n, m)
        outs.append(h)
    return jnp.concatenate(outs, axis=2).astype(q.dtype)
