"""Pallas ring-buffer packing kernel for the MCC experience pipeline.

The paper's Compressor (§4.2) raises transfer granularity by batching
per-channel payloads across agents.  The seed implementation staged every
push through host lists and re-materialized each channel with
``jnp.concatenate`` on every flush — the fine-grained-transfer pathology of
arXiv:2012.04210.  Here a push instead writes the agent's (T, N, ...) block
in place into a preallocated per-channel device ring buffer at a
slot-aligned column offset, so a flush degenerates to one pointer-bump
slice per channel.

Ring layout (S = ring slots, one slot per push):

    obs           (T, S*N, obs_dim)     slot s -> columns [s*N, (s+1)*N)
    actions       (T, S*N, act_dim)
    rewards       (T, S*N)
    dones         (T, S*N)
    bootstrap     (S, N)                slot s -> row s
    actor_version (S, 1)                slot s -> row s

A double-buffered ring (paper §4.1 serve/train overlap) alternates
storage *generations*: pushes stage device-resident payload references
(zero device work on the producer's critical path) and the buffer swap
packs the whole back generation slot-by-index in ONE fused, donation-free
dispatch (``pack_generation``), handing the result to the consumer while
the front generation keeps staging.  Two alternatives were measured and
rejected on the Table-8 workload:

* both buffers in one ``2*S``-slot allocation with swap = index flip —
  every swap slice-copies its half AND the next push donates buffers
  with in-flight snapshot reads, serializing producer behind consumer;
* per-push in-place packing into a fresh generation (this file's kernel,
  as used by the blocking ring) — each donating push must wait for the
  previous push's buffers to materialize, so with a trainer consume in
  flight the donation chain re-serializes serve behind train (donation
  of a buffer with a pending definition blocks at dispatch).

The staged-generation pack has no donation anywhere, so serving runs
ahead of the trainer's consumption limited only by ring capacity.

All six channels are packed by ONE ``pallas_call`` (grid (1,)): the slot
index rides in SMEM and every ring buffer is aliased input->output, so the
kernel performs six in-place dynamic stores and never touches the
untouched slots.  On CPU/GPU backends the identical program is lowered
through XLA ``dynamic_update_slice`` (``pack_channels_xla``) — donated and
jitted, so it is also an in-place pointer-bump where the runtime allows.

Slot-write contract (zero-copy producers)
-----------------------------------------
The layout above is a public contract, not a private detail of this
file: the env megakernel (``kernels/env_megakernel.py``, driven by
``rl.rollout.collect_ring`` through ``ChannelRing.acquire``/``commit``)
writes the four produced channels DIRECTLY — rollout step ``t`` into
ring slot ``s`` over envs ``[s*N, (s+1)*N)`` stores, at row ``t`` of
that column block, the observation the policy acted on, the RAW sampled
action (pre-clip; the env clips internally, trainers recompute
log-probs from what was sampled), the step reward, and ``done`` as
float32.  ``bootstrap`` row ``s`` and ``actor_version`` row ``s`` land
at commit time.  A producer-written slot is byte-identical to the same
push staged through :func:`pack_channels` — ``snapshot`` and every
consumer downstream cannot tell the two apart, which is exactly why the
staging copy can be skipped.  Anything changing this layout must move
producer and packer together.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHANNELS = ("obs", "actions", "rewards", "dones", "bootstrap",
            "actor_version")


def _as_payloads(payloads: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Normalize payload ranks: bootstrap (N,)->(1,N), version ()->(1,1)."""
    out = dict(payloads)
    out["bootstrap"] = jnp.asarray(payloads["bootstrap"]).reshape(1, -1)
    out["actor_version"] = jnp.asarray(
        payloads["actor_version"], jnp.int32).reshape(1, 1)
    return out


# ----------------------------------------------------------------- pallas --
def _kernel(slot_ref, obs_p, act_p, rew_p, done_p, boot_p, ver_p,
            obs_i, act_i, rew_i, done_i, boot_i, ver_i,
            obs_o, act_o, rew_o, done_o, boot_o, ver_o, *, n_env):
    del obs_i, act_i, rew_i, done_i, boot_i, ver_i  # aliased to outputs
    s = slot_ref[0, 0]
    col = s * n_env
    obs_o[:, pl.ds(col, n_env), :] = obs_p[...]
    act_o[:, pl.ds(col, n_env), :] = act_p[...]
    rew_o[:, pl.ds(col, n_env)] = rew_p[...]
    done_o[:, pl.ds(col, n_env)] = done_p[...]
    boot_o[pl.ds(s, 1), :] = boot_p[...]
    ver_o[pl.ds(s, 1), :] = ver_p[...]


def pack_channels(bufs: Dict[str, jax.Array], payloads: Dict[str, jax.Array],
                  slot, *, interpret: bool = False) -> Dict[str, jax.Array]:
    """Write one push into ring slot ``slot``; returns the updated rings.

    ``bufs``/``payloads`` are keyed by ``CHANNELS``; payload shapes are the
    per-push shapes (see module docstring).  ``slot`` is a traced int32 —
    no retrace per slot.
    """
    pay = _as_payloads(payloads)
    T, N = pay["rewards"].shape
    slot_arr = jnp.asarray(slot, jnp.int32).reshape(1, 1)

    def full(shape):
        return pl.BlockSpec(shape, lambda i: (0,) * len(shape))

    in_specs = [pl.BlockSpec((1, 1), lambda i: (0, 0),
                             memory_space=pltpu.SMEM)]
    in_specs += [full(pay[c].shape) for c in CHANNELS]
    in_specs += [full(bufs[c].shape) for c in CHANNELS]
    out_specs = [full(bufs[c].shape) for c in CHANNELS]
    out_shape = [jax.ShapeDtypeStruct(bufs[c].shape, bufs[c].dtype)
                 for c in CHANNELS]

    out = pl.pallas_call(
        functools.partial(_kernel, n_env=N),
        grid=(1,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={1 + len(CHANNELS) + i: i
                              for i in range(len(CHANNELS))},
        interpret=interpret,
    )(slot_arr, *[pay[c] for c in CHANNELS], *[bufs[c] for c in CHANNELS])
    return dict(zip(CHANNELS, out))


# -------------------------------------------------------------------- xla --
def _pack_xla(bufs, payloads, slot):
    pay = _as_payloads(payloads)
    _, N = pay["rewards"].shape
    col = slot * N
    z = jnp.int32(0)
    return {
        "obs": jax.lax.dynamic_update_slice(bufs["obs"], pay["obs"],
                                            (z, col, z)),
        "actions": jax.lax.dynamic_update_slice(bufs["actions"],
                                                pay["actions"], (z, col, z)),
        "rewards": jax.lax.dynamic_update_slice(bufs["rewards"],
                                                pay["rewards"], (z, col)),
        "dones": jax.lax.dynamic_update_slice(bufs["dones"], pay["dones"],
                                              (z, col)),
        "bootstrap": jax.lax.dynamic_update_slice(bufs["bootstrap"],
                                                  pay["bootstrap"],
                                                  (slot, z)),
        "actor_version": jax.lax.dynamic_update_slice(bufs["actor_version"],
                                                      pay["actor_version"],
                                                      (slot, z)),
    }


pack_channels_xla = jax.jit(_pack_xla, donate_argnums=(0,))


def alloc_rings(payloads, slots: int):
    """Zero-filled ring buffers sized for ``slots`` pushes shaped like
    ``payloads`` (the module-docstring layout)."""
    pay = _as_payloads(payloads)
    T, N = pay["rewards"].shape
    return {
        "obs": jnp.zeros((T, slots * N) + pay["obs"].shape[2:],
                         pay["obs"].dtype),
        "actions": jnp.zeros((T, slots * N) + pay["actions"].shape[2:],
                             pay["actions"].dtype),
        "rewards": jnp.zeros((T, slots * N), pay["rewards"].dtype),
        "dones": jnp.zeros((T, slots * N), pay["dones"].dtype),
        "bootstrap": jnp.zeros((slots, N), pay["bootstrap"].dtype),
        "actor_version": jnp.zeros((slots, 1), jnp.int32),
    }


@functools.partial(jax.jit, static_argnames=("slots",))
def pack_channels_fresh(payloads, *, slots: int):
    """Allocate rings and write slot 0 in one fused dispatch (the first
    push after a full-ring flush — or after a double-buffer generation
    swap — hands its buffers to the consumer, so the ring starts over on
    fresh storage)."""
    return _pack_xla(alloc_rings(payloads, slots), payloads, jnp.int32(0))


# ------------------------------------------------------- generation pack ---
@functools.lru_cache(maxsize=None)
def _generation_packer(n: int):
    """Jitted bulk pack of ``n`` staged pushes into one contiguous
    generation (slot ``s`` -> the slot-aligned block, exactly the ring
    layout above) — one donation-free dispatch per buffer swap."""
    C = len(CHANNELS)

    def pack(*flat):
        per = [_as_payloads(dict(zip(CHANNELS, flat[i * C:(i + 1) * C])))
               for i in range(n)]

        def cat(c, axis):
            xs = [p[c] for p in per]
            return xs[0] if n == 1 else jnp.concatenate(xs, axis=axis)

        return {
            "obs": cat("obs", 1),
            "actions": cat("actions", 1),
            "rewards": cat("rewards", 1),
            "dones": cat("dones", 1),
            "bootstrap": cat("bootstrap", 0).reshape(-1),
            "actor_version": cat("actor_version", 0).reshape(-1),
        }

    return jax.jit(pack)


def pack_generation(staged) -> Dict[str, jax.Array]:
    """Pack a sequence of staged per-push payload dicts (oldest first)
    into one generation's channel arrays, in a single dispatch."""
    assert staged
    flat = [p[c] for p in staged for c in CHANNELS]
    return _generation_packer(len(staged))(*flat)


# ----------------------------------------------------- cache-payload pack ---
# Prefill/decode disaggregation ships a finished prefill cache (an
# arbitrary pytree: KV stacks, SSM windows, hybrid mixes) between GMIs.
# Shipping dozens of small leaves is exactly the fine-grained-transfer
# pathology the ring pack above exists to avoid, so a cache payload is
# flattened into ONE contiguous 1-D buffer per dtype (the coarse-grained
# unit the channel ring moves) and reassembled bit-exactly on the decode
# side.  Both directions are jitted once per (treedef, shapes, dtypes)
# structure — the serving engines reuse a fixed cache layout, so in
# steady state pack/unpack are single cached dispatches.

@functools.lru_cache(maxsize=None)
def _cache_packer(spec):
    dtypes = sorted({d for _, d in spec})

    def pack(*leaves):
        return tuple(
            jnp.concatenate([leaves[i].reshape(-1)
                             for i, (_, d) in enumerate(spec) if d == dt])
            for dt in dtypes)

    return jax.jit(pack), dtypes


@functools.lru_cache(maxsize=None)
def _cache_unpacker(spec):
    dtypes = sorted({d for _, d in spec})

    def unpack(*bufs):
        offs = {dt: 0 for dt in dtypes}
        leaves = []
        for shape, dt in spec:
            n = 1
            for s in shape:
                n *= s
            buf = bufs[dtypes.index(dt)]
            leaves.append(jax.lax.dynamic_slice_in_dim(
                buf, offs[dt], n).reshape(shape))
            offs[dt] += n
        return tuple(leaves)

    return jax.jit(unpack)


def pack_cache_payload(tree):
    """Flatten a cache pytree into per-dtype contiguous 1-D buffers.

    Returns ``(bufs, meta)`` where ``bufs`` is a tuple of device arrays
    (one per distinct dtype, dtype-sorted) and ``meta`` re-creates the
    pytree via :func:`unpack_cache_payload`.  Round-trip is bit-exact —
    no casting, just ravel + concatenate."""
    leaves, treedef = jax.tree.flatten(tree)
    spec = tuple((tuple(l.shape), str(jnp.asarray(l).dtype))
                 for l in leaves)
    pack, _ = _cache_packer(spec)
    return pack(*leaves), (treedef, spec)


def unpack_cache_payload(bufs, meta):
    """Inverse of :func:`pack_cache_payload`."""
    treedef, spec = meta
    leaves = _cache_unpacker(spec)(*bufs)
    return jax.tree.unflatten(treedef, leaves)


def cache_payload_bytes(bufs) -> int:
    """Wire size of a packed payload (sum over per-dtype buffers)."""
    return int(sum(b.size * b.dtype.itemsize for b in bufs))


# ----------------------------------------------- page-wise payload pruning ---
@functools.lru_cache(maxsize=None)
def _page_slicer(lo: int, hi: int):
    def run(*leaves):
        return tuple(jax.lax.slice_in_dim(l, lo, hi, axis=2) for l in leaves)
    return jax.jit(run)


def truncate_cache_pages(tree, used_tokens: int, page_size: int,
                         head_skip: int = 0):
    """Prune a B=1 prefill-cache payload to whole pages before migration.

    Full-depth attention leaves (duck-typed: nodes with a ``slot_pos``
    field whose sequence depth covers every written position) are sliced
    along the sequence axis to ``[head_skip*page_size,
    ceil(used_tokens/page_size)*page_size)`` — dropping the max_seq tail a
    monolithic payload would ship, plus the leading ``head_skip`` pages
    the destination already holds in its shared-prefix index.  The decode
    engine's paged splice scatters entries by their recorded ``slot_pos``,
    so pruning is position-safe by construction.  Ring-buffer (sliding
    window) leaves shorter than ``used_tokens`` and recurrent-state leaves
    ship whole — they are already fixed-size.
    """
    P = max(int(page_size), 1)
    hi = -(-max(int(used_tokens), 0) // P) * P
    lo = min(max(int(head_skip), 0) * P, hi)

    def is_kv(n):
        return hasattr(n, "slot_pos") and hasattr(n, "k")

    def prune(n):
        if not is_kv(n):
            return n
        S = n.k.shape[2]
        if S < used_tokens:      # ring buffer: indices are not positions
            return n
        h = min(hi, S)
        k, v, sp = _page_slicer(lo, h)(n.k, n.v, n.slot_pos)
        return type(n)(k, v, sp)

    return jax.tree.map(prune, tree, is_leaf=is_kv)
