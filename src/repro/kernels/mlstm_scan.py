"""Chunkwise mLSTM Pallas TPU kernel (xLSTM matrix-memory recurrence).

The mLSTM chunkwise form is "masked linear attention inside a chunk +
recurrent (C, n, m) state across chunks".  On TPU the chunk axis is the
sequential innermost grid dimension; the matrix memory C (dh × dh), the
normalizer n and the log-space stabilizer m persist in VMEM scratch across
it, and each chunk's intra work is MXU matmuls.

Grid: (batch * heads, num_chunks)
  q/k/v block: (chunk, dh) VMEM;  log_i/log_f block: (chunk,) VMEM
  scratch: C (dh, dh) f32, n (dh,) f32, m (1,) f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LOG_EPS = -1e30


def _kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
            C_ref, n_ref, m_ref, *, chunk, dh):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0].astype(jnp.float32) * dh ** -0.5      # (L, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    log_i = li_ref[0].astype(jnp.float32)              # (L,)
    log_f = lf_ref[0].astype(jnp.float32)
    C_prev, n_prev, m_prev = C_ref[...], n_ref[...], m_ref[0]

    b = jnp.cumsum(log_f)                              # (L,)
    lw = b[:, None] - b[None, :] + log_i[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lw = jnp.where(causal, lw, LOG_EPS)
    inter = m_prev + b                                 # (L,)
    m_t = jnp.maximum(inter, jnp.max(lw, axis=-1))
    w_intra = jnp.exp(lw - m_t[:, None])
    w_inter = jnp.exp(inter - m_t)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * w_intra
    h_num = jax.lax.dot(scores, v, preferred_element_type=jnp.float32) + \
        w_inter[:, None] * jax.lax.dot(q, C_prev.T,
                                       preferred_element_type=jnp.float32)
    n_t = jax.lax.dot(w_intra, k, preferred_element_type=jnp.float32) + \
        w_inter[:, None] * n_prev[None, :]
    denom = jnp.maximum(jnp.abs(jnp.sum(q * n_t, axis=-1)), jnp.exp(-m_t))
    o_ref[0] = (h_num / denom[:, None]).astype(o_ref.dtype)

    # carry state to chunk end
    bl = b[-1]
    m_new = jnp.maximum(m_prev + bl, jnp.max(log_i + bl - b))
    w_c = jnp.exp(log_i + bl - b - m_new)              # (L,)
    decay = jnp.exp(m_prev + bl - m_new)
    C_ref[...] = decay * C_prev + jax.lax.dot_general(
        v * w_c[:, None], k, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = decay * n_prev + jnp.sum(k * w_c[:, None], axis=0)
    m_ref[0] = m_new


def mlstm_chunkwise(q, k, v, log_i, log_f, *, chunk: int = 128,
                    interpret: bool = False):
    """q/k/v: (B, H, S, dh); log_i/log_f: (B, H, S) -> h (B, H, S, dh).

    C is stored as v⊗k (C[d,e] = v_d k_e); the read contracts q with the
    k-dim, matching ``repro.models.ssm`` exactly.
    """
    B, H, S, dh = q.shape
    L = min(chunk, S)
    assert S % L == 0, "seq must divide the chunk size"
    nc = S // L
    fold = lambda t: t.reshape(B * H, S, t.shape[-1]) if t.ndim == 4 \
        else t.reshape(B * H, S)
    qh, kh, vh = fold(q), fold(k), fold(v)
    lih, lfh = fold(log_i), fold(log_f)

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=L, dh=dh),
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, L, dh), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, L, dh), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, L, dh), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, L), lambda h, c: (h, c)),
            pl.BlockSpec((1, L), lambda h, c: (h, c)),
        ],
        out_specs=pl.BlockSpec((1, L, dh), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh, lih, lfh)
    return out.reshape(B, H, S, dh)
