"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python per grid cell, validating the exact TPU program
against the ``ref.py`` oracles.  On TPU backends the same calls compile to
Mosaic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.channel_pack import pack_channels as _pack
from repro.kernels.env_megakernel import env_mega_step as _envmega
from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.fused_policy_mlp import fused_policy_mlp as _mlp
from repro.kernels.gae_scan import gae_scan as _gae
from repro.kernels.gae_scan import nstep_scan as _nstep
from repro.kernels.mlstm_scan import mlstm_chunkwise as _mlstm
from repro.kernels.paged_decode import paged_decode_attention as _paged


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def attention(q, k, v, *, causal=True, window=None, softcap=None,
              block_q=128, block_k=128, interpret=None):
    interp = _interpret_default() if interpret is None else interpret
    return _fa(q, k, v, causal=causal, window=window, softcap=softcap,
               block_q=block_q, block_k=block_k, interpret=interp)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "interpret"))
def paged_attention(q, k_pages, v_pages, slot_pos, table, positions, *,
                    window=None, softcap=None, scale=None, interpret=None):
    """Paged gather-decode attention (see paged_decode.py): one decode
    step per batch row read through a per-row page table.  ``window`` is a
    dynamic operand (it rides the kernel's scalar prefetch), so per-layer
    windows from a scanned stack don't retrace."""
    interp = _interpret_default() if interpret is None else interpret
    return _paged(q, k_pages, v_pages, slot_pos, table, positions,
                  window=window, softcap=softcap, scale=scale,
                  interpret=interp)


def policy_mlp(x, weights, biases, *, block_n=256, interpret=None):
    interp = _interpret_default() if interpret is None else interpret
    fn = jax.jit(functools.partial(_mlp, block_n=block_n, interpret=interp))
    return fn(x, list(weights), list(biases))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm(q, k, v, log_i, log_f, *, chunk=128, interpret=None):
    interp = _interpret_default() if interpret is None else interpret
    return _mlstm(q, k, v, log_i, log_f, chunk=chunk, interpret=interp)


@functools.partial(jax.jit,
                   static_argnames=("gamma", "lam", "eps", "interpret"))
def gae_norm(rewards, values, dones, last_value, *, gamma=0.99, lam=0.95,
             eps=1e-8, interpret=None):
    """Fused GAE + global advantage normalization (see gae_scan.py).

    Returns (normalized_advs, returns), both (T, N) f32."""
    interp = _interpret_default() if interpret is None else interpret
    return _gae(rewards, values, dones, last_value, gamma=gamma, lam=lam,
                eps=eps, interpret=interp)


@functools.partial(jax.jit, static_argnames=("gamma", "interpret"))
def nstep_returns(rewards, dones, bootstrap, *, gamma=0.99, interpret=None):
    """Fused A3C n-step discounted-return scan (see gae_scan.nstep_scan).

    Returns the (T, N) f32 return block."""
    interp = _interpret_default() if interpret is None else interpret
    return _nstep(rewards, dones, bootstrap, gamma=gamma, interpret=interp)


@functools.partial(jax.jit, donate_argnums=(9,),
                   static_argnames=("chain", "task", "substeps", "dt",
                                    "max_episode_len", "block_envs",
                                    "interpret"))
def env_mega_step(q, qd, root, prev_action, t, seed, resets, action, obs,
                  bufs, step_t, slot, sensor, tgt, masses, lengths, *,
                  chain, task, substeps, dt, max_episode_len,
                  block_envs=None, interpret=None):
    """Fused env megakernel step (see env_megakernel.py): physics
    substeps + reward + bookkeeping + predicated counter-PRNG auto-reset
    + observation, writing obs/action/reward/done straight into the
    donated ring-slot buffers.  Returns the new state arrays, next obs,
    reward, done, and the updated ring dict."""
    interp = _interpret_default() if interpret is None else interpret
    return _envmega(q, qd, root, prev_action, t, seed, resets, action,
                    obs, bufs, step_t, slot, sensor, tgt, masses, lengths,
                    chain=chain, task=task, substeps=substeps, dt=dt,
                    max_episode_len=max_episode_len, block_envs=block_envs,
                    interpret=interp)


@functools.partial(jax.jit, donate_argnums=(0,),
                   static_argnames=("interpret",))
def pack_channels(bufs, payloads, slot, *, interpret=None):
    """In-place ring-buffer pack of one experience push (all channels in
    one kernel launch; ring buffers donated)."""
    interp = _interpret_default() if interpret is None else interpret
    return _pack(bufs, payloads, slot, interpret=interp)
