from repro.data.tokens import batch_iterator, make_batch  # noqa: F401
