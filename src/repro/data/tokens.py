"""Synthetic token/feature pipeline.

Deterministic, seekable batch generation (Zipf-ish marginals over a Markov
backbone so the LM loss has learnable structure), plus sharded global-batch
assembly for multi-device training.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


def _markov_tokens(rng: np.random.Generator, batch: int, seq: int,
                   vocab: int) -> np.ndarray:
    """Order-1 Markov chain with Zipf marginals — compressible, non-trivial."""
    base = rng.zipf(1.5, size=(batch, seq)).astype(np.int64)
    toks = (base + np.cumsum(base, axis=1)) % vocab
    return toks.astype(np.int32)


def make_batch(cfg: ModelConfig, shape: InputShape, seed: int = 0,
               seq_len: Optional[int] = None,
               global_batch: Optional[int] = None) -> Dict[str, np.ndarray]:
    """One host-side batch dict matching the model family's inputs."""
    rng = np.random.default_rng(seed)
    S = seq_len or shape.seq_len
    B = global_batch or shape.global_batch
    if cfg.frontend == "audio":
        feats = rng.standard_normal((B, S, cfg.frontend_feat_dim),
                                    dtype=np.float32)
        mask = rng.random((B, S)) < 0.15
        targets = rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)
        return {"features": feats, "mask": mask, "targets": targets}
    if cfg.frontend == "vision":
        ptc = rng.standard_normal((B, cfg.num_patches, cfg.frontend_feat_dim),
                                  dtype=np.float32)
        T = max(S - cfg.num_patches, 8)
        toks = _markov_tokens(rng, B, T, cfg.vocab_size)
        return {"tokens": toks, "labels": toks, "patches": ptc}
    toks = _markov_tokens(rng, B, S, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


def batch_iterator(cfg: ModelConfig, shape: InputShape, *, seed: int = 0,
                   mesh: Optional[Mesh] = None,
                   batch_axes=("data",)) -> Iterator[Dict]:
    """Endless iterator; places batches on the mesh when given."""
    step = 0
    while True:
        host = make_batch(cfg, shape, seed=seed + step)
        if mesh is None:
            yield {k: jnp.asarray(v) for k, v in host.items()}
        else:
            ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
            out = {}
            for k, v in host.items():
                spec = P(ax, *(None,) * (v.ndim - 1))
                out[k] = jax.device_put(v, NamedSharding(mesh, spec))
            yield out
        step += 1
