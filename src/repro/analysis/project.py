"""Cross-file rules: kernel/oracle completeness, fault-kind
exhaustiveness, dead ``Decision``/``ControllerConfig`` fields, and
tracked bytecode hygiene.

These run in :meth:`Rule.finish` over the whole analyzed file set; the
kernel and fault rules additionally read sibling files (``ref.py``,
``tests/``) from disk so the analyzed paths don't have to include them.
"""
from __future__ import annotations

import ast
import os
import re
import subprocess
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Project, Rule, SourceFile
from repro.analysis.rules import dotted

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _word(name: str, text: str) -> bool:
    return re.search(rf"\b{re.escape(name)}\b", text) is not None


def _read(path: str) -> Optional[str]:
    try:
        with open(path, encoding="utf-8") as f:
            return f.read()
    except OSError:
        return None


# --------------------------------------------------------- kernel-oracle --
class KernelOracleRule(Rule):
    """Every ``pl.pallas_call`` under ``kernels/`` must belong to a
    function that (directly or through its ``ops.py`` public wrapper) is
    exercised against a ``ref.py`` oracle in some test under
    ``<root>/tests/``; and every BlockSpec ``index_map`` arity must
    equal grid rank + ``num_scalar_prefetch``."""
    name = "kernel-oracle"

    def finish(self, project: Project) -> Iterable[Finding]:
        findings: List[Finding] = []
        kernel_files = [f for f in project.files
                        if "kernels/" in f.rel
                        and os.path.basename(f.rel) not in (
                            "ref.py", "ops.py", "__init__.py")]
        if not kernel_files:
            return findings
        tests_src = self._tests_source(project)
        for f in kernel_files:
            wrappers = self._ops_wrappers(project, f)
            oracles = self._oracles(project, f)
            for fn in f.tree.body:
                if not isinstance(fn, _DEFS):
                    continue
                calls = [c for c in ast.walk(fn)
                         if isinstance(c, ast.Call)
                         and (dotted(c.func) or "").endswith("pallas_call")]
                if not calls:
                    continue
                line = calls[0].lineno
                names = {fn.name} | wrappers.get(fn.name, set())
                if not self._paired(names, oracles, tests_src):
                    findings.append(Finding(
                        self.name, f.rel, line,
                        f"kernel '{fn.name}' (pl.pallas_call) has no "
                        "ref.py oracle exercised together with it in a "
                        "tests/ parity test"))
                findings.extend(self._check_index_maps(f, fn, calls))
        return findings

    # -- pairing ----------------------------------------------------------
    def _tests_source(self, project: Project) -> List[str]:
        out = []
        tests_dir = os.path.join(project.root, "tests")
        for dirpath, dirnames, filenames in os.walk(tests_dir):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in filenames:
                if fn.endswith(".py"):
                    src = _read(os.path.join(dirpath, fn))
                    if src:
                        out.append(src)
        return out

    def _sibling(self, project: Project, f: SourceFile,
                 basename: str) -> Optional[ast.Module]:
        rel = f.rel.rsplit("/", 1)[0] + "/" + basename
        sf = next((x for x in project.files if x.rel == rel), None)
        if sf is not None:
            return sf.tree
        src = _read(os.path.join(os.path.dirname(f.path), basename))
        if src is None:
            return None
        try:
            return ast.parse(src)
        except SyntaxError:
            return None

    def _ops_wrappers(self, project: Project,
                      f: SourceFile) -> Dict[str, Set[str]]:
        """kernel function name -> public ops.py wrapper names."""
        tree = self._sibling(project, f, "ops.py")
        if tree is None:
            return {}
        alias_to_orig: Dict[str, str] = {}
        for node in tree.body:
            if isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if alias.asname:
                        alias_to_orig[alias.asname] = alias.name
        wrappers: Dict[str, Set[str]] = {}
        for node in tree.body:
            if isinstance(node, _DEFS):
                used = {n.id for n in ast.walk(node)
                        if isinstance(n, ast.Name)}
                for alias, orig in alias_to_orig.items():
                    if alias in used:
                        wrappers.setdefault(orig, set()).add(node.name)
        return wrappers

    def _oracles(self, project: Project, f: SourceFile) -> List[str]:
        tree = self._sibling(project, f, "ref.py")
        if tree is None:
            return []
        return [n.name for n in tree.body
                if isinstance(n, _DEFS) and n.name.endswith("_ref")]

    def _paired(self, names: Set[str], oracles: List[str],
                tests_src: List[str]) -> bool:
        for src in tests_src:
            if any(_word(n, src) for n in names) \
                    and any(_word(o, src) for o in oracles):
                return True
        return False

    # -- index_map arity --------------------------------------------------
    def _check_index_maps(self, f: SourceFile, fn: ast.AST,
                          calls: List[ast.Call]) -> Iterable[Finding]:
        findings: List[Finding] = []
        grid_rank, prefetch = self._grid_of(fn, calls)
        if grid_rank is None:
            return findings
        expected = grid_rank + prefetch
        local_defs = {n.name: n for n in ast.walk(fn)
                      if isinstance(n, _DEFS)}
        for spec in ast.walk(fn):
            if not (isinstance(spec, ast.Call)
                    and (dotted(spec.func) or "").endswith("BlockSpec")):
                continue
            imap = next((kw.value for kw in spec.keywords
                         if kw.arg == "index_map"), None)
            if imap is None:
                imap = next((a for a in spec.args
                             if isinstance(a, ast.Lambda)), None)
            if imap is None and len(spec.args) >= 2 \
                    and isinstance(spec.args[1], ast.Name) \
                    and spec.args[1].id in local_defs:
                imap = local_defs[spec.args[1].id]
            if imap is None:
                continue
            args = imap.args
            if args.vararg is not None:
                continue
            arity = len(args.posonlyargs) + len(args.args)
            if arity != expected:
                findings.append(Finding(
                    self.name, f.rel, spec.lineno,
                    f"BlockSpec index_map takes {arity} args but the "
                    f"grid has rank {grid_rank} with {prefetch} scalar-"
                    f"prefetch operands (expected {expected})"))
        return findings

    def _grid_of(self, fn: ast.AST,
                 calls: List[ast.Call]) -> Tuple[Optional[int], int]:
        """(grid rank, num_scalar_prefetch) resolved from the pallas_call
        subtree, or (None, 0) if the grid is not statically a tuple."""
        consts: Dict[str, ast.expr] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                consts[node.targets[0].id] = node.value
        # search the call subtrees plus any grid_spec built earlier in
        # the function and passed by name (the paged-decode idiom)
        trees: List[ast.AST] = list(calls)
        for call in calls:
            for kw in call.keywords:
                if kw.arg == "grid_spec" and isinstance(kw.value, ast.Name):
                    resolved = consts.get(kw.value.id)
                    if resolved is not None:
                        trees.append(resolved)
        grid_node = None
        prefetch = 0
        for tree in trees:
            for sub in ast.walk(tree):
                if not isinstance(sub, ast.Call):
                    continue
                for kw in sub.keywords:
                    if kw.arg == "grid" and grid_node is None:
                        grid_node = kw.value
                    elif kw.arg == "num_scalar_prefetch":
                        v = kw.value
                        if isinstance(v, ast.Constant) \
                                and isinstance(v.value, int):
                            prefetch = v.value
        if isinstance(grid_node, ast.Name):
            grid_node = consts.get(grid_node.id)
        if isinstance(grid_node, (ast.Tuple, ast.List)):
            return len(grid_node.elts), prefetch
        if isinstance(grid_node, ast.Constant) \
                and isinstance(grid_node.value, int):
            return 1, prefetch
        return None, prefetch


# ------------------------------------------------------------ fault-kind --
class FaultKindRule(Rule):
    """Every fault kind declared in ``fault/inject.py::KINDS`` must
    appear (as a string literal) in ``fault/supervisor.py`` — the
    supervisor's classification/recovery must stay exhaustive."""
    name = "fault-kind"

    def finish(self, project: Project) -> Iterable[Finding]:
        inject = project.find("fault/inject.py")
        if inject is None:
            return []
        kinds_node = None
        for node in inject.tree.body:
            if isinstance(node, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == "KINDS"
                            for t in node.targets):
                kinds_node = node
        if kinds_node is None or not isinstance(
                kinds_node.value, (ast.Tuple, ast.List)):
            return []
        kinds = [e.value for e in kinds_node.value.elts
                 if isinstance(e, ast.Constant) and isinstance(e.value, str)]
        sup = project.find("fault/supervisor.py")
        if sup is not None:
            sup_tree = sup.tree
        else:
            src = _read(os.path.join(os.path.dirname(inject.path),
                                     "supervisor.py"))
            if src is None:
                return [Finding(self.name, inject.rel, kinds_node.lineno,
                                "fault/supervisor.py not found next to "
                                "inject.py; fault kinds have no handler")]
            sup_tree = ast.parse(src)
        handled = {n.value for n in ast.walk(sup_tree)
                   if isinstance(n, ast.Constant)
                   and isinstance(n.value, str)}
        return [Finding(self.name, inject.rel, kinds_node.lineno,
                        f"fault kind '{k}' is declared in KINDS but never "
                        "referenced by the supervisor — recovery is not "
                        "exhaustive")
                for k in kinds if k not in handled]


# --------------------------------------------------- dead-decision-field --
class DeadDecisionFieldRule(Rule):
    """Fields of the controller's ``Decision``/``ControllerConfig``
    dataclasses that no analyzed file ever reads (no attribute access,
    no ``getattr(x, "field")``) are dead weight in the control plane."""
    name = "dead-decision-field"
    target_classes = ("Decision", "ControllerConfig")

    def finish(self, project: Project) -> Iterable[Finding]:
        decls: List[Tuple[SourceFile, str, str, int]] = []
        for f in project.files:
            for node in f.tree.body:
                if isinstance(node, ast.ClassDef) \
                        and node.name in self.target_classes:
                    for item in node.body:
                        if isinstance(item, ast.AnnAssign) \
                                and isinstance(item.target, ast.Name) \
                                and not item.target.id.startswith("_"):
                            ann = ast.dump(item.annotation)
                            if "ClassVar" in ann:
                                continue
                            decls.append((f, node.name, item.target.id,
                                          item.lineno))
        if not decls:
            return []
        read: Set[str] = set()
        for f in project.files:
            for node in ast.walk(f.tree):
                if isinstance(node, ast.Attribute) \
                        and isinstance(node.ctx, ast.Load):
                    read.add(node.attr)
                elif isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name) \
                        and node.func.id in ("getattr", "hasattr"):
                    if len(node.args) >= 2 \
                            and isinstance(node.args[1], ast.Constant) \
                            and isinstance(node.args[1].value, str):
                        read.add(node.args[1].value)
        return [Finding(self.name, f.rel, line,
                        f"{cls}.{field} is never read by any analyzed "
                        "file (no attribute access or getattr); delete "
                        "it or wire it up")
                for f, cls, field, line in decls if field not in read]


# ------------------------------------------------------ tracked-bytecode --
def _git(root: str, *argv: str) -> Optional[str]:
    try:
        proc = subprocess.run(["git", *argv], cwd=root,
                              capture_output=True, text=True, timeout=30)
    except (OSError, subprocess.SubprocessError):
        return None
    return proc.stdout if proc.returncode == 0 else None


class TrackedBytecodeRule(Rule):
    """No ``__pycache__``/``.pyc`` artifact may be tracked by git, and
    ``.gitignore`` must keep covering bytecode patterns.  Only applies
    when the analysis root IS a git toplevel (it has happened twice:
    8436fa0 removed six tracked .pyc, bd262a9 re-committed them)."""
    name = "tracked-bytecode"

    def finish(self, project: Project) -> Iterable[Finding]:
        root = project.root
        top = _git(root, "rev-parse", "--show-toplevel")
        if top is None or os.path.realpath(top.strip()) \
                != os.path.realpath(root):
            return []
        findings: List[Finding] = []
        listed = _git(root, "ls-files")
        for path in (listed or "").splitlines():
            if path.endswith((".pyc", ".pyo")) \
                    or "__pycache__" in path.split("/"):
                findings.append(Finding(
                    self.name, path, 1,
                    "bytecode artifact is tracked by git; `git rm "
                    "--cached` it"))
        gi = _read(os.path.join(root, ".gitignore")) or ""
        patterns = [ln.strip() for ln in gi.splitlines()
                    if ln.strip() and not ln.lstrip().startswith("#")]
        if "__pycache__/" not in patterns:
            findings.append(Finding(
                self.name, ".gitignore", 1,
                "missing a `__pycache__/` ignore pattern"))
        if not any(p in ("*.pyc", "*.py[cod]") for p in patterns):
            findings.append(Finding(
                self.name, ".gitignore", 1,
                "missing a `*.pyc`/`*.py[cod]` ignore pattern"))
        return findings
