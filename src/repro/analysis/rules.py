"""Per-file AST rules: PRNG key discipline, donated-buffer reuse, and
host syncs in hot paths.

Each rule runs a small linear abstract interpretation over every
function body (and the module body): statements execute in order against
a per-name state dict, ``if``/``try`` branches run on copies and merge
pessimistically, and loop bodies run twice (findings deduped) so
cross-iteration reuse is caught without a fixpoint.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Rule, SourceFile

_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)
_LOOPS = (ast.For, ast.AsyncFor, ast.While)


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _target_names(target: ast.AST) -> Iterable[str]:
    """Dotted names (re)bound by an assignment target."""
    if isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _target_names(elt)
    elif isinstance(target, ast.Starred):
        yield from _target_names(target.value)
    else:
        d = dotted(target)
        if d:
            yield d


def _stmt_targets(stmt: ast.stmt) -> Iterable[str]:
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            yield from _target_names(t)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        yield from _target_names(stmt.target)
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            yield from _target_names(t)
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        yield from _target_names(stmt.target)
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            if item.optional_vars is not None:
                yield from _target_names(item.optional_vars)


def _walrus_targets(expr: ast.AST) -> Iterable[str]:
    for node in ast.walk(expr):
        if isinstance(node, ast.NamedExpr):
            yield from _target_names(node.target)


def _stmt_exprs(stmt: ast.stmt) -> Iterable[ast.AST]:
    """The value expressions a statement evaluates (left-to-right-ish),
    excluding nested function/class bodies."""
    if isinstance(stmt, _DEFS + (ast.ClassDef,)):
        return
    for fld, value in ast.iter_fields(stmt):
        if fld in ("body", "orelse", "finalbody", "handlers", "target",
                   "targets"):
            continue
        if isinstance(value, ast.expr):
            yield value
        elif isinstance(value, list):
            for v in value:
                if isinstance(v, ast.expr):
                    yield v
                elif isinstance(v, ast.withitem):
                    yield v.context_expr
                elif isinstance(v, ast.keyword):
                    yield v.value


def _terminates(stmts: List[ast.stmt]) -> bool:
    """A block whose last statement leaves the scope doesn't merge its
    state back into the fall-through path."""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


def _calls_in(expr: ast.AST) -> List[ast.Call]:
    out: List[ast.Call] = []

    def visit(node: ast.AST) -> None:
        if isinstance(node, (ast.Lambda,) + _DEFS):
            return  # deferred bodies don't execute here
        if isinstance(node, ast.Call):
            out.append(node)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return out


class _Interp:
    """Shared linear-interpretation driver.  Subclass hooks:
    ``on_exprs(exprs, state, stmt)`` runs for each statement's value
    expressions *before* its targets reset state."""

    def __init__(self, f: SourceFile):
        self.f = f
        self.findings: Dict[Tuple[int, str], Finding] = {}

    def emit(self, rule: str, line: int, key: str, message: str) -> None:
        self.findings.setdefault(
            (line, key), Finding(rule, self.f.rel, line, message))

    def on_exprs(self, exprs: List[ast.AST], state: dict,
                 stmt: ast.stmt) -> None:
        raise NotImplementedError

    def merge(self, state: dict, branches: List[dict]) -> None:
        """Pessimistic union: keep a name's entry if any branch kept or
        created it; per-entry max by natural ordering."""
        state.clear()
        for b in branches:
            for k, v in b.items():
                if k in state:
                    state[k] = max(state[k], v)
                else:
                    state[k] = v

    def run_block(self, stmts: List[ast.stmt], state: dict) -> None:
        for stmt in stmts:
            self.run_stmt(stmt, state)

    def run_stmt(self, stmt: ast.stmt, state: dict) -> None:
        if isinstance(stmt, _DEFS + (ast.ClassDef,)):
            return
        exprs = list(_stmt_exprs(stmt))
        if exprs:
            self.on_exprs(exprs, state, stmt)
        for name in _stmt_targets(stmt):
            state.pop(name, None)
        for expr in exprs:
            for name in _walrus_targets(expr):
                state.pop(name, None)
        if isinstance(stmt, ast.If):
            branches = []
            b1 = dict(state)
            self.run_block(stmt.body, b1)
            if not _terminates(stmt.body):
                branches.append(b1)
            b2 = dict(state)
            self.run_block(stmt.orelse, b2)
            if not _terminates(stmt.orelse):
                branches.append(b2)
            if branches:
                self.merge(state, branches)
            else:
                state.clear()    # fall-through is unreachable
        elif isinstance(stmt, _LOOPS):
            # two passes catch cross-iteration reuse; findings dedupe
            for _ in range(2):
                self.run_block(stmt.body, state)
            self.run_block(stmt.orelse, state)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            self.run_block(stmt.body, state)
        elif isinstance(stmt, ast.Try):
            branches = []
            b = dict(state)
            self.run_block(stmt.body, b)
            bo = dict(b)
            self.run_block(stmt.orelse, bo)
            branches.append(bo)
            for handler in stmt.handlers:
                bh = dict(state)
                if handler.name:
                    bh.pop(handler.name, None)
                self.run_block(handler.body, bh)
                branches.append(bh)
            self.merge(state, branches)
            self.run_block(stmt.finalbody, state)

    def run_file(self) -> List[Finding]:
        scopes: List[List[ast.stmt]] = [list(self.f.tree.body)]
        for node in ast.walk(self.f.tree):
            if isinstance(node, _DEFS):
                scopes.append(list(node.body))
        for body in scopes:
            self.run_block(body, {})
        return sorted(self.findings.values(),
                      key=lambda fd: (fd.line, fd.message))


# ------------------------------------------------------------ prng-reuse --
_PRNG_CREATORS = {"key", "PRNGKey"}
_PRNG_NONCONSUMING = {"fold_in", "key_data", "wrap_key_data", "clone",
                      "key_impl", "default_prng_impl"}


def _jax_random_prefixes(tree: ast.Module) -> Set[str]:
    """Module paths that resolve to ``jax.random`` in this file."""
    prefixes = {"jax.random"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "jax.random" and alias.asname:
                    prefixes.add(alias.asname)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax":
                for alias in node.names:
                    if alias.name == "random":
                        prefixes.add(alias.asname or "random")
    return prefixes


class _PrngInterp(_Interp):
    name = "prng-reuse"

    def __init__(self, f: SourceFile):
        super().__init__(f)
        self.prefixes = _jax_random_prefixes(f.tree)

    def _jax_random_fn(self, call: ast.Call) -> Optional[str]:
        d = dotted(call.func)
        if d is None or "." not in d:
            return None
        mod, fn = d.rsplit(".", 1)
        return fn if mod in self.prefixes else None

    def on_exprs(self, exprs, state, stmt):
        # state: key name -> (uses since derivation, line of last use)
        for expr in exprs:
            for call in _calls_in(expr):
                fn = self._jax_random_fn(call)
                if fn is None or fn in _PRNG_CREATORS \
                        or fn in _PRNG_NONCONSUMING:
                    continue
                if not call.args or not isinstance(call.args[0], ast.Name):
                    continue
                name = call.args[0].id
                uses, last = state.get(name, (0, 0))
                if uses >= 1:
                    self.emit(
                        self.name, call.lineno, name,
                        f"PRNG key '{name}' consumed again by jax.random."
                        f"{fn} (already used at line {last}); derive a "
                        "fresh key with split/fold_in")
                state[name] = (uses + 1, call.lineno)


class PrngReuseRule(Rule):
    """A key passed to ≥2 consuming ``jax.random.*`` calls (samplers or
    ``split``) without being rebound by ``split``/``fold_in`` in
    between.  ``fold_in`` does not consume its key."""
    name = "prng-reuse"

    def check_file(self, f: SourceFile) -> Iterable[Finding]:
        return _PrngInterp(f).run_file()


# -------------------------------------------------------- donation-reuse --
def _donate_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """donate_argnums of a ``jax.jit(...)`` call, else None."""
    d = dotted(call.func)
    if d not in ("jax.jit", "jit"):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for elt in v.elts:
                    if isinstance(elt, ast.Constant) \
                            and isinstance(elt.value, int):
                        out.append(elt.value)
                return tuple(out)
    return None


def _collect_donating_callables(tree: ast.Module) -> Dict[str,
                                                          Tuple[int, ...]]:
    """Dotted callable name -> donated positional indices, from
    ``X = jax.jit(fn, donate_argnums=...)`` assignments and
    ``@jax.jit``/``@partial(jax.jit, ...)`` decorations."""
    donating: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            pos = _donate_positions(node.value)
            if pos:
                for t in node.targets:
                    d = dotted(t)
                    if d:
                        donating[d] = pos
        elif isinstance(node, _DEFS):
            for dec in node.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                pos = _donate_positions(dec)
                if pos is None and dotted(dec.func) in (
                        "partial", "functools.partial") and dec.args \
                        and dotted(dec.args[0]) in ("jax.jit", "jit"):
                    for kw in dec.keywords:
                        if kw.arg == "donate_argnums":
                            fake = ast.Call(
                                func=dec.args[0], args=[],
                                keywords=[kw])
                            pos = _donate_positions(fake)
                if pos:
                    donating[node.name] = pos
    return donating


class _DonationInterp(_Interp):
    name = "donation-reuse"

    def __init__(self, f: SourceFile):
        super().__init__(f)
        self.donating = _collect_donating_callables(f.tree)

    def on_exprs(self, exprs, state, stmt):
        # state: dotted var -> line it was donated at
        for expr in exprs:
            deaths: List[Tuple[str, int]] = []
            for node in ast.walk(expr):
                if isinstance(node, (ast.Name, ast.Attribute)) \
                        and isinstance(node.ctx, ast.Load):
                    d = dotted(node)
                    if d in state:
                        self.emit(
                            self.name, node.lineno, d,
                            f"'{d}' read after being donated to a jitted "
                            f"call at line {state[d]}; donated buffers "
                            "are invalidated")
                if isinstance(node, ast.Call):
                    pos = self.donating.get(dotted(node.func) or "")
                    if pos:
                        for p in pos:
                            if p < len(node.args):
                                d = dotted(node.args[p])
                                if d:
                                    deaths.append((d, node.lineno))
            for d, line in deaths:
                state.setdefault(d, line)


class DonationReuseRule(Rule):
    """A variable read after being passed in a ``donate_argnums``
    position of a jitted callable, before reassignment.  The idiomatic
    ``tok, self._caches = self._decode(params, self._caches, ...)``
    same-statement rebind is safe."""
    name = "donation-reuse"

    def check_file(self, f: SourceFile) -> Iterable[Finding]:
        return _DonationInterp(f).run_file()


# ------------------------------------------------- host-sync-in-hot-path --
def _numpy_aliases(tree: ast.Module) -> Set[str]:
    out = {"np", "numpy"}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "numpy" and alias.asname:
                    out.add(alias.asname)
    return out


def _time_names(tree: ast.Module) -> Tuple[Set[str], Set[str]]:
    """(module aliases of ``time``, bare names imported from ``time``)."""
    mods = {"time"}
    bare: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "time" and alias.asname:
                    mods.add(alias.asname)
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                bare.add(alias.asname or alias.name)
    return mods, bare


class HostSyncRule(Rule):
    """Host-synchronizing constructs (``.item()``,
    ``.block_until_ready()``, ``np.asarray``, non-constant ``float()``,
    ``time.*``) inside hot code: functions marked ``# repro: hot`` or
    anything under ``kernels/``."""
    name = "host-sync-in-hot-path"

    def check_file(self, f: SourceFile) -> Iterable[Finding]:
        file_hot = "kernels/" in f.rel or f.rel.startswith("kernels/")
        np_aliases = _numpy_aliases(f.tree)
        time_mods, time_bare = _time_names(f.tree)
        findings: List[Finding] = []

        def check_call(call: ast.Call) -> None:
            d = dotted(call.func)
            msg = None
            if isinstance(call.func, ast.Attribute):
                if call.func.attr == "item" and not call.args:
                    msg = ".item() forces a device->host sync"
                elif call.func.attr == "block_until_ready":
                    msg = ".block_until_ready() blocks on the device"
            if msg is None and d is not None:
                if "." in d:
                    mod, fn = d.rsplit(".", 1)
                    if mod in np_aliases and fn in ("asarray", "array"):
                        msg = f"{d}() copies device data to the host"
                    elif mod in time_mods:
                        msg = f"{d}() is host-side timing"
                elif d in time_bare:
                    msg = f"{d}() (from time) is host-side timing"
                elif d == "float" and call.args and not isinstance(
                        call.args[0], ast.Constant):
                    msg = "float() on a non-constant forces a " \
                          "device->host sync"
            if msg is not None:
                findings.append(Finding(
                    self.name, f.rel, call.lineno,
                    msg + "; hoist it out of the hot path or annotate "
                    "`# repro: allow(host-sync-in-hot-path)`"))

        def scan_stmts(stmts: List[ast.stmt], hot: bool) -> None:
            for stmt in stmts:
                if isinstance(stmt, _DEFS):
                    scan_stmts(stmt.body,
                               hot or file_hot or f.is_hot_marked(stmt))
                    continue
                if isinstance(stmt, ast.ClassDef):
                    scan_stmts(stmt.body, hot)
                    continue
                if hot:
                    for expr in _stmt_exprs(stmt):
                        for call in _calls_in(expr):
                            check_call(call)
                for fld in ("body", "orelse", "finalbody"):
                    scan_stmts(getattr(stmt, fld, []) or [], hot)
                for handler in getattr(stmt, "handlers", []) or []:
                    scan_stmts(handler.body, hot)

        scan_stmts(list(f.tree.body), False)
        return findings
