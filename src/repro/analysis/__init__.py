"""repro.analysis — static invariant checks for the GMI-DRL codebase.

``python -m repro.analysis [--strict] [--json] [paths...]`` walks the
given paths (default: ``src/repro benchmarks examples`` under the repo
root), parses every ``.py`` file, and reports ``file:line`` findings.
``--strict`` exits non-zero on any finding; ``benchmarks/run.py`` runs
it as a pre-flight so a violating tree can never re-baseline a BENCH
json, and ``tests/test_static_analysis.py`` gates tier-1 on a clean
tree.

Rule reference
==============

``prng-reuse``
    A PRNG key consumed by two or more ``jax.random.*`` calls (samplers
    or ``split``) without an intervening rebind.  ``fold_in`` and
    ``key``/``PRNGKey`` do not consume; the loop idiom
    ``normal(fold_in(key, i))`` is clean.  Catches the PR 5
    ``key``/``PRNGKey`` class of bug mechanically.

``donation-reuse``
    A variable read after being passed in a ``donate_argnums`` position
    of a jitted callable (``X = jax.jit(fn, donate_argnums=...)``
    assignments and ``@jax.jit``/``@partial(jax.jit, ...)``
    decorations), before reassignment.  The serve engine's same-
    statement rebind ``tok, self._caches = self._decode(params,
    self._caches, ...)`` is safe; anything else reading a donated
    buffer is undefined behavior.

``host-sync-in-hot-path``
    ``.item()``, ``.block_until_ready()``/``jax.block_until_ready``,
    ``np.asarray``/``np.array``, non-constant ``float()``, and
    ``time.*`` calls inside hot code.  Hot = any function under
    ``kernels/`` or one marked with a ``# repro: hot`` comment on (or
    right above) its ``def`` line.  Deliberate syncs (the decode loop's
    single token readback, telemetry clocks) carry
    ``# repro: allow(host-sync-in-hot-path)``.

``kernel-oracle``
    Every ``pl.pallas_call`` under ``kernels/`` must belong to a
    function exercised — directly or via its ``ops.py`` wrapper (import
    aliases are resolved) — together with a ``ref.py`` oracle in a test
    under ``tests/``; and every BlockSpec ``index_map`` arity must equal
    grid rank + ``num_scalar_prefetch``.

``fault-kind``
    Every kind in ``fault/inject.py::KINDS`` must be referenced by
    ``fault/supervisor.py`` — injected fault classes the supervisor
    cannot classify would silently break lossless recovery.

``dead-decision-field``
    ``Decision``/``ControllerConfig`` dataclass fields never read by
    any analyzed file (attribute access and ``getattr``/``hasattr``
    string literals both count as reads) must be deleted or wired up.

``tracked-bytecode``
    No ``__pycache__``/``.pyc`` artifact tracked by git, and
    ``.gitignore`` keeps covering ``__pycache__/`` + ``*.py[cod]``.
    Active only when the analysis root is the git toplevel.

Suppressions
============

``# repro: allow(<rule>[, <rule>...])`` on the flagged line or the line
immediately above suppresses those rules there.  ``# repro: hot`` on or
above a ``def`` marks it hot for ``host-sync-in-hot-path``.

Adding a checker
================

Subclass :class:`repro.analysis.core.Rule`, set ``name``, implement
``check_file(SourceFile)`` (per-file) and/or ``finish(Project)``
(cross-file), and register it in
:func:`repro.analysis.core.default_rules`.  Add a bad fixture proving
it fires and a good fixture proving it stays quiet under
``tests/analysis_fixtures/``.
"""
from repro.analysis.core import (Finding, Project, Rule,  # noqa: F401
                                 SourceFile, default_rules, report,
                                 run_analysis)

__all__ = ["Finding", "Project", "Rule", "SourceFile", "default_rules",
           "report", "run_analysis"]
