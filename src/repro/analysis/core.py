"""Checker framework: file walking, rule registry, findings, suppressions.

A :class:`Rule` sees every analyzed file once (:meth:`Rule.check_file`,
over a parsed :class:`SourceFile`) and the whole file set once at the end
(:meth:`Rule.finish`, over the :class:`Project`) — per-file rules use the
former, cross-file invariants (kernel/oracle pairing, fault-kind
exhaustiveness, dead dataclass fields, repo hygiene) the latter.  Every
:class:`Finding` carries ``rule``, ``file:line``, and a message; a
``# repro: allow(<rule>)`` comment on the flagged line or the line above
suppresses it (several rules comma-separate).

The rule battery lives in :mod:`repro.analysis.rules` (per-file) and
:mod:`repro.analysis.project` (cross-file); :func:`run_analysis` wires
walking, checking, and suppression together and is what both the CLI
(``python -m repro.analysis``) and the ``benchmarks/run.py`` pre-flight
call.
"""
from __future__ import annotations

import ast
import json
import os
import re
import sys
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\(([^)]*)\)")
_HOT_RE = re.compile(r"#\s*repro:\s*hot\b")


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored at ``path:line``."""
    rule: str
    path: str            # repo-relative (or as-given) path
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}


@dataclass
class SourceFile:
    """One parsed Python file: source, AST, and per-line annotations."""
    path: str            # absolute
    rel: str             # path relative to the project root ('/'-separated)
    source: str
    tree: ast.Module
    allows: Dict[int, Set[str]] = field(default_factory=dict)
    hot_lines: Set[int] = field(default_factory=set)

    @classmethod
    def parse(cls, path: str, rel: str) -> "SourceFile":
        with open(path, encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
        allows: Dict[int, Set[str]] = {}
        hot: Set[int] = set()
        for i, line in enumerate(source.splitlines(), start=1):
            m = _ALLOW_RE.search(line)
            if m:
                rules = {r.strip() for r in m.group(1).split(",")
                         if r.strip()}
                allows[i] = rules
            if _HOT_RE.search(line):
                hot.add(i)
        return cls(path=path, rel=rel, source=source, tree=tree,
                   allows=allows, hot_lines=hot)

    def allowed(self, rule: str, line: int) -> bool:
        """An ``allow(rule)`` comment on the flagged line or the line
        immediately above suppresses the finding."""
        for ln in (line, line - 1):
            if rule in self.allows.get(ln, ()):
                return True
        return False

    def is_hot_marked(self, node: ast.AST) -> bool:
        """A ``# repro: hot`` comment on the ``def`` line or the line
        immediately above (above any decorators) marks a function hot."""
        lines = {node.lineno, node.lineno - 1}
        for dec in getattr(node, "decorator_list", []):
            lines.add(dec.lineno - 1)
        return bool(lines & self.hot_lines)


@dataclass
class Project:
    """The analyzed file set plus the repo root project rules need for
    out-of-set context (``tests/``, ``git ls-files``, ``.gitignore``)."""
    root: str
    files: List[SourceFile] = field(default_factory=list)

    def find(self, rel_suffix: str) -> Optional[SourceFile]:
        for f in self.files:
            if f.rel.endswith(rel_suffix):
                return f
        return None


class Rule:
    """Base checker.  Subclasses set ``name`` and override one or both
    hooks; ``check_file`` runs once per analyzed file, ``finish`` once at
    the end with the whole :class:`Project`."""
    name: str = "rule"

    def check_file(self, f: SourceFile) -> Iterable[Finding]:
        return ()

    def finish(self, project: Project) -> Iterable[Finding]:
        return ()


def default_rules() -> List[Rule]:
    from repro.analysis import project as project_rules
    from repro.analysis import rules as file_rules
    return [
        file_rules.PrngReuseRule(),
        file_rules.DonationReuseRule(),
        file_rules.HostSyncRule(),
        project_rules.KernelOracleRule(),
        project_rules.FaultKindRule(),
        project_rules.DeadDecisionFieldRule(),
        project_rules.TrackedBytecodeRule(),
    ]


def _iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__"
                                     and not d.startswith("."))
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)


def run_analysis(paths: Sequence[str], root: Optional[str] = None,
                 rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Walk ``paths``, run every rule, and return suppression-filtered
    findings sorted by location.  ``root`` anchors relative finding paths
    and the project-level context (defaults to the CWD)."""
    root = os.path.abspath(root or os.getcwd())
    rules = list(default_rules() if rules is None else rules)
    project = Project(root=root)
    findings: List[Finding] = []
    by_rel: Dict[str, SourceFile] = {}
    for path in _iter_py_files(paths):
        apath = os.path.abspath(path)
        rel = os.path.relpath(apath, root).replace(os.sep, "/")
        if rel in by_rel:
            continue
        try:
            sf = SourceFile.parse(apath, rel)
        except SyntaxError as e:
            findings.append(Finding("parse-error", rel, e.lineno or 1,
                                    f"cannot parse: {e.msg}"))
            continue
        by_rel[rel] = sf
        project.files.append(sf)
    for sf in project.files:
        for rule in rules:
            for fnd in rule.check_file(sf):
                if not sf.allowed(fnd.rule, fnd.line):
                    findings.append(fnd)
    for rule in rules:
        for fnd in rule.finish(project):
            sf = by_rel.get(fnd.path)
            if sf is not None and sf.allowed(fnd.rule, fnd.line):
                continue
            findings.append(fnd)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def report(findings: Sequence[Finding], as_json: bool = False,
           stream=None) -> None:
    stream = stream or sys.stdout
    if as_json:
        json.dump([f.to_dict() for f in findings], stream, indent=1)
        stream.write("\n")
        return
    for f in findings:
        print(f.format(), file=stream)
    n = len(findings)
    print(f"repro.analysis: {n} finding{'s' if n != 1 else ''}",
          file=stream)
