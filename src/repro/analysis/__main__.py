"""CLI: ``python -m repro.analysis [--strict] [--json] [paths...]``."""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.core import report, run_analysis

DEFAULT_PATHS = ("src/repro", "benchmarks", "examples")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static invariant checks (see repro.analysis "
                    "docstring for the rule reference).")
    p.add_argument("paths", nargs="*",
                   help="files/directories to analyze (default: "
                        + " ".join(DEFAULT_PATHS) + " under --root)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 on any finding")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit findings as JSON")
    p.add_argument("--root", default=None,
                   help="repo root for relative paths and project-level "
                        "context (default: cwd)")
    args = p.parse_args(argv)
    root = os.path.abspath(args.root or os.getcwd())
    paths = args.paths or [os.path.join(root, d) for d in DEFAULT_PATHS
                           if os.path.isdir(os.path.join(root, d))]
    findings = run_analysis(paths, root=root)
    report(findings, as_json=args.as_json)
    return 1 if (args.strict and findings) else 0


if __name__ == "__main__":
    sys.exit(main())
