"""Experience collection: the serving loop (simulator <-> agent interaction).

``collect`` is the paper's "DRL serving block": the simulator and the agent
execute sequentially inside one jitted scan — the TCG (task-colocated GMI)
template, where state/action sharing is an intra-instance memory access
(COM = 0, Table 4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.policy import log_prob, policy_apply, sample_action


class Trajectory(NamedTuple):
    obs: jax.Array       # (T, N, obs_dim)
    actions: jax.Array   # (T, N, act_dim)
    log_probs: jax.Array # (T, N)
    rewards: jax.Array   # (T, N)
    dones: jax.Array     # (T, N)
    values: jax.Array    # (T, N)


def collect(policy_params, env, env_state, obs, key, num_steps: int,
            policy_fn=policy_apply):
    """Roll the policy for ``num_steps`` across all vectorized envs.

    Returns (traj, env_state, last_obs, last_value, key).
    """

    def step(carry, _):
        env_state, obs, key = carry
        key, akey = jax.random.split(key)
        mu, log_std, value = policy_fn(policy_params, obs)
        action = sample_action(akey, mu, log_std)
        lp = log_prob(mu, log_std, action)
        env_state, next_obs, reward, done = env.step(env_state, action)
        out = (obs, action, lp, reward, done.astype(jnp.float32), value)
        return (env_state, next_obs, key), out

    (env_state, obs, key), outs = jax.lax.scan(
        step, (env_state, obs, key), None, length=num_steps)
    traj = Trajectory(*outs)
    _, _, last_value = policy_fn(policy_params, obs)
    return traj, env_state, obs, last_value, key


def gae(rewards, values, dones, last_value, gamma: float = 0.99,
        lam: float = 0.95):
    """Generalized advantage estimation.  All inputs (T, N)."""

    def step(carry, xs):
        adv_next, v_next = carry
        r, v, d = xs
        nonterm = 1.0 - d
        delta = r + gamma * v_next * nonterm - v
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        step, (jnp.zeros_like(last_value), last_value),
        (rewards, values, dones), reverse=True)
    returns = advs + values
    return advs, returns


def gae_fused(rewards, values, dones, last_value, gamma: float = 0.99,
              lam: float = 0.95, eps: float = 1e-8):
    """Fused Pallas GAE: one kernel computes the reverse scan, the returns,
    AND the global advantage normalization without leaving VMEM (see
    ``repro.kernels.gae_scan``).  Returns (normalized_advs, returns).

    Unlike :func:`gae`, the advantages come back already normalized over
    the whole (T, N) batch — callers must not re-normalize per minibatch.
    """
    from repro.kernels import ops
    return ops.gae_norm(rewards, values, dones, last_value, gamma=gamma,
                        lam=lam, eps=eps)
