"""Experience collection: the serving loop (simulator <-> agent interaction).

``collect`` is the paper's "DRL serving block": the simulator and the agent
execute sequentially inside one jitted scan — the TCG (task-colocated GMI)
template, where state/action sharing is an intra-instance memory access
(COM = 0, Table 4).

``collect_ring`` is its zero-copy producer sibling for megakernel envs:
the same scan, but each step runs the fused env megakernel
(``kernels/env_megakernel.py``) which writes obs/action/reward/done
straight into the caller's ``ChannelRing`` slot buffers — no Trajectory
is staged, nothing is re-packed by ``pack_channels``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.policy import log_prob, policy_apply, sample_action


class Trajectory(NamedTuple):
    obs: jax.Array       # (T, N, obs_dim)
    actions: jax.Array   # (T, N, act_dim)
    log_probs: jax.Array # (T, N)
    rewards: jax.Array   # (T, N)
    dones: jax.Array     # (T, N)
    values: jax.Array    # (T, N)


def collect(policy_params, env, env_state, obs, key, num_steps: int,
            policy_fn=policy_apply):
    """Roll the policy for ``num_steps`` across all vectorized envs.

    Returns (traj, env_state, last_obs, last_value, key).
    """

    def step(carry, _):
        env_state, obs, key = carry
        key, akey = jax.random.split(key)
        mu, log_std, value = policy_fn(policy_params, obs)
        action = sample_action(akey, mu, log_std)
        lp = log_prob(mu, log_std, action)
        env_state, next_obs, reward, done = env.step(env_state, action)
        out = (obs, action, lp, reward, done.astype(jnp.float32), value)
        return (env_state, next_obs, key), out

    (env_state, obs, key), outs = jax.lax.scan(
        step, (env_state, obs, key), None, length=num_steps)
    traj = Trajectory(*outs)
    _, _, last_value = policy_fn(policy_params, obs)
    return traj, env_state, obs, last_value, key


@functools.partial(
    jax.jit, donate_argnums=(4,),
    static_argnames=("chain", "task", "substeps", "dt", "max_episode_len",
                     "num_steps", "use_pallas", "interpret", "policy_fn"))
def _collect_ring(params, state, obs, key, bufs, slot, sensor, tgt, masses,
                  lengths, *, chain, task, substeps, dt, max_episode_len,
                  num_steps, use_pallas, interpret, policy_fn):
    from repro.envs.base import EnvState
    from repro.kernels.env_megakernel import env_mega_step, mega_step_ring
    slot_i = jnp.asarray(slot, jnp.int32)

    def step(carry, step_t):
        state, obs, key, bufs = carry
        key, akey = jax.random.split(key)
        mu, log_std, _ = policy_fn(params, obs)
        action = sample_action(akey, mu, log_std)
        if use_pallas:
            out = env_mega_step(
                *state, action, obs, bufs, step_t, slot_i, sensor, tgt,
                masses, lengths, chain=chain, task=task, substeps=substeps,
                dt=dt, max_episode_len=max_episode_len, interpret=interpret)
        else:
            out = mega_step_ring(
                *state, action, obs, bufs, step_t, slot_i, sensor, tgt,
                masses, lengths, chain=chain, task=task, substeps=substeps,
                dt=dt, max_episode_len=max_episode_len)
        q, qd, root, pa, t, seed, resets, next_obs = out[:8]
        return (EnvState(q, qd, root, pa, t, seed, resets), next_obs, key,
                out[10]), None

    (state, obs, key, bufs), _ = jax.lax.scan(
        step, (state, obs, key, bufs),
        jnp.arange(num_steps, dtype=jnp.int32))
    _, _, bootstrap = policy_fn(params, obs)
    return bufs, state, obs, bootstrap, key


def collect_ring(policy_params, env, env_state, obs, key, num_steps: int,
                 bufs, slot, policy_fn=policy_apply, use_pallas=None):
    """Zero-copy serving for ``VectorEnv(megakernel=True)``.

    One jitted, donated scan: per step the policy acts, then the fused
    env megakernel advances every env AND writes the experience row
    (acted-on obs, raw action, reward, done) directly into ring slot
    ``slot`` of the ``{obs, actions, rewards, dones}`` buffers ``bufs``
    — the ``ChannelRing`` layout from ``kernels/channel_pack.py``.
    ``bufs`` is donated; use the returned dict.  On TPU the step is the
    Pallas megakernel; elsewhere the identically fused XLA program
    (``mega_step_ring``), matching the ``pack_channels`` convention.

    Returns ``(bufs, env_state, last_obs, bootstrap, key)`` where
    ``bootstrap`` is the value of ``last_obs`` under ``policy_params``.
    """
    if not getattr(env, "megakernel", False):
        raise ValueError("collect_ring needs VectorEnv(megakernel=True); "
                         "use collect for the vmap path")
    on_tpu = jax.default_backend() == "tpu"
    use_pallas = on_tpu if use_pallas is None else use_pallas
    mc = env.mega
    return _collect_ring(
        policy_params, env_state, obs, key, bufs, jnp.asarray(slot, jnp.int32),
        mc.sensor, mc.tgt, mc.masses, mc.lengths, chain=mc.chain,
        task=mc.task, substeps=env.spec.substeps, dt=env.spec.dt,
        max_episode_len=env.spec.max_episode_len, num_steps=int(num_steps),
        use_pallas=use_pallas, interpret=not on_tpu, policy_fn=policy_fn)


def gae(rewards, values, dones, last_value, gamma: float = 0.99,
        lam: float = 0.95):
    """Generalized advantage estimation.  All inputs (T, N)."""

    def step(carry, xs):
        adv_next, v_next = carry
        r, v, d = xs
        nonterm = 1.0 - d
        delta = r + gamma * v_next * nonterm - v
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        step, (jnp.zeros_like(last_value), last_value),
        (rewards, values, dones), reverse=True)
    returns = advs + values
    return advs, returns


def gae_fused(rewards, values, dones, last_value, gamma: float = 0.99,
              lam: float = 0.95, eps: float = 1e-8):
    """Fused Pallas GAE: one kernel computes the reverse scan, the returns,
    AND the global advantage normalization without leaving VMEM (see
    ``repro.kernels.gae_scan``).  Returns (normalized_advs, returns).

    Unlike :func:`gae`, the advantages come back already normalized over
    the whole (T, N) batch — callers must not re-normalize per minibatch.
    """
    from repro.kernels import ops
    return ops.gae_norm(rewards, values, dones, last_value, gamma=gamma,
                        lam=lam, eps=eps)
