"""A3C-style asynchronized DRL training (Mnih et al., ICML'16; GA3C).

The paper's async mode decouples *serving* (experience collection on agent
GMIs) from *training* (policy update on trainer GMIs), connected by the
channel-based experience pipeline (§4.2).  In single-controller JAX the
asynchrony is modeled as round-interleaved execution with an explicit
parameter-staleness counter: actors hold a possibly-stale snapshot of the
policy; trainers consume experience batches in arrival order.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.policy import entropy, log_prob, policy_apply
from repro.optim import adam_update
from repro.rl.rollout import collect, collect_ring


class Experience(NamedTuple):
    """One actor-produced experience batch (the unit shipped over channels)."""
    obs: jax.Array        # (T, N, obs_dim)
    actions: jax.Array    # (T, N, act_dim)
    rewards: jax.Array    # (T, N)
    dones: jax.Array      # (T, N)
    bootstrap: jax.Array  # (N,) value of last obs under the actor's params
    actor_version: jax.Array  # scalar: params version used to act


def actor_collect(params, version, env, env_state, obs, key,
                  num_steps: int) -> tuple:
    """Experience collection on an agent instance (policy serving)."""
    traj, env_state, obs, last_value, key = collect(
        params, env, env_state, obs, key, num_steps)
    exp = Experience(obs=traj.obs, actions=traj.actions, rewards=traj.rewards,
                     dones=traj.dones, bootstrap=last_value,
                     actor_version=version)
    return exp, env_state, obs, key


def nstep_returns(rewards, dones, bootstrap, gamma: float = 0.99, *,
                  use_fused_kernels: bool = False):
    """Reverse discounted-return scan; ``use_fused_kernels`` routes it
    through the fused Pallas block-resident scan (kernels/gae_scan.py's
    n-step sibling) instead of the unfused ``lax.scan``."""
    if use_fused_kernels:
        from repro.kernels import ops
        return ops.nstep_returns(rewards, dones, bootstrap, gamma=gamma)

    def step(carry, xs):
        r, d = xs
        g = r + gamma * carry * (1.0 - d)
        return g, g
    _, rets = jax.lax.scan(step, bootstrap, (rewards, dones), reverse=True)
    return rets


def a3c_loss(params, exp: Experience, gamma: float, vf_coef: float,
             ent_coef: float, use_fused_kernels: bool = False):
    rets = nstep_returns(exp.rewards, exp.dones, exp.bootstrap, gamma,
                         use_fused_kernels=use_fused_kernels)
    mu, log_std, value = policy_apply(params, exp.obs)
    adv = rets - value
    lp = log_prob(mu, log_std, exp.actions)
    pg = -(lp * jax.lax.stop_gradient(adv)).mean()
    vf = 0.5 * jnp.square(adv).mean()
    ent = entropy(log_std).mean()
    return pg + vf_coef * vf - ent_coef * ent, (pg, vf, ent)


def trainer_update(params, opt_state, exp: Experience, *, lr=3e-4,
                   gamma=0.99, vf_coef=0.5, ent_coef=0.01, grad_sync_fn=None,
                   max_grad_norm=1.0, use_fused_kernels=False):
    """Policy update on a trainer instance from one experience batch.

    ``grad_sync_fn`` may be a bare closure or a
    ``repro.comm.Communicator`` (resolved via its grad-sync property)."""
    from repro.comm.api import as_grad_sync
    grad_sync_fn = as_grad_sync(grad_sync_fn)
    (loss, aux), grads = jax.value_and_grad(a3c_loss, has_aux=True)(
        params, exp, gamma, vf_coef, ent_coef, use_fused_kernels)
    if grad_sync_fn is not None:
        grads = grad_sync_fn(grads)
    params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                    beta1=0.9, beta2=0.999,
                                    grad_clip=max_grad_norm)
    return params, opt_state, loss


def staleness(current_version, exp: Experience):
    """Paper §5.1: async training trades throughput for parameter staleness."""
    return current_version - exp.actor_version


class AsyncRunner:
    """Round-interleaved async A3C over the device-resident MCC pipeline.

    Owns the whole §4.2 flow for one async layout: serving GMIs collect
    with a (possibly stale) parameter snapshot, pushes land in the
    per-group device ring buffers, ``flush`` pointer-bumps the round's
    experience to the trainers the Migrator picks, and every consumed
    batch advances the parameter version.  The per-GMI GPU map from the
    placement layout is what lets the Migrator direct-forward same-GPU
    groups instead of funneling every flush to one trainer.

    ``overlap=True`` double-buffers the rings (paper §4.1): ``flush``
    swaps buffers instead of waiting, so each round trains on the
    PREVIOUS round's experience while this round's pushes are still
    materializing in the front halves — serving never stalls behind the
    trainer.  Call :meth:`finish` when done so the in-flight tail is
    trained on too (``trained_samples`` catches up to ``predictions``
    there, at the cost of one extra staleness step on the tail).

    An attached :class:`~repro.core.controller.OnlineGMIController`
    observes every round (throughput, ring occupancy, spills) and may
    hand back a re-plan between epochs; :meth:`replan` applies it by
    draining the old pipeline (lossless across the re-plan), rebuilding
    pipeline + actors under the new layout, and keeping model state.

    An attached :class:`~repro.comm.Communicator` owns the reduction
    decision state for the controller loop: measured per-round reduce
    seconds reach it through ``RoundSample.reduce_s`` (or direct
    ``observe`` calls from a real SPMD launcher — the runner's eager
    simulation has no cross-instance reduce to time, and timing the
    identity closure would feed scheduler noise into the switch
    hysteresis), and a controller Decision carrying a
    ``reduction_strategy`` switches the schedule in place — communication
    plumbing only, params/optimizer untouched.  Mesh-attached
    communicators are rejected: their sync closure is SPMD-only and
    cannot run inside this eager trainer.
    """

    def __init__(self, env, serving_gmis, trainer_gmis, *, gmi_gpu=None,
                 num_envs: int = 64, num_steps: int = 16, seed: int = 0,
                 lr: float = 3e-4, pipeline=None, overlap: bool = False,
                 controller=None, layout_builder=None, communicator=None,
                 router=None, use_fused_kernels: bool = False):
        from repro.core.channels import MultiChannelPipeline
        from repro.models.policy import init_policy
        from repro.optim import adam_init

        self.env = env
        self.num_steps = num_steps
        self.num_envs = num_envs
        self.serving_gmis = list(serving_gmis)
        self.lr = lr
        self.seed = seed
        self.overlap = overlap
        self.controller = controller
        self.layout_builder = layout_builder
        # single-arbiter control plane: with a request-serving front
        # attached (RequestRouter or serve.disagg.DisaggFront), its
        # telemetry epochs fold into the SAME controller instance every
        # round and its decisions apply through the front's thin
        # apply_decision hook — rollout, trainer, prefill, and decode
        # GMIs all arbitrated by one Algorithm-2 loop under one
        # min_gain hysteresis, never by a second decision loop
        self.router = router
        if communicator is not None and communicator.mesh is not None:
            raise TypeError(
                "AsyncRunner's round-interleaved trainer is eager; a "
                "mesh-attached Communicator's sync closure is SPMD-only "
                "(use allreduce in a shard_map launcher, or attach a "
                "mesh-less Communicator for decision state)")
        self.communicator = communicator
        self.use_fused_kernels = use_fused_kernels
        if controller is not None and communicator is not None \
                and controller.communicator is None:
            controller.communicator = communicator
        self.pipe = pipeline or MultiChannelPipeline(
            serving_gmis, trainer_gmis, gmi_gpu=gmi_gpu, overlap=overlap)
        self.params = init_policy(jax.random.key(seed), env.spec.policy_dims)
        self.opt_state = adam_init(self.params)
        self.actor_params = self.params        # stale snapshot
        self.version = jnp.int32(0)
        self.actors = {}
        self._reset_actors()
        self.predictions = 0
        self.trained_samples = 0
        self.replans = 0
        self.rounds = 0
        # fault-injection seam (repro.fault): called with ("serving", gmi)
        # before each actor collect and ("trainer", gmi) before each batch
        # update; raising InjectedFault there kills that GMI mid-round.
        # The trainer path re-queues every consumed-but-untrained batch
        # into the pipeline (spill-not-drop) before propagating.
        self.fault_hook = None
        # non-finite guard (installed by the FleetSupervisor): a batch
        # whose loss is NaN/inf — e.g. a poisoned channel flush — has its
        # UPDATE discarded (params/opt/version untouched) instead of
        # corrupting the model; the data itself is unrecoverable and is
        # counted, not retrained
        self.nonfinite_guard = False
        self.poisoned_batches = 0
        self.poisoned_samples = 0

    def _reset_actors(self):
        self.actors = {}
        for a in self.serving_gmis:
            es, obs = self.env.reset(jax.random.PRNGKey(self.seed + a),
                                     num_envs=self.num_envs)
            self.actors[a] = [es, obs,
                              jax.random.PRNGKey(self.seed + 100 + a)]

    # repro: hot
    def _train(self, routed):
        """Consume routed trainer batches; returns (losses, staleness)."""
        losses, stale = [], []
        # a mesh-less communicator's sync closure is the identity (and is
        # deliberately NOT timed: measured reduce seconds enter through
        # RoundSample.reduce_s / Communicator.observe, never from no-ops)
        sync = None if self.communicator is None \
            else self.communicator.grad_sync_fn
        # flat worklist so a mid-iteration trainer fault can re-queue the
        # failing batch AND everything not yet consumed
        work = [(dst, exp) for dst, batches in routed.items()
                for exp in batches]
        for i, (dst, exp) in enumerate(work):
            if self.fault_hook is not None:
                try:
                    self.fault_hook("trainer", dst)
                except BaseException:
                    # spill, not drop: this batch's gradient is lost with
                    # the trainer, but its experience — and every batch
                    # behind it — rejoins the pipeline for the survivors
                    self.pipe.requeue([e for _, e in work[i:]])
                    raise
            stale.append(int(staleness(self.version, exp)))
            new_params, new_opt, loss = trainer_update(
                self.params, self.opt_state, exp, lr=self.lr,
                grad_sync_fn=sync,
                use_fused_kernels=self.use_fused_kernels)
            if self.nonfinite_guard and not bool(jnp.isfinite(loss)):
                # discard the poisoned update: the pre-update pytrees are
                # still live (JAX arrays are immutable — rollback is free);
                # version stays put so staleness accounting is untouched
                self.poisoned_batches += 1
                self.poisoned_samples += int(exp.rewards.size)
                continue
            self.params, self.opt_state = new_params, new_opt
            # keep the loss on device: a float() here would sync the
            # trainer stream once per batch (host-sync-in-hot-path)
            losses.append(loss)
            self.trained_samples += int(exp.rewards.size)
            self.version = self.version + 1
        # single post-loop drain of the queued losses
        return ([float(x)  # repro: allow(host-sync-in-hot-path)
                 for x in jax.device_get(losses)], stale)

    # repro: hot
    def round(self):
        """One serve -> ship -> train round; returns (losses, staleness).

        With overlap on, the trained batches are the previous round's
        flush (the first round returns no losses)."""
        # round-duration telemetry feeds the controller's ladder
        t0 = time.perf_counter()  # repro: allow(host-sync-in-hot-path)
        # megakernel envs on blocking rings produce experience straight
        # into the ring slot (collect_ring): no staged Trajectory, no
        # pack_channels re-copy.  Overlap rings stage references (zero
        # producer-side device work already), so they keep actor_collect.
        direct = (getattr(self.env, "megakernel", False)
                  and not self.overlap and hasattr(self.pipe, "produce"))
        for a in self.serving_gmis:
            if self.fault_hook is not None:
                # a kill here loses only THIS GMI's not-yet-collected
                # round; earlier actors' pushes are already ringed and
                # survive into the recovery drain
                self.fault_hook("serving", a)
            es, obs, k = self.actors[a]
            if direct:
                carry = {}

                def producer(bufs, slot, _es=es, _obs=obs, _k=k):
                    bufs, es2, obs2, boot, k2 = collect_ring(
                        self.actor_params, self.env, _es, _obs, _k,
                        self.num_steps, bufs, slot)
                    carry["actor"] = [es2, obs2, k2]
                    return bufs, boot, self.version

                self.pipe.produce(a, self.num_steps, self.num_envs,
                                  self.env.spec.obs_dim,
                                  self.env.spec.act_dim, producer)
                self.actors[a] = carry["actor"]
                self.predictions += self.num_steps * self.num_envs
                continue
            exp, es, obs, k = actor_collect(
                self.actor_params, self.version, self.env, es, obs, k,
                self.num_steps)
            self.actors[a] = [es, obs, k]
            self.predictions += int(exp.rewards.size)
            self.pipe.push(a, exp)
        before = self.trained_samples
        losses, stale = self._train(self.pipe.flush())
        self.actor_params = self.params        # model push AFTER acting
        if self.controller is not None:
            decision = self.controller.observe_pipeline(
                self.pipe, samples=self.trained_samples - before,
                # repro: allow(host-sync-in-hot-path)
                dt=time.perf_counter() - t0)
            if decision is not None:
                if decision.layout_changed:
                    self.replan(decision)
                elif decision.reduction_strategy \
                        and self.communicator is not None:
                    # strategy-only re-plan: pure communication plumbing,
                    # no pipeline drain / actor rebuild needed
                    self.communicator.switch(decision.reduction_strategy)
        if self.router is not None and self.controller is not None:
            # the serving half of the single-arbiter loop: fold the
            # front's telemetry epoch into the same controller and apply
            # whatever it answers through the thin hook.  A decision
            # captured before this round's rollout re-plan carries a
            # stale seq and is refused by the hook's fence.
            sdec = self.controller.observe_serving(self.router.take_epoch())
            if sdec is not None:
                self.router.apply_decision(sdec, controller=self.controller)
        self.rounds += 1
        return losses, stale

    def finish(self):
        """Drain the pipeline (both buffer halves in overlap mode) and
        train on the tail; returns (losses, staleness)."""
        losses, stale = self._train(self.pipe.drain())
        self.actor_params = self.params
        return losses, stale

    def replan(self, decision, layout=None):
        """Apply a controller Decision between epochs: drain + train on
        everything still buffered (nothing is lost across the re-plan),
        then rebuild the pipeline — carrying the old pipeline's batching
        /ring/backend configuration — and the actors under the new
        layout.  Model parameters, optimizer state, and version persist.
        A decision carrying a ``reduction_strategy`` additionally switches
        the communicator's LGR schedule in place — by construction this
        touches no model state.

        An explicit ``layout`` bypasses the controller/layout_builder —
        the FleetSupervisor's failure-recovery path, where the layout is
        planned against the reduced (quarantined) pool rather than the
        controller's notion of the fleet."""
        if not hasattr(self.pipe, "clone_for"):
            raise TypeError(
                f"online re-planning needs a pipeline with clone_for "
                f"(MultiChannelPipeline), got {type(self.pipe).__name__}")
        if self.controller is not None:
            # staleness fence: any serving Decision emitted before this
            # drain carries the old seq and must not apply afterwards —
            # it was computed against the layout being torn down
            self.controller.plan_seq += 1
        self._train(self.pipe.drain())
        if layout is None:
            layout = (self.layout_builder(decision) if self.layout_builder
                      else self.controller.plan_layout())
        if self.communicator is not None:
            # the communicator's grid/cost model must track the NEW
            # layout, or later strategy decisions are scored (and
            # validated) against a stale instance grid
            self.communicator.rebind(layout)
            if getattr(decision, "reduction_strategy", None):
                strat = decision.reduction_strategy
                if strat in self.communicator.candidates():
                    self.communicator.switch(strat)
        gmi_gpu = {g.gmi_id: g.gpu_id for g in layout.manager.gmis.values()}
        self.serving_gmis = list(layout.serving_gmis)
        self.pipe = self.pipe.clone_for(layout.serving_gmis,
                                        layout.trainer_gmis, gmi_gpu=gmi_gpu)
        self.num_envs = int(decision.num_env)
        self._reset_actors()
        self.actor_params = self.params
        self.replans += 1
        return layout

    # ------------------------------------------------- preemption safety --
    def _ckpt_template(self):
        return {"params": self.params, "opt_state": self.opt_state,
                "version": self.version}

    def checkpoint(self, directory, step=None, fault_hook=None):
        """Preemption-safe checkpoint: params/opt_state/version as the
        atomic npz+manifest pair (``repro.checkpoint``), with counters and
        the controller's learned tables riding in the manifest ``extra``.
        Returns the checkpoint path prefix."""
        import os

        from repro.checkpoint import ckpt
        if step is None:
            step = int(self.version)
        extra = {"predictions": self.predictions,
                 "trained_samples": self.trained_samples,
                 "num_envs": self.num_envs,
                 "rounds": self.rounds}
        if self.controller is not None \
                and hasattr(self.controller, "state_dict"):
            extra["controller"] = self.controller.state_dict()
        path = os.path.join(directory, f"ckpt_{step}")
        ckpt.save(path, self._ckpt_template(), step=step, extra=extra,
                  fault_hook=fault_hook)
        return path

    def restore(self, directory, shardings=None):
        """Resume from the newest LOADABLE checkpoint in ``directory``.

        Torn pairs (manifest without npz) are invisible via
        ``ckpt.steps``; a pair that is present but unreadable (truncated
        npz, template mismatch) is skipped and the previous step is
        tried — so a crash during or after a save always resumes from the
        last durable state.  Returns the restored step, or ``None`` when
        nothing loadable exists (fresh start)."""
        import os

        from repro.checkpoint import ckpt
        for step in reversed(ckpt.steps(directory)):
            path = os.path.join(directory, f"ckpt_{step}")
            try:
                tree = ckpt.load(path, self._ckpt_template(),
                                 shardings=shardings)
                extra = ckpt.load_manifest(path).get("extra") or {}
            except (FileNotFoundError, ValueError, KeyError):
                continue
            self.params = tree["params"]
            self.opt_state = tree["opt_state"]
            self.version = tree["version"]
            self.actor_params = self.params
            self.predictions = int(extra.get("predictions",
                                             self.predictions))
            self.trained_samples = int(extra.get("trained_samples",
                                                 self.trained_samples))
            self.rounds = int(extra.get("rounds", self.rounds))
            new_envs = int(extra.get("num_envs", self.num_envs))
            if new_envs != self.num_envs:
                self.num_envs = new_envs
                self._reset_actors()
            if self.controller is not None and "controller" in extra \
                    and hasattr(self.controller, "load_state_dict"):
                self.controller.load_state_dict(extra["controller"])
            return step
        return None
