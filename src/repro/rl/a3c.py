"""A3C-style asynchronized DRL training (Mnih et al., ICML'16; GA3C).

The paper's async mode decouples *serving* (experience collection on agent
GMIs) from *training* (policy update on trainer GMIs), connected by the
channel-based experience pipeline (§4.2).  In single-controller JAX the
asynchrony is modeled as round-interleaved execution with an explicit
parameter-staleness counter: actors hold a possibly-stale snapshot of the
policy; trainers consume experience batches in arrival order.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.policy import entropy, log_prob, policy_apply
from repro.optim import adam_update
from repro.rl.rollout import collect


class Experience(NamedTuple):
    """One actor-produced experience batch (the unit shipped over channels)."""
    obs: jax.Array        # (T, N, obs_dim)
    actions: jax.Array    # (T, N, act_dim)
    rewards: jax.Array    # (T, N)
    dones: jax.Array      # (T, N)
    bootstrap: jax.Array  # (N,) value of last obs under the actor's params
    actor_version: jax.Array  # scalar: params version used to act


def actor_collect(params, version, env, env_state, obs, key,
                  num_steps: int) -> tuple:
    """Experience collection on an agent instance (policy serving)."""
    traj, env_state, obs, last_value, key = collect(
        params, env, env_state, obs, key, num_steps)
    exp = Experience(obs=traj.obs, actions=traj.actions, rewards=traj.rewards,
                     dones=traj.dones, bootstrap=last_value,
                     actor_version=version)
    return exp, env_state, obs, key


def nstep_returns(rewards, dones, bootstrap, gamma: float = 0.99):
    def step(carry, xs):
        r, d = xs
        g = r + gamma * carry * (1.0 - d)
        return g, g
    _, rets = jax.lax.scan(step, bootstrap, (rewards, dones), reverse=True)
    return rets


def a3c_loss(params, exp: Experience, gamma: float, vf_coef: float,
             ent_coef: float):
    rets = nstep_returns(exp.rewards, exp.dones, exp.bootstrap, gamma)
    mu, log_std, value = policy_apply(params, exp.obs)
    adv = rets - value
    lp = log_prob(mu, log_std, exp.actions)
    pg = -(lp * jax.lax.stop_gradient(adv)).mean()
    vf = 0.5 * jnp.square(adv).mean()
    ent = entropy(log_std).mean()
    return pg + vf_coef * vf - ent_coef * ent, (pg, vf, ent)


def trainer_update(params, opt_state, exp: Experience, *, lr=3e-4,
                   gamma=0.99, vf_coef=0.5, ent_coef=0.01, grad_sync_fn=None,
                   max_grad_norm=1.0):
    """Policy update on a trainer instance from one experience batch."""
    (loss, aux), grads = jax.value_and_grad(a3c_loss, has_aux=True)(
        params, exp, gamma, vf_coef, ent_coef)
    if grad_sync_fn is not None:
        grads = grad_sync_fn(grads)
    params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                    beta1=0.9, beta2=0.999,
                                    grad_clip=max_grad_norm)
    return params, opt_state, loss


def staleness(current_version, exp: Experience):
    """Paper §5.1: async training trades throughput for parameter staleness."""
    return current_version - exp.actor_version


class AsyncRunner:
    """Round-interleaved async A3C over the device-resident MCC pipeline.

    Owns the whole §4.2 flow for one async layout: serving GMIs collect
    with a (possibly stale) parameter snapshot, pushes land in the
    per-group device ring buffers, ``flush`` pointer-bumps the round's
    experience to the trainers the Migrator picks, and every consumed
    batch advances the parameter version.  The per-GMI GPU map from the
    placement layout is what lets the Migrator direct-forward same-GPU
    groups instead of funneling every flush to one trainer.
    """

    def __init__(self, env, serving_gmis, trainer_gmis, *, gmi_gpu=None,
                 num_envs: int = 64, num_steps: int = 16, seed: int = 0,
                 lr: float = 3e-4, pipeline=None):
        from repro.core.channels import MultiChannelPipeline
        from repro.models.policy import init_policy
        from repro.optim import adam_init

        self.env = env
        self.num_steps = num_steps
        self.serving_gmis = list(serving_gmis)
        self.lr = lr
        self.pipe = pipeline or MultiChannelPipeline(
            serving_gmis, trainer_gmis, gmi_gpu=gmi_gpu)
        self.params = init_policy(jax.random.key(seed), env.spec.policy_dims)
        self.opt_state = adam_init(self.params)
        self.actor_params = self.params        # stale snapshot
        self.version = jnp.int32(0)
        self.actors = {}
        for a in self.serving_gmis:
            es, obs = env.reset(jax.random.PRNGKey(seed + a),
                                num_envs=num_envs)
            self.actors[a] = [es, obs, jax.random.PRNGKey(seed + 100 + a)]
        self.predictions = 0
        self.trained_samples = 0

    def round(self):
        """One serve -> ship -> train round; returns (losses, staleness)."""
        for a in self.serving_gmis:
            es, obs, k = self.actors[a]
            exp, es, obs, k = actor_collect(
                self.actor_params, self.version, self.env, es, obs, k,
                self.num_steps)
            self.actors[a] = [es, obs, k]
            self.predictions += int(exp.rewards.size)
            self.pipe.push(a, exp)
        losses, stale = [], []
        for _, batches in self.pipe.flush().items():
            for exp in batches:
                stale.append(int(staleness(self.version, exp)))
                self.params, self.opt_state, loss = trainer_update(
                    self.params, self.opt_state, exp, lr=self.lr)
                losses.append(float(loss))
                self.trained_samples += int(exp.rewards.size)
                self.version = self.version + 1
        self.actor_params = self.params        # model push AFTER acting
        return losses, stale
