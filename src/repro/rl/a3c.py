"""A3C-style asynchronized DRL training (Mnih et al., ICML'16; GA3C).

The paper's async mode decouples *serving* (experience collection on agent
GMIs) from *training* (policy update on trainer GMIs), connected by the
channel-based experience pipeline (§4.2).  In single-controller JAX the
asynchrony is modeled as round-interleaved execution with an explicit
parameter-staleness counter: actors hold a possibly-stale snapshot of the
policy; trainers consume experience batches in arrival order.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.policy import entropy, log_prob, policy_apply
from repro.optim import adam_update
from repro.rl.rollout import collect


class Experience(NamedTuple):
    """One actor-produced experience batch (the unit shipped over channels)."""
    obs: jax.Array        # (T, N, obs_dim)
    actions: jax.Array    # (T, N, act_dim)
    rewards: jax.Array    # (T, N)
    dones: jax.Array      # (T, N)
    bootstrap: jax.Array  # (N,) value of last obs under the actor's params
    actor_version: jax.Array  # scalar: params version used to act


def actor_collect(params, version, env, env_state, obs, key,
                  num_steps: int) -> tuple:
    """Experience collection on an agent instance (policy serving)."""
    traj, env_state, obs, last_value, key = collect(
        params, env, env_state, obs, key, num_steps)
    exp = Experience(obs=traj.obs, actions=traj.actions, rewards=traj.rewards,
                     dones=traj.dones, bootstrap=last_value,
                     actor_version=version)
    return exp, env_state, obs, key


def nstep_returns(rewards, dones, bootstrap, gamma: float = 0.99):
    def step(carry, xs):
        r, d = xs
        g = r + gamma * carry * (1.0 - d)
        return g, g
    _, rets = jax.lax.scan(step, bootstrap, (rewards, dones), reverse=True)
    return rets


def a3c_loss(params, exp: Experience, gamma: float, vf_coef: float,
             ent_coef: float):
    rets = nstep_returns(exp.rewards, exp.dones, exp.bootstrap, gamma)
    mu, log_std, value = policy_apply(params, exp.obs)
    adv = rets - value
    lp = log_prob(mu, log_std, exp.actions)
    pg = -(lp * jax.lax.stop_gradient(adv)).mean()
    vf = 0.5 * jnp.square(adv).mean()
    ent = entropy(log_std).mean()
    return pg + vf_coef * vf - ent_coef * ent, (pg, vf, ent)


def trainer_update(params, opt_state, exp: Experience, *, lr=3e-4,
                   gamma=0.99, vf_coef=0.5, ent_coef=0.01, grad_sync_fn=None,
                   max_grad_norm=1.0):
    """Policy update on a trainer instance from one experience batch."""
    (loss, aux), grads = jax.value_and_grad(a3c_loss, has_aux=True)(
        params, exp, gamma, vf_coef, ent_coef)
    if grad_sync_fn is not None:
        grads = grad_sync_fn(grads)
    params, opt_state = adam_update(grads, opt_state, params, lr=lr,
                                    beta1=0.9, beta2=0.999,
                                    grad_clip=max_grad_norm)
    return params, opt_state, loss


def staleness(current_version, exp: Experience):
    """Paper §5.1: async training trades throughput for parameter staleness."""
    return current_version - exp.actor_version
