from repro.rl import a3c, ppo, rollout  # noqa: F401
