"""PPO (Schulman et al., arXiv:1707.06347) — the paper's synchronized DRL
training workload (Isaac Gym's official algorithm).

One ``train_iteration`` = experience collection (m simulator-agent rounds)
+ minibatched clipped-surrogate updates — the two sequential stages of §5.1.
Gradient synchronization across trainer GMIs plugs in via ``grad_sync_fn``,
which accepts either a bare closure or a ``repro.comm.Communicator`` (the
communication subsystem object owning mesh + LGR strategy); identity on a
single instance.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.policy import entropy, log_prob, policy_apply
from repro.optim import AdamState, adam_init, adam_update
from repro.rl.rollout import Trajectory, collect, gae, gae_fused


class PPOConfig(NamedTuple):
    num_steps: int = 32          # m: simulator-agent rounds per iteration
    num_epochs: int = 4
    num_minibatches: int = 4
    gamma: float = 0.99
    lam: float = 0.95
    clip_eps: float = 0.2
    vf_coef: float = 0.5
    ent_coef: float = 0.01
    lr: float = 3e-4
    max_grad_norm: float = 1.0
    # fused hot path: Pallas GAE+normalization kernel and single-gather
    # minibatch shuffling (advantages arrive batch-normalized, so the loss
    # skips its per-minibatch renormalization)
    use_fused_kernels: bool = False


def ppo_loss(params, batch, clip_eps, vf_coef, ent_coef,
             policy_fn=policy_apply, normalize_adv: bool = True):
    obs, actions, old_lp, advs, returns = batch
    mu, log_std, value = policy_fn(params, obs)
    lp = log_prob(mu, log_std, actions)
    ratio = jnp.exp(lp - old_lp)
    advs_n = (advs - advs.mean()) / (advs.std() + 1e-8) \
        if normalize_adv else advs
    pg = -jnp.minimum(ratio * advs_n,
                      jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * advs_n)
    vf = 0.5 * jnp.square(value - returns)
    ent = entropy(log_std)
    loss = pg.mean() + vf_coef * vf.mean() - ent_coef * ent.mean()
    return loss, (pg.mean(), vf.mean(), ent.mean())


def train_iteration(params, opt_state: AdamState, env, env_state, obs, key,
                    cfg: PPOConfig, grad_sync_fn: Optional[Callable] = None,
                    policy_fn=policy_apply):
    """One full PPO iteration.  Returns (params, opt_state, env_state, obs,
    key, metrics).  ``grad_sync_fn`` may be a closure or a Communicator."""
    from repro.comm.api import as_grad_sync   # lazy: rl <-> comm layering
    grad_sync_fn = as_grad_sync(grad_sync_fn)
    traj, env_state, obs, last_value, key = collect(
        params, env, env_state, obs, key, cfg.num_steps, policy_fn)
    if cfg.use_fused_kernels:
        # fused Pallas kernel: advantages arrive normalized over the batch
        advs, returns = gae_fused(traj.rewards, traj.values, traj.dones,
                                  last_value, cfg.gamma, cfg.lam)
    else:
        advs, returns = gae(traj.rewards, traj.values, traj.dones,
                            last_value, cfg.gamma, cfg.lam)

    T, N = traj.rewards.shape
    flat = jax.tree.map(lambda x: x.reshape((T * N,) + x.shape[2:]),
                        (traj.obs, traj.actions, traj.log_probs, advs,
                         returns))
    mb_size = (T * N) // cfg.num_minibatches

    def epoch(carry, _):
        params, opt_state, key = carry
        key, pkey = jax.random.split(key)
        perm = jax.random.permutation(pkey, T * N)
        if cfg.use_fused_kernels:
            # single gather straight into minibatch layout — no
            # shuffle-then-reshape copy chain through XLA
            idx = perm.reshape((cfg.num_minibatches, mb_size))
            mb = jax.tree.map(lambda x: jnp.take(x, idx, axis=0), flat)
        else:
            shuf = jax.tree.map(lambda x: x[perm], flat)
            mb = jax.tree.map(
                lambda x: x.reshape((cfg.num_minibatches, mb_size)
                                    + x.shape[1:]), shuf)

        def minibatch(carry, batch):
            params, opt_state = carry
            (loss, aux), grads = jax.value_and_grad(
                ppo_loss, has_aux=True)(params, batch, cfg.clip_eps,
                                        cfg.vf_coef, cfg.ent_coef, policy_fn,
                                        not cfg.use_fused_kernels)
            if grad_sync_fn is not None:
                grads = grad_sync_fn(grads)
            params, opt_state = adam_update(
                grads, opt_state, params, lr=cfg.lr, beta1=0.9, beta2=0.999,
                grad_clip=cfg.max_grad_norm)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(minibatch,
                                                   (params, opt_state), mb)
        return (params, opt_state, key), losses.mean()

    (params, opt_state, key), losses = jax.lax.scan(
        epoch, (params, opt_state, key), None, length=cfg.num_epochs)

    metrics = {
        "loss": losses.mean(),
        "reward_mean": traj.rewards.mean(),
        "reward_sum": traj.rewards.sum(0).mean(),
        "episode_done_frac": traj.dones.mean(),
        "steps": jnp.float32(T * N),
    }
    return params, opt_state, env_state, obs, key, metrics


def make_train_step(env, cfg: PPOConfig, grad_sync_fn=None,
                    policy_fn=policy_apply):
    """jit-compiled PPO iteration bound to an env instance.

    ``grad_sync_fn`` may be a closure or a ``repro.comm.Communicator`` —
    resolved once here so the jitted step holds a stable callable."""
    from repro.comm.api import as_grad_sync   # lazy: rl <-> comm layering
    grad_sync_fn = as_grad_sync(grad_sync_fn)

    # donate only the env state: params may be SHARED between GMI instances
    # right after a global policy sync (donating would delete the shared
    # buffer under the other instances)
    @functools.partial(jax.jit, donate_argnums=(2,))
    def step(params, opt_state, env_state, obs, key):
        return train_iteration(params, opt_state, env, env_state, obs, key,
                               cfg, grad_sync_fn, policy_fn)

    return step


def init_train(key, env, policy_dims, num_envs: int):
    from repro.models.policy import init_policy
    kp, ke = jax.random.split(key)
    params = init_policy(kp, policy_dims)
    opt_state = adam_init(params)
    env_state, obs = env.reset(ke, num_envs)
    return params, opt_state, env_state, obs
