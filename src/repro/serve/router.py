"""Multi-GMI serving front (paper §3–§4: resource-adjustable GMIs hosting
inference workloads).

Each serving GMI runs its own :class:`~repro.serve.engine.ServeEngine`
(on a ``GMIManager.submesh`` — the MIG-style isolation boundary — when a
mesh is attached); the :class:`RequestRouter` is the admission/queueing
layer in front: requests route to the least-loaded engine by queue depth,
and per-GMI p50/p95 latency and tok/s accumulate in each engine's
telemetry.  The control plane is single-arbiter: epoch snapshots
(``take_epoch``) feed the ONE ``OnlineGMIController`` instance — normally
driven from the overlapped ``AsyncRunner`` round loop — and its decisions
come back through :meth:`RequestRouter.apply_decision`, a thin apply hook
guarded against stale (pre-re-plan) and double-applied decisions.
:meth:`RequestRouter.maybe_replan` is the standalone observe-then-apply
wrapper for serving-only deployments without a runner.  The
disaggregated front (:mod:`repro.serve.disagg`) wraps this router for
the decode side and adds prefill specialists under the same arbiter.

:class:`ServingRole` is the concrete ``DRLRole`` for serving (paper
Listing 1): ``gmi_run(requests)`` executes the engine's request loop
inside the instance's resource slice — the GMI programming model's
serving instance.
"""
from __future__ import annotations

import inspect
import time
import warnings
from typing import Callable, Dict, List, Optional

from repro.core.gmi import DRLRole, GMIManager
from repro.serve.engine import Completion, Request, ServeEngine
from repro.serve.telemetry import ServingLoad, merge_loads


def _engine_load(engine, take: bool = False) -> ServingLoad:
    """One engine's epoch with its memory/page counters attached (paged
    counters are 0/0 for dense engines — getattr keeps duck-typed engine
    substitutes working)."""
    fn = engine.telemetry.take_epoch if take else engine.telemetry.snapshot
    return fn(engine.cache_bytes, getattr(engine, "free_pages", 0),
              getattr(engine, "total_pages", 0))


class RequestRouter:
    """Admission/queueing front over N serving engines.

    ``engine_factory(index) -> ServeEngine`` lets the router scale the
    worker set at runtime (:meth:`scale_to`, usually driven by a
    controller :class:`~repro.core.controller.Decision`); a factory that
    also accepts a ``slots`` keyword lets the controller's decode-slot
    ladder decisions re-shape the engines (:meth:`resize_slots`).
    Constructing with a plain engine list disables scaling up beyond
    that list unless a factory is supplied too."""

    def __init__(self, engines: Optional[List[ServeEngine]] = None, *,
                 engine_factory: Optional[
                     Callable[[int], ServeEngine]] = None,
                 num_engines: Optional[int] = None):
        if engines is None and engine_factory is None:
            raise ValueError("need engines or an engine_factory")
        self._factory = engine_factory
        self._factory_takes_slots = False
        if engine_factory is not None:
            try:
                params = inspect.signature(engine_factory).parameters
                self._factory_takes_slots = "slots" in params or any(
                    p.kind == p.VAR_KEYWORD for p in params.values())
            except (TypeError, ValueError):
                pass
        self._slots: Optional[int] = None
        self._spawned = 0
        self.engines: List[ServeEngine] = list(engines or [])
        self._spawned = len(self.engines)
        if num_engines is not None:
            self.scale_to(num_engines)
        self.completions: List[Completion] = []
        # telemetry of workers retired mid-epoch: their drained tokens /
        # latencies must still reach the next take_epoch, or a scale-down
        # makes the system look idler than it was
        self._retired_loads: List[ServingLoad] = []
        self._seen_rids: set = set()
        # per-rid restart counts for requests whose engine died mid-decode
        self._retries: Dict[int, int] = {}
        self.failed_engines = 0
        # double-replan guard: the last decision object applied (a
        # decision applies at most once) — see apply_decision
        self._last_applied = None
        self.stale_decisions = 0

    # -------------------------------------------------------------- routing --
    @property
    def num_engines(self) -> int:
        return len(self.engines)

    @property
    def queue_len(self) -> int:
        return sum(e.queue_len for e in self.engines)

    @property
    def busy(self) -> bool:
        return any(e.busy for e in self.engines)

    def submit(self, req: Request) -> int:
        """Route by queue depth: the engine with the least outstanding
        work (queued + in decode slots) admits the request; ties break to
        the lowest index for determinism.  A rid this router has already
        accepted is rejected — double-submitting would double-count the
        request everywhere downstream (internal restarts after an engine
        failure go through ``_resubmit``, which bypasses this check)."""
        if not self.engines:
            raise RuntimeError("router has no engines (scaled to zero?)")
        if req.rid in self._seen_rids:
            raise ValueError(f"request {req.rid} already submitted to "
                             "this router (duplicate rid)")
        # min() is stable: ties go to the lowest-index engine
        eng = min(self.engines, key=lambda e: e.load)
        rid = eng.submit(req)
        self._seen_rids.add(rid)
        return rid

    def step(self) -> List[Completion]:
        """Advance every busy engine one decode step."""
        done: List[Completion] = []
        for e in self.engines:
            if e.busy:
                done.extend(e.step())
        self.completions.extend(done)
        return done

    def drain(self) -> List[Completion]:
        """Step until every engine is idle."""
        done: List[Completion] = []
        while self.busy:
            done.extend(self.step())
        self.completions.extend(done)
        return done

    # ------------------------------------------------------------ telemetry --
    @property
    def total_slots(self) -> int:
        """Live decode-slot capacity across the current engine set."""
        return sum(e.max_slots for e in self.engines)

    def snapshot(self) -> ServingLoad:
        """Aggregate the engines' current epochs (no reset)."""
        return merge_loads([_engine_load(e) for e in self.engines]
                           + self._retired_loads,
                           live_slots=self.total_slots)

    def take_epoch(self) -> ServingLoad:
        """Aggregate AND reset every engine's telemetry epoch — the
        router-level load the controller consumes.  Includes the final
        epochs of workers retired since the last call (their tokens and
        latencies count; the reported slot capacity is the LIVE engine
        set's, so a resize epoch never shows phantom slots)."""
        retired, self._retired_loads = self._retired_loads, []
        return merge_loads([_engine_load(e, take=True)
                            for e in self.engines] + retired,
                           live_slots=self.total_slots)

    def per_gmi_stats(self) -> List[ServingLoad]:
        """Per-engine epoch snapshots (p50/p95 + tok/s per GMI)."""
        return [_engine_load(e) for e in self.engines]

    # -------------------------------------------------------------- scaling --
    def _spawn(self, index: int) -> ServeEngine:
        if self._slots is not None and self._factory_takes_slots:
            return self._factory(index, slots=self._slots)
        return self._factory(index)

    def _retire(self, engine: ServeEngine) -> List[Request]:
        """Drain an engine being removed: in-flight slots finish, queued
        requests come back (with their original submit timestamps), and
        its final telemetry epoch is preserved for the next take_epoch."""
        pending = engine.take_queue()
        stamps = {r.rid: engine.telemetry.submit_time(r.rid, None)
                  for r in pending}
        self.completions.extend(engine.run_until_idle(admit=False))
        self._retired_loads.append(_engine_load(engine, take=True))
        for req in pending:
            req._submit_t = stamps.get(req.rid)
        return pending

    def _resubmit(self, req: Request):
        eng = min(self.engines, key=lambda e: e.load)
        t0 = getattr(req, "_submit_t", None)
        if t0 is not None:
            # keep the original arrival: on_submit setdefaults, so the
            # survivor's own submit() stamp cannot shorten the latency
            eng.telemetry.on_submit(req.rid, t0)
        eng.submit(req)

    def fail_engine(self, engine: ServeEngine,
                    max_retries: int = 2) -> List[Completion]:
        """Remove a DEAD engine and recover its requests — the lossless
        half of serving-GMI failure handling.

        Unlike :meth:`_retire` there is no drain: the engine's decode
        state is gone.  Its queued requests re-route to the survivors
        with their original submit clocks (``_resubmit``); its in-flight
        requests restart from scratch on a survivor, at most
        ``max_retries`` times each — past that they complete with status
        ``"failed"`` rather than bouncing between dying engines forever.
        Deadlines keep running through all of it: an expired restart
        times out at the survivor's admission.  The dead engine's final
        telemetry epoch is preserved for the next ``take_epoch``.
        Returns the completions produced (retry-exhausted failures)."""
        if engine not in self.engines:
            return []
        self.engines.remove(engine)
        self.failed_engines += 1
        queued = engine.take_queue()
        inflight = engine.take_inflight()
        prefilled = engine.take_prefilled() \
            if hasattr(engine, "take_prefilled") else []
        stamps = {r.rid: engine.telemetry.submit_time(r.rid, None)
                  for r in queued + inflight}
        self._retired_loads.append(_engine_load(engine, take=True))
        if not self.engines:
            raise RuntimeError(
                "last serving engine died; no survivors to fail over to")
        # not-yet-spliced migrated payloads are engine-independent: a
        # survivor splices them as-is, generation progress intact
        for pl in prefilled:
            min(self.engines, key=lambda e: e.load).submit_prefilled(pl)
        done: List[Completion] = []
        inflight_rids = {r.rid for r in inflight}
        for req in queued + inflight:
            req._submit_t = stamps.get(req.rid)
            if req.rid in inflight_rids:
                tries = self._retries.get(req.rid, 0)
                if tries >= max_retries:
                    now = time.perf_counter()
                    t0 = req._submit_t if req._submit_t is not None else now
                    done.append(Completion(
                        request=req, tokens=[],
                        prompt_tokens=len(req.tokens),
                        latency_s=now - t0, status="failed"))
                    continue
                self._retries[req.rid] = tries + 1
            self._resubmit(req)
        self.completions.extend(done)
        return done

    def scale_to(self, n: int) -> int:
        """Grow or shrink the worker set to ``n`` engines.

        Growing spawns via the factory.  Shrinking retires the
        highest-index workers: their not-yet-admitted requests re-route to
        the survivors (latency clocks intact) and their in-flight slots
        run to completion first — no request is lost or truncated."""
        n = max(int(n), 1)
        while len(self.engines) < n:
            if self._factory is None:
                # surface the shortfall loudly — a silent break here left
                # callers believing they scaled up when nothing happened
                warnings.warn(
                    f"scale_to({n}): router has no engine_factory; "
                    f"staying at {len(self.engines)} engine(s)",
                    RuntimeWarning, stacklevel=2)
                break
            self.engines.append(self._spawn(self._spawned))
            self._spawned += 1
        while len(self.engines) > n:
            for req in self._retire(self.engines.pop()):
                self._resubmit(req)
        return len(self.engines)

    def resize_slots(self, slots: int) -> bool:
        """Rebuild every engine with a new decode-slot width (the
        controller's slot-ladder decisions).  Lossless like scale-down:
        in-flight requests finish on the old engines, queued ones carry
        over.  Returns False when the factory cannot build resized
        engines."""
        if self._factory is None or not self._factory_takes_slots:
            return False
        current = self._slots or (self.engines[0].max_slots
                                  if self.engines else None)
        if int(slots) == current:
            return False
        old, self.engines = self.engines, []
        pending: List[Request] = []
        for e in old:
            pending.extend(self._retire(e))
        self._slots = int(slots)
        self.engines = [self._spawn(i) for i in range(len(old))]
        self._spawned = max(self._spawned, len(old))
        for req in pending:
            self._resubmit(req)
        return True

    # ------------------------------------------------------------ controller --
    def apply_decision(self, decision, *, controller=None,
                       engines_per_gpu: Optional[int] = None) -> bool:
        """Apply an already-made controller serving decision: scale the
        worker set to ``serving_gpus * engines_per_gpu`` engines and/or
        rebuild them at the decided slot width.  This is the router's
        ONLY mutation hook on the control plane — the decision itself is
        Algorithm 2's, made wherever the single controller instance runs
        (normally the overlapped ``AsyncRunner`` round loop).

        Two guards close the double-replan hazard:

        * **staleness** — a decision captured before an ``AsyncRunner``
          re-plan drained carries the pre-drain ``seq``; the re-plan
          bumps ``controller.plan_seq``, so such a decision is refused
          (and the controller's committed split reconciled to the real
          fleet) instead of applying a split computed against a layout
          that no longer exists;
        * **single application** — a decision object applies at most
          once, so the runner-driven path and a direct
          :meth:`maybe_replan` caller can never both act on one epoch.

        Returns True when the worker set changed."""
        if decision is None or not decision.layout_changed:
            return False
        if engines_per_gpu is None:
            engines_per_gpu = max(int(getattr(controller,
                                              "gmi_per_gpu", 1)), 1)
        achieved = max(self.num_engines // engines_per_gpu, 1)
        if controller is not None:
            seq = getattr(decision, "seq", None)
            plan_seq = getattr(controller, "plan_seq", None)
            if None not in (seq, plan_seq) and seq != plan_seq:
                self.stale_decisions += 1
                if achieved != controller.serving_gpus:
                    controller.serving_gpus = achieved
                return False
            if decision is self._last_applied:
                return False
            self._last_applied = decision
        changed = False
        if decision.slots:
            changed = self.resize_slots(decision.slots) or changed
        before = self.num_engines
        self.scale_to(decision.serving_gpus * engines_per_gpu)
        # reconcile: a router that COULD not follow (no factory, fixed
        # engine list) must not let the controller's committed split
        # drift from the real fleet — its telemetry divisor would shrink
        # per-instance throughput a little more every unapplied epoch
        if controller is not None:
            achieved = max(self.num_engines // engines_per_gpu, 1)
            if achieved != controller.serving_gpus:
                controller.serving_gpus = achieved
        return changed or self.num_engines != before

    def maybe_replan(self, controller, *,
                     engines_per_gpu: Optional[int] = None) -> bool:
        """Fold one telemetry epoch into the controller's serving loop and
        apply whatever Algorithm 2 answers — a thin
        observe-then-:meth:`apply_decision` wrapper kept for standalone
        serving (no runner).  ``engines_per_gpu`` defaults to the
        controller's ``gmi_per_gpu`` so the engine count matches the
        instance count the controller divides telemetry by — a mismatch
        would mis-key its measured slot table.  Returns True when the
        worker set changed."""
        decision = controller.observe_serving(self.take_epoch())
        return self.apply_decision(decision, controller=controller,
                                   engines_per_gpu=engines_per_gpu)


class ServingRole(DRLRole):
    """Paper Listing 1's serving instance, made concrete: a GMI whose
    execution routine is the continuous-batching engine loop.

    Registers the GMI with the manager, carves its resource slice, and —
    under the ``submesh`` backend — builds the engine inside the
    instance's dedicated mesh so its compiled programs cannot touch
    another instance's devices."""

    def __init__(self, manager: GMIManager, gmi_id: int, gpu_id: int,
                 cfg, params, *, resource_fraction: float = 1.0,
                 max_slots: int = 4, max_seq: int = 128,
                 window_override: Optional[int] = None):
        super().__init__(manager, gmi_id, "serving", gpu_id,
                         resource_fraction)
        mesh = manager.submesh(gmi_id) \
            if manager.backend == "submesh" else None
        self.engine = ServeEngine(cfg, params, max_slots=max_slots,
                                  max_seq=max_seq,
                                  window_override=window_override,
                                  mesh=mesh, name=f"gmi{gmi_id}")

    def gmi_run(self, requests: List[Request]) -> List[Completion]:
        """The GMI's execution routine: serve a batch of requests to
        completion inside this instance's slice."""
        return self.engine.serve(requests)
