"""Serving telemetry — the measurement side of adaptive GMI management.

arXiv:2012.04210's argument (already driving the rollout controller) is
that the serving:training split must follow *measured* load — which
requires serving to produce telemetry in the first place.  This module is
that producer: every :class:`~repro.serve.engine.ServeEngine` owns a
:class:`ServingTelemetry`, records each admission, decode step, and
completion into it, and the router / controller consume epoch snapshots
(:class:`ServingLoad`) the same way the rollout loop consumes
``RoundSample``s.

Nothing here imports the engine or the controller — the coupling is one
dataclass (:class:`ServingLoad`) that the controller duck-types.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class ServingLoad:
    """One telemetry epoch of a serving instance (or an aggregate of
    several) — the serving analogue of the controller's ``RoundSample``."""
    dt: float                   # wall seconds spanned by the epoch
    tokens: int                 # tokens generated (prefill first-token incl.)
    requests: int               # requests completed during the epoch
    queue_depth_mean: float     # mean waiting requests over decode steps
    queue_depth_max: int        # peak waiting requests
    occupancy_mean: float       # mean busy-slot fraction over decode steps
    backlog: int                # requests still waiting at epoch end with
                                # every decode slot busy (admission-starved)
    p50_s: float                # median completed-request latency (seconds)
    p95_s: float                # tail completed-request latency (seconds)
    slots: int                  # decode slots of the producing engine(s)
    prefill_s: float = 0.0      # wall seconds spent in prefill
    decode_s: float = 0.0       # wall seconds spent in decode steps
    mem_bytes: float = 0.0      # cache bytes held (memory-pressure proxy)
    # disaggregated-serving extensions (trailing defaults: ServingLoad is
    # constructed positionally in several places)
    prefill_backlog: int = 0    # requests waiting on a prefill GMI at
                                # epoch end (the prefill-pressure signal)
    migrations: int = 0         # cache payloads migrated prefill->decode
    # paged-cache extensions: free/total pages of the engine's page pool
    # (0/0 for dense engines).  Page occupancy already feeds
    # ``occupancy_mean`` indirectly — admission blocks on free pages — so
    # the controller's ladder logic needs no change; these are the raw
    # counters for benches and capacity planning.
    free_pages: int = 0
    total_pages: int = 0

    @property
    def tok_s(self) -> float:
        return self.tokens / self.dt if self.dt > 0 else 0.0


def merge_loads(loads: List[ServingLoad],
                live_slots: Optional[int] = None) -> ServingLoad:
    """Aggregate per-engine epochs into one router-level load.  Engines run
    concurrently, so ``dt`` is the max span (not the sum) while counters
    add; occupancy/queue means weight by slots.  ``live_slots`` overrides
    the reported slot capacity — when the list mixes retired engines'
    final epochs with their replacements', summing both sides would
    report phantom capacity the consumer (the controller's slot-table
    keying) would mis-divide by."""
    if not loads:
        return ServingLoad(0.0, 0, 0, 0.0, 0, 0.0, 0, 0.0, 0.0,
                           live_slots or 0)
    tot_slots = sum(l.slots for l in loads) or 1
    # percentile summaries don't compose exactly; approximate the merged
    # p50 as the request-weighted mean of engine medians and keep the
    # WORST engine tail as the merged p95 (never hides a slow engine,
    # unlike reconstructing a population — which collapses p95 to p50 for
    # engines with few completions)
    served = [l for l in loads if l.requests > 0]
    n_req = sum(l.requests for l in served)
    p50 = sum(l.p50_s * l.requests for l in served) / n_req if n_req else 0.0
    p95 = max((l.p95_s for l in served), default=0.0)
    return ServingLoad(
        dt=max(l.dt for l in loads),
        tokens=sum(l.tokens for l in loads),
        requests=sum(l.requests for l in loads),
        queue_depth_mean=sum(l.queue_depth_mean for l in loads),
        queue_depth_max=max(l.queue_depth_max for l in loads),
        occupancy_mean=sum(l.occupancy_mean * l.slots
                           for l in loads) / tot_slots,
        backlog=sum(l.backlog for l in loads),
        p50_s=p50, p95_s=p95,
        slots=live_slots if live_slots is not None else tot_slots,
        prefill_s=sum(l.prefill_s for l in loads),
        decode_s=sum(l.decode_s for l in loads),
        mem_bytes=sum(l.mem_bytes for l in loads),
        prefill_backlog=sum(l.prefill_backlog for l in loads),
        migrations=sum(l.migrations for l in loads),
        free_pages=sum(l.free_pages for l in loads),
        total_pages=sum(l.total_pages for l in loads))


class ServingTelemetry:
    """Per-engine measurement sink.

    The engine calls ``on_submit`` / ``on_admit`` / ``on_step`` /
    ``on_finish``; :meth:`take_epoch` folds everything since the last call
    into one :class:`ServingLoad` and resets the epoch counters (cumulative
    totals survive — the CLI summaries read those)."""

    def __init__(self, slots: int, clock=time.perf_counter):
        self.slots = int(slots)
        self.clock = clock
        # epoch-scoped
        self._steps: List[Tuple[float, int, int]] = []   # (dt, active, queued)
        self._latencies: List[float] = []
        self._epoch_tokens = 0
        self._epoch_requests = 0
        self._epoch_prefill_s = 0.0
        self._epoch_decode_s = 0.0
        self._epoch_start: Optional[float] = None
        self._epoch_last: Optional[float] = None
        self._end_active = 0
        self._end_queued = 0
        # request-lifetime
        self._submit_t: Dict[int, float] = {}
        # cumulative
        self.total_tokens = 0
        self.total_prompt_tokens = 0
        self.total_requests = 0
        self.total_prefill_s = 0.0
        self.total_decode_s = 0.0
        self.total_decode_steps = 0

    # ------------------------------------------------------------- events --
    def _mark(self, t: float):
        if self._epoch_start is None:
            self._epoch_start = t
        self._epoch_last = t

    def on_submit(self, rid: int, t: Optional[float] = None):
        # an explicit t only backdates the LATENCY clock (re-routed
        # requests keep their original arrival); epoch span markers always
        # move with the wall clock, or a re-route just after an epoch
        # reset would rewind the epoch start and inflate its dt
        now = self.clock()
        self._submit_t.setdefault(rid, now if t is None else t)
        self._mark(now)

    def on_admit(self, rid: int, prompt_tokens: int, prefill_s: float,
                 t: Optional[float] = None):
        t = self.clock() if t is None else t
        self._submit_t.setdefault(rid, t - prefill_s)
        self._epoch_prefill_s += prefill_s
        self._epoch_tokens += 1          # prefill emits the first token
        self.total_prefill_s += prefill_s
        self.total_prompt_tokens += prompt_tokens
        self.total_tokens += 1
        self._mark(t)

    def on_step(self, dt: float, active: int, queued: int, tokens_out: int,
                t: Optional[float] = None):
        t = self.clock() if t is None else t
        self._steps.append((dt, active, queued))
        self._epoch_decode_s += dt
        self._epoch_tokens += tokens_out
        self._end_active, self._end_queued = active, queued
        self.total_decode_s += dt
        self.total_decode_steps += 1
        self.total_tokens += tokens_out
        self._mark(t)

    def on_finish(self, rid: int, t: Optional[float] = None):
        t = self.clock() if t is None else t
        t0 = self._submit_t.pop(rid, None)
        if t0 is not None:
            self._latencies.append(t - t0)
        self._epoch_requests += 1
        self.total_requests += 1
        self._mark(t)

    def submit_time(self, rid: int, default: float = 0.0) -> float:
        return self._submit_t.get(rid, default)

    # -------------------------------------------------------------- epoch --
    def percentiles(self) -> Tuple[float, float]:
        """(p50, p95) completed-request latency of the current epoch."""
        if not self._latencies:
            return 0.0, 0.0
        arr = np.asarray(self._latencies)
        return float(np.percentile(arr, 50)), float(np.percentile(arr, 95))

    def snapshot(self, mem_bytes: float = 0.0, free_pages: int = 0,
                 total_pages: int = 0) -> ServingLoad:
        """The current epoch as a :class:`ServingLoad` (no reset)."""
        p50, p95 = self.percentiles()
        if self._steps:
            q_mean = sum(q for _, _, q in self._steps) / len(self._steps)
            q_max = max(q for _, _, q in self._steps)
            occ = sum(a for _, a, _ in self._steps) / (
                len(self._steps) * max(self.slots, 1))
        else:
            q_mean, q_max, occ = 0.0, 0, 0.0
        span = 0.0
        if self._epoch_start is not None and self._epoch_last is not None:
            span = self._epoch_last - self._epoch_start
        dt = max(span, self._epoch_prefill_s + self._epoch_decode_s)
        backlog = self._end_queued if self._end_active >= self.slots else 0
        return ServingLoad(
            dt=dt, tokens=self._epoch_tokens, requests=self._epoch_requests,
            queue_depth_mean=q_mean, queue_depth_max=int(q_max),
            occupancy_mean=occ, backlog=int(backlog),
            p50_s=p50, p95_s=p95, slots=self.slots,
            prefill_s=self._epoch_prefill_s, decode_s=self._epoch_decode_s,
            mem_bytes=mem_bytes, free_pages=int(free_pages),
            total_pages=int(total_pages))

    def take_epoch(self, mem_bytes: float = 0.0, free_pages: int = 0,
                   total_pages: int = 0) -> ServingLoad:
        """Snapshot the epoch and reset its counters (cumulative totals and
        in-flight submit timestamps survive)."""
        load = self.snapshot(mem_bytes, free_pages, total_pages)
        self._steps = []
        self._latencies = []
        self._epoch_tokens = 0
        self._epoch_requests = 0
        self._epoch_prefill_s = 0.0
        self._epoch_decode_s = 0.0
        self._epoch_start = None
        self._epoch_last = None
        self._end_active = 0
        self._end_queued = 0
        return load
