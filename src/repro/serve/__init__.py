"""``repro.serve`` — the first-class serving subsystem (paper §3–§4).

GMI-DRL's serving half builds resource-adjustable GMIs that host inference
workloads and an adaptive management loop that resizes them under load.
This package is that half for the reproduction, mirroring how
``repro.comm`` owns the communication layer:

Paper concept → code map
------------------------
* §3 "GMI hosting an inference workload" →
  :class:`~repro.serve.engine.ServeEngine`: one model replica with a
  fixed-slot continuous-batching decode loop over the existing
  ``transformer.prefill`` / ``decode_step`` cache machinery (KV, ring,
  SSM, and hybrid caches).  Requests of different prompt lengths and
  generation budgets join and leave the decode batch without
  recompilation; greedy output is token-identical to the single-request
  oracle path (:meth:`~repro.serve.engine.ServeEngine.oracle_generate`).
* §3 MIG-style isolation (``GMIManager.submesh``) →
  :class:`~repro.serve.router.ServingRole`: the concrete ``DRLRole``
  (paper Listing 1) whose ``gmi_run`` executes the engine loop inside the
  instance's dedicated mesh slice.
* §4 request admission across instances →
  :class:`~repro.serve.router.RequestRouter`: the multi-GMI front —
  queue-depth routing, per-GMI p50/p95 latency + tok/s, lossless worker
  drain on scale-down.
* §4 adaptive GMI management (Algorithm 2 under traffic) →
  :class:`~repro.serve.telemetry.ServingTelemetry` epochs
  (:class:`~repro.serve.telemetry.ServingLoad`) fold into
  ``OnlineGMIController.observe_serving``; sustained backlog moves a GPU
  to serving, idle slots give one back, and
  :meth:`~repro.serve.router.RequestRouter.maybe_replan` applies the
  decision by scaling the engine set — the same measured-load loop that
  already rebalances serve/train for rollouts (arXiv:2012.04210).

``launch/serve.py``, ``examples/llm_policy_serving.py``,
``examples/submesh_serving.py``, and ``benchmarks/bench_serving.py`` are
thin clients of this package.
"""
from repro.serve.engine import Completion, Request, ServeEngine
from repro.serve.router import RequestRouter, ServingRole
from repro.serve.telemetry import ServingLoad, ServingTelemetry, merge_loads

__all__ = [
    "Completion", "Request", "ServeEngine",
    "RequestRouter", "ServingRole",
    "ServingLoad", "ServingTelemetry", "merge_loads",
]
