"""``repro.serve`` — the first-class serving subsystem (paper §3–§4).

GMI-DRL's serving half builds resource-adjustable GMIs that host inference
workloads and an adaptive management loop that resizes them under load.
This package is that half for the reproduction, mirroring how
``repro.comm`` owns the communication layer:

Paper concept → code map
------------------------
* §3 "GMI hosting an inference workload" →
  :class:`~repro.serve.engine.ServeEngine`: one model replica with a
  fixed-slot continuous-batching decode loop over the existing
  ``transformer.prefill`` / ``decode_step`` cache machinery (KV, ring,
  SSM, and hybrid caches).  Requests of different prompt lengths and
  generation budgets join and leave the decode batch without
  recompilation; greedy output is token-identical to the single-request
  oracle path (:meth:`~repro.serve.engine.ServeEngine.oracle_generate`).
* §3 memory-sized GMIs, applied to the cache →
  the engine's **paged cache pool** (default): attention caches live in
  a batch-free pool of fixed-size pages
  (``models.attention.PagedKVCache``) with an engine-owned per-slot
  page table, decoded through the ``kernels/paged_decode.py`` Pallas
  gather kernel (``decode_kernel=True``) or its jnp gather fallback.
  Admission reserves ``ceil((prompt+budget)/page)`` pages for the
  request's lifetime instead of a full ``max_seq`` slot, so a fixed
  cache-byte budget admits strictly more concurrent requests
  (``benchmarks/bench_serving.py::run_paged`` asserts it); same-length
  queued prompts coalesce into ONE batched prefill dispatch, long
  prompts prefill in fixed chunks interleaved with decode
  (``chunk_prefill``), and common prompt heads share read-only pages
  with copy-on-write at divergence (``share_prefix``) — the
  millions-of-users system-prompt case.  Every path stays
  token-identical to the oracle across the KV / SSM-window / hybrid /
  MoE cache families (``tests/test_serve_engine.py``).
* §3 MIG-style isolation (``GMIManager.submesh``) →
  :class:`~repro.serve.router.ServingRole`: the concrete ``DRLRole``
  (paper Listing 1) whose ``gmi_run`` executes the engine loop inside the
  instance's dedicated mesh slice.
* §4 request admission across instances →
  :class:`~repro.serve.router.RequestRouter`: the multi-GMI front —
  queue-depth routing, per-GMI p50/p95 latency + tok/s, lossless worker
  drain on scale-down.
* §4 adaptive GMI management (Algorithm 2 under traffic) →
  :class:`~repro.serve.telemetry.ServingTelemetry` epochs
  (:class:`~repro.serve.telemetry.ServingLoad`) fold into
  ``OnlineGMIController.observe_serving``.  The controller runs as ONE
  instance inside the overlapped ``AsyncRunner`` round loop, arbitrating
  trainers, rollout actors, prefill GMIs, and decode GMIs under the same
  1.05x hysteresis; the fronts' ``apply_decision`` hooks are thin
  appliers guarded against stale and double-applied decisions
  (``Decision.seq`` vs ``controller.plan_seq``).
* §4.2 coarse-grained transfer discipline, applied to serving →
  :mod:`repro.serve.disagg`: prefill/decode disaggregation across GMIs.
  Request lifecycle: submit → :class:`~repro.serve.disagg.MigrationPlanner`
  prices migrate-vs-local in Table-2 cost-model units → EITHER a
  :class:`~repro.serve.disagg.PrefillEngine` specialist prefills and
  ships the packed cache over a ``core.channels.CacheChannel`` to the
  least-loaded decode GMI (splice-only admission,
  :meth:`~repro.serve.engine.ServeEngine.submit_prefilled`) OR the
  request stays on the decode side's local B=1 prefill + splice path →
  batched decode → completion.  Decode output is token-identical either
  way, for every cache family.

``launch/serve.py`` (``--disagg``), ``examples/llm_policy_serving.py``,
``examples/submesh_serving.py``, ``benchmarks/bench_serving.py``, and
``benchmarks/bench_disagg.py`` are thin clients of this package.
"""
from repro.serve.disagg import (CachePayload, DisaggFront, MigrationPlanner,
                                PrefillEngine)
from repro.serve.engine import Completion, Request, ServeEngine
from repro.serve.router import RequestRouter, ServingRole
from repro.serve.telemetry import ServingLoad, ServingTelemetry, merge_loads

__all__ = [
    "Completion", "Request", "ServeEngine",
    "RequestRouter", "ServingRole",
    "CachePayload", "DisaggFront", "MigrationPlanner", "PrefillEngine",
    "ServingLoad", "ServingTelemetry", "merge_loads",
]
