"""Slot-based continuous-batching request engine (paper §3: one serving
GMI's execution loop).

The engine owns a fixed-slot decode batch.  Two cache regimes:

* **Paged (default).**  Attention caches live in a batch-free shared pool
  of fixed-size pages (``attention.PagedKVCache``); each decode slot owns
  a row of an engine-held page table mapping virtual page v (absolute
  positions ``[v*page_size, (v+1)*page_size)``) to a physical page.  Page
  0 is the trash page: idle rows and unmapped writes land there and stay
  masked.  Pages are reserved for a request's whole lifetime (prompt +
  budget) at admission, so decode never faults; a request that cannot get
  pages simply stays queued until a retirement frees them.  Recurrent
  (mLSTM/sLSTM/Mamba2) states are fixed-size per slot and stay batched.
* **Dense (``paged=False``).**  The pre-paging layout — each slot owns a
  monolithic ``max_seq``-deep cache row — kept as the memory baseline
  (``benchmarks/bench_serving.py`` pins paged admitting strictly more
  concurrent requests at the same cache-byte budget).

On top of pages the engine adds three prefill disciplines:

* **Batched prefill** (``batch_prefill=True``): same-length queued
  prompts admitted in the same step coalesce into ONE ``B=G`` prefill
  dispatch, then splice row-by-row into the pool.
* **Chunked prefill** (``chunk_prefill=C`` > 0): prompts longer than C
  are prefilled C tokens per engine step via ``transformer.prefill_chunk``
  (writing pages in place through the slot's table row), interleaved with
  the decode batch — a long prompt no longer stalls every in-flight
  decode for its whole prefill.  A length-1 final chunk merges into the
  previous one (C+1) so SSM states never see a 1-token apply.
* **Shared-prefix reuse** (``share_prefix=True``; attention-only,
  non-MoE, text-frontend configs): full prompt-prefix pages are promoted
  into a chain-hash index at admission; later prompts sharing the prefix
  map the same read-only physical pages and only prefill their tail.  A
  divergence *inside* a block is handled with an eager copy-on-write: the
  new request gets a private copy of the divergence page truncated to the
  common prefix, so no page ever has two writers.

Request lifecycle (disaggregated; see ``repro.serve.disagg``)::

    submit -> planner: migrate or local?
      local   -> queue -> [admit: reserve pages -> (batched|chunked|tail)
                           prefill -> first token]
      migrate -> prefill GMI (B=1 dense prefill) -> CachePayload
              -> channel ring -> submit_prefilled
              -> [admit: reserve pages -> page-wise cache splice only]
    -> decode slot (one batched decode_step per engine step)
    -> retire (budget exhausted / eos) -> pages + slot freed

Both admission paths converge on the same page pool and the same paged
decode, so a decode batch fed by a migrated cache is token-identical to
one that prefilled locally — and both to
:meth:`ServeEngine.oracle_generate`, which runs the same paged pipeline
at B=1 over its own fresh pool.

Design points:

* **No decode recompilation.**  The decode batch has a fixed slot count
  and the page table is a dynamic operand, so requests join and leave —
  and pages map and unmap — without retracing.  Prefill traces once per
  distinct (length, group) pair.
* **Idle slots cost one row of compute.**  They decode token 0 at
  position -1: the paged write masks negative positions into the trash
  page and the attention mask kills every key, so the softmax degrades
  to uniform, not NaN, and nothing real is touched.
* **Batch-composition independence.**  Greedy decoding is token-identical
  to the B=1 oracle (pinned in ``tests/test_serve_engine.py`` across
  attention, SSM, hybrid, and MoE cache families).  Sampling uses
  per-request keys (``fold_in(key(seed), position)`` vmapped per row).
  MoE routing is per batch row (``moe_apply`` routes groups = rows), so
  finite expert capacity cannot couple requests either; with
  ``cfg.moe_route_block`` set, routing is additionally invariant to
  R-aligned prefill chunking.
"""
from __future__ import annotations

import hashlib
import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.attention import PagedKVCache
from repro.serve.telemetry import ServingTelemetry

_REQUEST_IDS = itertools.count()


@dataclass
class Request:
    """One generation request.  ``tokens`` is the prompt (1-D int array);
    ``max_new_tokens`` counts every generated token, including the one the
    prefill emits.  ``extras`` carries additional prompt modalities (e.g.
    ``{"patches": (num_patches, feat)}`` for vision frontends); each entry
    gets a leading batch dim at admission.  ``deadline_s`` is a TTL from
    submit time: a request still queued past it completes with status
    ``"timeout"`` instead of occupying a decode slot."""
    tokens: Any
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos_id: Optional[int] = None
    extras: Optional[Dict[str, Any]] = None
    deadline_s: Optional[float] = None
    rid: int = field(default_factory=lambda: next(_REQUEST_IDS))

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclass
class Completion:
    """A retired request: ``tokens`` are the generated ids (prefill token
    first), ``latency_s`` is submit-to-retire wall time.  ``status`` is
    ``"ok"`` for a normal retire, ``"timeout"`` for a deadline-expired
    queued request (empty ``tokens``), ``"failed"`` for a request whose
    engine died mid-decode with retries exhausted."""
    request: Request
    tokens: List[int]
    prompt_tokens: int
    latency_s: float
    status: str = "ok"

    @property
    def rid(self) -> int:
        return self.request.rid


@dataclass
class _Slot:
    req: Request
    pos: int                     # absolute position of the token being fed
    remaining: int               # decode steps left (budget - prefill token)
    generated: List[int]
    submit_t: float
    pages: List[int] = field(default_factory=list)   # page refs to release
    # chunked-prefill state machine (prefilling while the batch decodes)
    prefilling: bool = False
    chunk_next: int = 0          # next prompt position to prefill
    prompt_total: int = 0
    hashes: Optional[list] = None
    prefill_s: float = 0.0
    t_admit: float = 0.0


class _PagePool:
    """Host-side bookkeeping for the physical page pool: a free stack and
    per-page refcounts.  Page 0 (trash) is pinned forever."""

    def __init__(self, num_pages: int):
        self.num_pages = int(num_pages)
        if self.num_pages < 2:
            raise ValueError("page pool needs >= 2 pages (trash + 1)")
        self.free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self.ref = np.zeros((self.num_pages,), np.int64)
        self.ref[0] = 1

    @property
    def free_count(self) -> int:
        return len(self.free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self.free):
            return None
        out = [self.free.pop() for _ in range(n)]
        for p in out:
            self.ref[p] = 1
        return out

    def retain(self, pid: int):
        self.ref[pid] += 1

    def release(self, pid: int) -> bool:
        self.ref[pid] -= 1
        assert self.ref[pid] >= 0, f"double free of page {pid}"
        if self.ref[pid] == 0:
            self.free.append(pid)
            return True
        return False


class _PrefixIndex:
    """Chain-hash index of promoted prompt-prefix pages.

    ``full[h]`` maps the sha1 chain hash of blocks ``0..j`` (all full) to
    the physical page holding block j.  ``nxt[h]`` maps the chain hash of
    blocks ``0..j-1`` to SOME page holding a block-j candidate (possibly
    partial) whose tokens are in ``toks[pid]`` — the copy-on-write source
    for divergence inside block j.  Every entry holds one pool ref, so
    indexed pages survive their owner's retirement (that persistence IS
    the prefix cache); :meth:`ServeEngine._alloc_pages` evicts
    index-only pages under free-list pressure."""

    def __init__(self, page_size: int):
        self.P = int(page_size)
        self.full: Dict[bytes, int] = {}
        self.nxt: Dict[bytes, int] = {}
        self.toks: Dict[int, Tuple[int, ...]] = {}
        self.keys_of: Dict[int, List[Tuple[str, bytes]]] = {}

    def hashes(self, tokens) -> List[Tuple[bytes, bytes, Tuple[int, ...]]]:
        """Per block j (incl. a trailing partial block):
        ``(chain_prev, chain_self, block_tokens)``."""
        P = self.P
        toks = np.asarray(tokens, np.int32)
        out = []
        prev = b""
        for j in range(-(-len(toks) // P)):
            blk = tuple(int(t) for t in toks[j * P:(j + 1) * P])
            h = hashlib.sha1(prev + np.asarray(blk, np.int32).tobytes())
            out.append((prev, h.digest(), blk))
            prev = h.digest()
        return out

    def entry_count(self, pid: int) -> int:
        return len(self.keys_of.get(pid, ()))

    def pages(self) -> List[int]:
        return list(self.keys_of)

    def add(self, kind: str, key: bytes, pid: int) -> bool:
        d = self.full if kind == "full" else self.nxt
        if key in d:
            return False
        d[key] = pid
        self.keys_of.setdefault(pid, []).append((kind, key))
        return True

    def drop(self, pid: int) -> int:
        """Remove every entry pointing at ``pid``; returns how many."""
        keys = self.keys_of.pop(pid, [])
        for kind, key in keys:
            (self.full if kind == "full" else self.nxt).pop(key, None)
        self.toks.pop(pid, None)
        return len(keys)


class ServeEngine:
    """Continuous-batching engine over one model replica.

    Parameters
    ----------
    cfg, params : the model (any non-encoder-only architecture).
    max_slots   : decode batch width — the fixed slot count.
    max_seq     : per-request depth; every request needs
                  ``len(prompt) + max_new_tokens <= max_seq``.
    window_override : sliding-window serving variant.
    paged       : paged cache pool (default) vs dense per-slot caches.
    page_size   : tokens per page.
    num_pages   : physical pages incl. the trash page.  Default
                  ``max_slots * ceil(max_seq/page_size) + 1`` — the
                  worst-case budget, which makes the controller's existing
                  slot ladder double as the page-budget ladder.  Smaller
                  values oversubscribe: admission then blocks on free
                  pages, not slots.
    batch_prefill : coalesce same-length queued prompts into one dispatch.
    chunk_prefill : prefill chunk size (0 = whole-prompt prefill).
    share_prefix  : reuse common prompt-head pages across requests
                  (auto-disabled for SSM/hybrid, MoE, and non-text
                  frontends, where cache content is not a pure function
                  of the token prefix or pages are not position-pure).
    decode_kernel : route paged decode reads through the Pallas
                  gather-decode kernel (``repro.kernels.paged_decode``)
                  instead of the jnp gather.
    mesh        : optional ``jax.sharding.Mesh`` (a GMI submesh) — params
                  and all per-step inputs are committed to it, so the
                  engine's compiled programs run inside the instance's
                  MIG-style isolation boundary.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_seq: int = 128, window_override: Optional[int] = None,
                 mesh=None, telemetry: Optional[ServingTelemetry] = None,
                 name: str = "engine", paged: bool = True,
                 page_size: int = 8, num_pages: Optional[int] = None,
                 batch_prefill: bool = True, chunk_prefill: int = 0,
                 share_prefix: bool = True, decode_kernel: bool = False):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name}: encoder-only model has no decode "
                             "step — nothing to serve")
        self.cfg = cfg
        self.max_slots = int(max_slots)
        self.max_seq = int(max_seq)
        self.window_override = window_override
        self.mesh = mesh
        self.name = name
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.batch_prefill = bool(batch_prefill) and self.paged
        self.chunk_prefill = int(chunk_prefill) if self.paged else 0
        if self.chunk_prefill > 0 and cfg.num_experts:
            # finite-capacity MoE routing is chunk-invariant only when
            # chunk starts land on multiples of the routing block
            if cfg.moe_route_block <= 0:
                raise ValueError(
                    "chunk_prefill with an MoE config requires "
                    "cfg.moe_route_block > 0 (block-local routing) — "
                    "otherwise chunked and whole prefill route differently")
            r = cfg.moe_route_block
            self.chunk_prefill = -(-self.chunk_prefill // r) * r
        self.decode_kernel = bool(decode_kernel) and self.paged
        self.telemetry = telemetry or ServingTelemetry(self.max_slots)
        # fault-injection seam (repro.fault): called with this engine at
        # the top of step(); raising InjectedFault there kills the engine
        # mid-decode (``dead`` flips, slots are forfeit, queue survives)
        self.fault_hook = None
        self.dead = False
        self.timeouts = 0
        self.prefix_fallbacks = 0    # migrated payloads re-queued because a
                                     # promised shared head was evicted
        self._sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            self._sharding = NamedSharding(mesh, PartitionSpec())
            params = jax.device_put(params, self._sharding)
        self.params = params

        self._queue: Deque[Request] = deque()
        # prefilled-elsewhere payloads awaiting a slot (cache splice only,
        # no local prefill compute) — admitted ahead of the raw queue
        # because their prefill cost is already sunk on another GMI
        self._prefilled: Deque[Any] = deque()
        self._slots: List[Optional[_Slot]] = [None] * self.max_slots
        dt = jnp.dtype(cfg.dtype)

        # virtual pages per slot (the page-table width)
        self.pages_per_slot = -(-self.max_seq // self.page_size)
        if self.paged:
            self.num_pages = int(num_pages) if num_pages is not None \
                else self.max_slots * self.pages_per_slot + 1
            caches = T.init_paged_cache(cfg, self.max_slots, self.max_seq,
                                        window_override, dt,
                                        page_size=self.page_size,
                                        num_pages=self.num_pages)
            self._pool = _PagePool(self.num_pages)
            self._table = np.full((self.max_slots, self.pages_per_slot), -1,
                                  np.int32)
            self._table_dev = None           # rebuilt lazily when dirty
            self._share = bool(share_prefix) and not cfg.block_pattern \
                and cfg.num_experts == 0 \
                and cfg.frontend not in ("vision", "audio")
            self._index = _PrefixIndex(self.page_size)
        else:
            self.num_pages = 0
            caches = T.init_cache(cfg, self.max_slots, self.max_seq,
                                  window_override, dt)
            self._pool = None
            self._share = False
        self._caches = self._put(caches)
        self._cache_bytes = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(caches)
            if hasattr(x, "dtype"))
        # host-side mirrors of the decode-batch inputs; idle rows feed
        # (token=0, pos=-1, temp=0) — the negative position routes their
        # paged write to the trash page — and are ignored on the way out
        self._idle_pos = -1 if self.paged else 0
        self._tok = np.zeros((self.max_slots,), np.int32)
        self._pos = np.full((self.max_slots,), self._idle_pos, np.int32)
        self._seed = np.zeros((self.max_slots,), np.int32)
        self._temp = np.zeros((self.max_slots,), np.float32)

        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, self.max_seq, window_override))
        # the cache pytree is rebound to the jit output on every call:
        # donate it so decode, splice, clear, copy, and chunk prefill all
        # update in place instead of copying the pool per token
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))
        if self.paged:
            self._insert = jax.jit(self._insert_paged_fn, donate_argnums=(0,))
            self._clear = jax.jit(self._clear_fn, donate_argnums=(0,))
            self._reset_row = jax.jit(self._reset_row_fn, donate_argnums=(0,))
            self._copy_page = jax.jit(self._copy_page_fn, donate_argnums=(0,))
            self._chunk = jax.jit(
                lambda p, tk, pos, c, slot, trow: T.prefill_chunk(
                    p, cfg, tk, pos, c, slot, trow, window_override),
                donate_argnums=(3,))
        else:
            self._insert = jax.jit(self._insert_dense_fn, donate_argnums=(0,))

    # ------------------------------------------------------- jitted bodies --
    def _decode_fn(self, params, caches, tok, pos, seed, temp, table):
        logits, caches = T.decode_step(params, self.cfg, tok, pos, caches,
                                       self.window_override, page_table=table,
                                       paged_kernel=self.decode_kernel)
        return _pick_tokens(logits, pos, seed, temp), caches

    @staticmethod
    def _insert_dense_fn(full, one, row, slot):
        # every stacked cache leaf is (layers_or_super, batch, ...): splice
        # one row of the (possibly batched) prefill cache into its slot
        return jax.tree.map(
            lambda f, o: jax.lax.dynamic_update_index_in_dim(
                f, jax.lax.dynamic_index_in_dim(o, row, 1, keepdims=False),
                slot, 1), full, one)

    def _insert_paged_fn(self, full, one, row, slot, table_row):
        """Splice row ``row`` of a DENSE prefill cache tree into the paged
        pool through ``table_row`` (M,) — a scatter by each entry's
        absolute ``slot_pos``, so it is length-agnostic: whole prefills,
        ring-truncated windows, and page-truncated migration payloads all
        land at their true positions (invalid/unmapped entries fall into
        the trash page).  Non-paged (recurrent-state) leaves splice into
        batch row ``slot`` as in the dense engine."""
        P = self.page_size

        def splice(f, o):
            if isinstance(f, PagedKVCache):
                k = jax.lax.dynamic_index_in_dim(o.k, row, 1, keepdims=False)
                v = jax.lax.dynamic_index_in_dim(o.v, row, 1, keepdims=False)
                sp = jax.lax.dynamic_index_in_dim(o.slot_pos, row, 1,
                                                  keepdims=False)   # (L, S)
                ok = sp >= 0
                safe = jnp.where(ok, sp, 0)
                vp = jnp.clip(safe // P, 0, table_row.shape[0] - 1)
                phys = table_row[vp]
                ok &= phys >= 0
                phys = jnp.where(ok, phys, 0)
                off = safe % P
                lidx = jnp.arange(sp.shape[0])[:, None]
                return PagedKVCache(
                    f.k_pages.at[lidx, phys, off].set(
                        k.astype(f.k_pages.dtype)),
                    f.v_pages.at[lidx, phys, off].set(
                        v.astype(f.v_pages.dtype)),
                    f.slot_pos.at[lidx, phys, off].set(
                        jnp.where(ok, sp, -1)))
            return jax.lax.dynamic_update_index_in_dim(
                f, jax.lax.dynamic_index_in_dim(o, row, 1, keepdims=False),
                slot, 1)

        return jax.tree.map(splice, full, one,
                            is_leaf=lambda n: isinstance(n, PagedKVCache))

    @staticmethod
    def _reset_row_fn(full, slot):
        """Zero one batch row of every NON-paged (recurrent-state) leaf.
        Whole-prefill admissions overwrite the row by splice, but chunked
        prefill CONTINUES from the slot's current recurrent state — which,
        on a reused slot, is the previous occupant's final state.  Every
        recurrent init state is all-zeros, so zeroing the row restores a
        fresh one."""
        def z(f):
            if isinstance(f, PagedKVCache):
                return f
            return f.at[:, slot].set(jnp.zeros((), f.dtype))
        return jax.tree.map(z, full,
                            is_leaf=lambda n: isinstance(n, PagedKVCache))

    @staticmethod
    def _clear_fn(full, pids):
        """Invalidate the given physical pages (``slot_pos = -1``) in every
        paged node.  ``pids`` is fixed-width, padded with 0 — re-clearing
        the trash page is a no-op, so one trace serves every request."""
        def clear(f):
            if isinstance(f, PagedKVCache):
                return f._replace(slot_pos=f.slot_pos.at[:, pids].set(-1))
            return f
        return jax.tree.map(clear, full,
                            is_leaf=lambda n: isinstance(n, PagedKVCache))

    @staticmethod
    def _copy_page_fn(full, src, dst, keep_below):
        """Copy-on-write: duplicate physical page ``src`` into ``dst`` in
        every paged node, keeping only entries with absolute position
        ``< keep_below`` valid (the shared prefix inside the divergence
        block; the source's tail — including any decode positions its
        owner wrote since promotion — is dropped)."""
        def cp(f):
            if isinstance(f, PagedKVCache):
                sp = f.slot_pos[:, src]
                sp = jnp.where((sp >= 0) & (sp < keep_below), sp, -1)
                return PagedKVCache(
                    f.k_pages.at[:, dst].set(f.k_pages[:, src]),
                    f.v_pages.at[:, dst].set(f.v_pages[:, src]),
                    f.slot_pos.at[:, dst].set(sp))
            return f
        return jax.tree.map(cp, full,
                            is_leaf=lambda n: isinstance(n, PagedKVCache))

    def _put(self, tree):
        if self._sharding is None:
            return tree
        return jax.device_put(tree, self._sharding)

    # ------------------------------------------------------------- queries --
    @property
    def active_count(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    @property
    def free_slots(self) -> int:
        return self.max_slots - self.active_count

    @property
    def queue_len(self) -> int:
        return len(self._queue) + len(self._prefilled)

    @property
    def load(self) -> int:
        """Outstanding work — the router's queue-depth routing key."""
        return self.active_count + self.queue_len

    @property
    def busy(self) -> bool:
        return self.load > 0

    @property
    def cache_bytes(self) -> int:
        return self._cache_bytes

    @property
    def free_pages(self) -> int:
        return self._pool.free_count if self.paged else 0

    @property
    def total_pages(self) -> int:
        """Usable (non-trash) physical pages."""
        return self.num_pages - 1 if self.paged else 0

    def _pages_needed(self, prompt_total: int, max_new: int) -> int:
        # cache writes span positions [0, prompt_total + max_new - 1): the
        # final generated token is emitted but never written back
        return -(-(prompt_total + max_new - 1) // self.page_size)

    # ----------------------------------------------------------- lifecycle --
    def submit(self, req: Request) -> int:
        """Queue a request; returns its id.  Admission happens at the next
        :meth:`step` when a slot (and, paged, enough pages) frees up."""
        total = len(req.tokens) + self._extra_tokens(req) + req.max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+budget {total} exceeds engine "
                f"max_seq {self.max_seq}")
        if self.paged:
            need = self._pages_needed(
                len(req.tokens) + self._extra_tokens(req), req.max_new_tokens)
            if need > self.total_pages:
                raise ValueError(
                    f"request {req.rid}: needs {need} pages, engine pool has "
                    f"{self.total_pages} — can never be admitted")
        self.telemetry.on_submit(req.rid)
        self._queue.append(req)
        return req.rid

    def submit_prefilled(self, payload) -> int:
        """Queue a prefilled-elsewhere cache payload (duck-typed: ``req``,
        ``cache``, ``first_id``, ``prompt_tokens``, ``submit_t``, optional
        ``head_pages``) for splice-only admission — the decode half of
        prefill/decode disaggregation.  The cache must come from the same
        model family (cfg/params/max_seq/window) for the splice to be
        well-formed.  ``head_pages`` > 0 promises the first ``head_pages``
        full prompt blocks are in this engine's shared-prefix index (the
        sender stripped them from the payload); if the promise no longer
        holds at admission the request re-queues for a full local prefill
        instead — lossless, just slower."""
        req = payload.req
        total = payload.prompt_tokens + req.max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+budget {total} exceeds engine "
                f"max_seq {self.max_seq}")
        self.telemetry.on_submit(req.rid,
                                 t=getattr(payload, "submit_t", None))
        self._prefilled.append(payload)
        return req.rid

    def take_prefilled(self) -> List[Any]:
        """Remove and return the not-yet-spliced prefilled payloads (they
        are engine-independent — a survivor can splice them as-is)."""
        out = list(self._prefilled)
        self._prefilled.clear()
        return out

    def _extra_tokens(self, req: Request) -> int:
        if self.cfg.frontend == "vision" and req.extras \
                and "patches" in req.extras:
            return int(req.extras["patches"].shape[0])
        return 0

    # --------------------------------------------------------- page plumbing --
    def shared_head_pages(self, tokens) -> int:
        """How many leading FULL prompt blocks of ``tokens`` are currently
        in this engine's shared-prefix index (a migration sender may strip
        exactly that many pages from its payload)."""
        if not self._share:
            return 0
        n = 0
        for _, h_self, blk in self._index.hashes(tokens):
            if len(blk) < self.page_size or h_self not in self._index.full:
                break
            n += 1
        return n

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """Allocate from the free list, evicting index-only shared pages
        (no live slot references them) under pressure."""
        if n <= self._pool.free_count:
            return self._pool.alloc(n)
        for pid in self._index.pages() if self._share else []:
            if self._pool.ref[pid] == self._index.entry_count(pid):
                for _ in range(self._index.drop(pid)):
                    self._pool.release(pid)
                if self._pool.free_count >= n:
                    break
        return self._pool.alloc(n)

    def _table_row_dev(self, slot: int):
        return jnp.asarray(self._table[slot])

    def _table_device(self):
        if self._table_dev is None:
            self._table_dev = self._put(jnp.asarray(self._table))
        return self._table_dev

    def _set_table_row(self, slot: int, row: List[int]):
        self._table[slot, :] = -1
        self._table[slot, :len(row)] = row
        self._table_dev = None

    def _release_slot_pages(self, st: _Slot):
        for pid in st.pages:
            self._pool.release(pid)
        st.pages = []

    def _plan_pages(self, req: Request):
        """Reserve the request's lifetime pages, resolving shared-prefix
        reuse and performing the (at most one) copy-on-write.  Returns
        ``(row, p0, hashes)`` — the slot's page-table row, the first
        prompt position that still needs local prefill, and the chain
        hashes for post-prefill promotion — or None if the pool cannot
        cover it right now."""
        P = self.page_size
        extra = self._extra_tokens(req)
        Lp = len(req.tokens) + extra
        need = self._pages_needed(Lp, req.max_new_tokens)
        shared_pids: List[int] = []
        cand = None
        lcp = 0
        hashes = None
        if self._share and not req.extras:
            hashes = self._index.hashes(req.tokens)
            for h_prev, h_self, blk in hashes:
                pid = self._index.full.get(h_self)
                if pid is None or len(blk) < P:
                    break
                shared_pids.append(pid)
            s = len(shared_pids)
            if s < len(hashes):
                h_prev, _, blk = hashes[s]
                c = self._index.nxt.get(h_prev)
                if c is not None:
                    ctoks = self._index.toks.get(c, ())
                    while lcp < min(len(blk), len(ctoks)) \
                            and blk[lcp] == ctoks[lcp]:
                        lcp += 1
                    cand = c if lcp > 0 else None
        # pin resolved pages so eviction inside _alloc_pages can't free them
        for pid in shared_pids:
            self._pool.retain(pid)
        if cand is not None:
            self._pool.retain(cand)
        s = len(shared_pids)
        cov = s * P + lcp
        p0 = min(cov, Lp - 1)
        d0 = p0 // P
        use_shared = min(d0, s)
        priv = self._alloc_pages(need - use_shared)
        if priv is None:
            for pid in shared_pids:
                self._pool.release(pid)
            if cand is not None:
                self._pool.release(cand)
            return None
        row = shared_pids[:use_shared] + priv
        self._caches = self._clear(
            self._caches,
            np.pad(np.asarray(priv, np.int32),
                   (0, self.pages_per_slot - len(priv))))
        cow_src = None
        if d0 < s:
            cow_src = shared_pids[d0]     # whole prompt inside shared blocks
        elif lcp > 0:
            cow_src = cand                # divergence inside block d0
        if cow_src is not None:
            self._caches = self._copy_page(
                self._caches, np.int32(cow_src), np.int32(row[d0]),
                np.int32(p0))
        # drop the pins we are not keeping in the row
        for pid in shared_pids[use_shared:]:
            self._pool.release(pid)
        if cand is not None:
            self._pool.release(cand)
        return row, p0, hashes

    def _promote(self, st: _Slot) -> None:
        """Publish the slot's prompt-prefix pages into the shared index:
        full blocks become exact-match (``full``) and divergence-source
        (``nxt``) candidates; a trailing partial block becomes a ``nxt``
        candidate only.  Decode never writes into a full prompt block, and
        copy-on-write truncates below the divergence point, so published
        pages are safe even while their owner keeps decoding into the
        trailing one.  Runs after prefill completes and BEFORE any
        immediate retirement, so even a budget-1 request seeds the cache."""
        if st.hashes is None or not self._share:
            return
        nfull = len(st.req.tokens) // self.page_size
        for j, (h_prev, h_self, blk) in enumerate(st.hashes):
            pid = int(st.pages[j]) if j < len(st.pages) else -1
            if pid <= 0:
                continue
            if j < nfull and self._index.add("full", h_self, pid):
                self._pool.retain(pid)
                self._index.toks.setdefault(pid, blk)
            if self._index.add("nxt", h_prev, pid):
                self._pool.retain(pid)
                self._index.toks.setdefault(pid, blk)

    # ----------------------------------------------------------- admission --
    def _finish(self, st: _Slot, slot: Optional[int] = None) -> Completion:
        t = time.perf_counter()
        self.telemetry.on_finish(st.req.rid, t)
        if self.paged:
            self._release_slot_pages(st)
            if slot is not None:
                # unmap the retired row NOW: a released page re-allocated
                # to another slot must never appear mapped in two rows
                # (the stale row is decode-masked via pos = -1, but the
                # invariant "mapped => live reference" keeps the table
                # auditable)
                self._set_table_row(slot, [])
        # pos always trails the generated count by prompt_tokens - 1
        return Completion(request=st.req, tokens=st.generated,
                          prompt_tokens=st.pos - len(st.generated) + 1,
                          latency_s=t - st.submit_t)

    def _timeout(self, req: Request, t0: float, t_sub: float) -> Completion:
        self.telemetry.on_finish(req.rid, t0)
        self.timeouts += 1
        return Completion(request=req, tokens=[],
                          prompt_tokens=len(req.tokens),
                          latency_s=t0 - t_sub, status="timeout")

    def _activate(self, slot: int, st: _Slot, first_id: int,
                  done: List[Completion]) -> None:
        """Common tail of every admission path: record the prefill token
        and either retire immediately (budget 1 / instant eos) or join the
        decode batch."""
        st.generated = [first_id]
        st.prefilling = False
        self.telemetry.on_admit(st.req.rid, st.prompt_total, st.prefill_s)
        if self.paged:
            self._promote(st)
        if st.remaining == 0 or first_id == st.req.eos_id:
            self._slots[slot] = None
            self._tok[slot] = 0
            self._pos[slot] = self._idle_pos
            self._seed[slot] = 0
            self._temp[slot] = 0.0
            done.append(self._finish(st, slot))
            return
        self._slots[slot] = st
        self._tok[slot] = first_id
        self._pos[slot] = st.pos
        self._seed[slot] = st.req.seed
        self._temp[slot] = st.req.temperature

    def _admit_prefilled_paged(self, done: List[Completion]) -> None:
        while self._prefilled and self.free_slots > 0:
            pl = self._prefilled[0]
            req = pl.req
            head = int(getattr(pl, "head_pages", 0) or 0)
            if head > 0 and self.shared_head_pages(req.tokens) < head:
                # the promised shared head was evicted between the
                # sender's query and arrival: the payload alone cannot
                # rebuild the cache — fall back to a full local prefill
                self._prefilled.popleft()
                self.prefix_fallbacks += 1
                self._queue.append(req)
                continue
            need = self._pages_needed(pl.prompt_tokens, req.max_new_tokens)
            shared = []
            if head > 0:
                hs = self._index.hashes(req.tokens)
                shared = [self._index.full[h] for _, h, _ in hs[:head]]
                for pid in shared:
                    self._pool.retain(pid)
            priv = self._alloc_pages(need - head)
            if priv is None:
                for pid in shared:
                    self._pool.release(pid)
                break                      # wait for a retirement
            self._prefilled.popleft()
            t0 = time.perf_counter()
            slot = self._slots.index(None)
            row = shared + priv
            self._set_table_row(slot, row)
            self._caches = self._clear(
                self._caches,
                np.pad(np.asarray(priv, np.int32),
                       (0, self.pages_per_slot - len(priv))))
            self._caches = self._insert(
                self._caches, self._put(pl.cache), np.int32(0),
                np.int32(slot), self._table_row_dev(slot))
            st = _Slot(req=req, pos=pl.prompt_tokens,
                       remaining=req.max_new_tokens - 1, generated=[],
                       submit_t=self.telemetry.submit_time(req.rid, t0),
                       pages=row, prompt_total=pl.prompt_tokens,
                       hashes=self._index.hashes(req.tokens)
                       if self._share and not req.extras else None,
                       prefill_s=time.perf_counter() - t0, t_admit=t0)
            self._slots[slot] = st
            self._activate(slot, st, pl.first_id, done)

    def _prefill_batch(self, items, done: List[Completion]) -> None:
        """One dense prefill dispatch for G same-length prompts, spliced
        row-by-row into the pool."""
        t0 = time.perf_counter()
        G = len(items)
        batch = {"tokens": jnp.asarray(
            np.stack([it[0].tokens for it in items]))}
        extras = items[0][0].extras
        if extras:          # G == 1 by construction for extras requests
            for k, v in extras.items():
                batch[k] = jnp.asarray(np.asarray(v)[None])
        batch = self._put(batch)
        logits, cache = self._prefill(self.params, batch)
        pts = [len(it[0].tokens) + self._extra_tokens(it[0]) for it in items]
        first = _pick_tokens(
            logits,
            jnp.asarray([p - 1 for p in pts], jnp.int32),
            jnp.asarray([it[0].seed for it in items], jnp.int32),
            jnp.asarray([it[0].temperature for it in items], jnp.float32))
        for r, (req, slot, row, hashes) in enumerate(items):
            if self.paged:
                self._caches = self._insert(
                    self._caches, cache, np.int32(r), np.int32(slot),
                    self._table_row_dev(slot))
            else:
                self._caches = self._insert(self._caches, cache,
                                            np.int32(r), np.int32(slot))
        first_host = np.asarray(jax.block_until_ready(first))
        prefill_s = (time.perf_counter() - t0) / G
        for r, (req, slot, row, hashes) in enumerate(items):
            st = _Slot(req=req, pos=pts[r],
                       remaining=req.max_new_tokens - 1, generated=[],
                       submit_t=self.telemetry.submit_time(req.rid, t0),
                       pages=row, prompt_total=pts[r], hashes=hashes,
                       prefill_s=prefill_s, t_admit=t0)
            self._slots[slot] = st
            self._activate(slot, st, int(first_host[r]), done)

    def _run_chunk(self, slot: int, st: _Slot, done: List[Completion]) -> None:
        """Advance one prefill chunk for an admitting slot; on the final
        chunk, emit the first token and join the decode batch."""
        L = len(st.req.tokens)
        C = self.chunk_prefill if self.chunk_prefill > 0 else L
        end = min(st.chunk_next + C, L)
        if L - end == 1:
            end = L            # merge a length-1 final chunk (C+1 tokens)
        t0 = time.perf_counter()
        toks = jnp.asarray(st.req.tokens[st.chunk_next:end])
        pos = jnp.arange(st.chunk_next, end, dtype=jnp.int32)
        logits, self._caches = self._chunk(
            self.params, toks, pos, self._caches, np.int32(slot),
            self._table_row_dev(slot))
        st.chunk_next = end
        if end < L:
            st.prefill_s += time.perf_counter() - t0
            return
        first = _pick_tokens(logits,
                             jnp.asarray([L - 1], jnp.int32),
                             jnp.asarray([st.req.seed], jnp.int32),
                             jnp.asarray([st.req.temperature], jnp.float32))
        first_id = int(jax.block_until_ready(first)[0])
        st.prefill_s += time.perf_counter() - t0
        self._activate(slot, st, first_id, done)

    def _admit_paged(self) -> List[Completion]:
        done: List[Completion] = []
        self._admit_prefilled_paged(done)
        batches: Dict[int, List[tuple]] = {}
        while self._queue and self.free_slots > 0:
            req = self._queue[0]
            t0 = time.perf_counter()
            t_sub = self.telemetry.submit_time(req.rid, t0)
            if req.deadline_s is not None and t0 - t_sub > req.deadline_s:
                self._queue.popleft()
                done.append(self._timeout(req, t0, t_sub))
                continue
            plan = self._plan_pages(req)
            if plan is None:
                break                      # pool exhausted: stay queued
            self._queue.popleft()
            row, p0, hashes = plan
            slot = self._slots.index(None)
            self._set_table_row(slot, row)
            L = len(req.tokens)
            Lp = L + self._extra_tokens(req)
            st = _Slot(req=req, pos=Lp, remaining=req.max_new_tokens - 1,
                       generated=[], submit_t=t_sub, pages=row,
                       prefilling=True, chunk_next=p0, prompt_total=Lp,
                       hashes=hashes, t_admit=t0)
            self._slots[slot] = st
            whole = p0 == 0 and (self.chunk_prefill <= 0
                                 or L <= self.chunk_prefill)
            if req.extras or (whole and not self.batch_prefill):
                self._prefill_batch([(req, slot, row, hashes)], done)
            elif whole:
                batches.setdefault(L, []).append((req, slot, row, hashes))
            else:
                self._caches = self._reset_row(self._caches, np.int32(slot))
                if self.chunk_prefill <= 0 or L - p0 <= self.chunk_prefill:
                    self._run_chunk(slot, st, done)   # synchronous tail
                # else: leave the slot in the prefilling state; step()
                # advances one chunk per engine step, interleaved with the
                # decode batch
        for L, items in batches.items():
            self._prefill_batch(items, done)
        return done

    def _admit_dense(self) -> List[Completion]:
        done: List[Completion] = []
        # migrated payloads first: their prefill is already sunk on a
        # prefill GMI, so admission is the jitted splice alone — the same
        # `_insert` the local path uses, which is what makes migrated and
        # local admissions token-identical downstream
        while self._prefilled and self.free_slots > 0:
            pl = self._prefilled.popleft()
            req = pl.req
            t0 = time.perf_counter()
            slot = self._slots.index(None)
            self._caches = self._insert(self._caches, self._put(pl.cache),
                                        np.int32(0), np.int32(slot))
            st = _Slot(req=req, pos=pl.prompt_tokens,
                       remaining=req.max_new_tokens - 1, generated=[],
                       submit_t=self.telemetry.submit_time(req.rid, t0),
                       prompt_total=pl.prompt_tokens,
                       prefill_s=time.perf_counter() - t0, t_admit=t0)
            self._slots[slot] = st
            self._activate(slot, st, pl.first_id, done)
        while self._queue and self.free_slots > 0:
            req = self._queue.popleft()
            t0 = time.perf_counter()
            t_sub = self.telemetry.submit_time(req.rid, t0)
            if req.deadline_s is not None and t0 - t_sub > req.deadline_s:
                done.append(self._timeout(req, t0, t_sub))
                continue
            slot = self._slots.index(None)
            st = _Slot(req=req, pos=0, remaining=req.max_new_tokens - 1,
                       generated=[], submit_t=t_sub, t_admit=t0)
            st.prompt_total = len(req.tokens) + self._extra_tokens(req)
            st.pos = st.prompt_total
            self._slots[slot] = st
            self._prefill_batch([(req, slot, [], None)], done)
        return done

    # repro: hot
    def step(self) -> List[Completion]:
        """Admit from the queue, advance chunked prefills, run ONE batched
        decode step, retire finished requests.  Returns this step's
        completions."""
        if self.fault_hook is not None:
            try:
                self.fault_hook(self)
            except Exception as exc:
                # mid-decode death: slots (KV caches and all) are forfeit,
                # the queue survives at the admission front; tag the
                # exception with the corpse so the router can target it
                self.dead = True
                if getattr(exc, "engine", None) is None:
                    exc.engine = self
                raise
        if self.dead:
            raise RuntimeError(f"{self.name}: engine is dead")
        done = self._admit_paged() if self.paged else self._admit_dense()
        # advance ONE chunk for each slot still prefilling (they are not
        # in the decode batch yet, so long prompts don't stall decode)
        for i, st in enumerate(self._slots):
            if st is not None and st.prefilling:
                self._run_chunk(i, st, done)
        active = [i for i, s in enumerate(self._slots)
                  if s is not None and not s.prefilling]
        if not active:
            return done
        # per-step decode timing is the telemetry the controller plans
        # from; the token readback is the ONE unavoidable sync per step
        # (continuous batching needs the ids host-side to retire slots)
        t0 = time.perf_counter()  # repro: allow(host-sync-in-hot-path)
        table = self._table_device() if self.paged else None
        tok, self._caches = self._decode(
            self.params, self._caches, *self._put(
                (jnp.asarray(self._tok), jnp.asarray(self._pos),
                 jnp.asarray(self._seed), jnp.asarray(self._temp))), table)
        # repro: allow(host-sync-in-hot-path)
        tok_host = np.asarray(jax.block_until_ready(tok))
        dt = time.perf_counter() - t0  # repro: allow(host-sync-in-hot-path)
        emitted = 0
        for i in active:
            st = self._slots[i]
            tid = int(tok_host[i])
            st.generated.append(tid)
            st.pos += 1
            st.remaining -= 1
            emitted += 1
            if st.remaining == 0 or tid == st.req.eos_id:
                self._slots[i] = None
                self._tok[i] = 0
                self._pos[i] = self._idle_pos
                self._seed[i] = 0
                self._temp[i] = 0.0
                done.append(self._finish(st, i))
            else:
                self._tok[i] = tid
                self._pos[i] = st.pos
        self.telemetry.on_step(dt, len(active), len(self._queue), emitted)
        return done

    def take_queue(self) -> List[Request]:
        """Remove and return every not-yet-admitted request (used by the
        router when draining a worker before retiring it)."""
        out = list(self._queue)
        self._queue.clear()
        return out

    def take_inflight(self) -> List[Request]:
        """Remove and return the requests currently holding decode slots
        (including mid-chunked-prefill ones), abandoning their generation
        progress (the caches are forfeit on a dead engine) — the router's
        restart-elsewhere path."""
        out = [s.req for s in self._slots if s is not None]
        if self.paged:
            for s in self._slots:
                if s is not None:
                    self._release_slot_pages(s)
            self._table[:] = -1
            self._table_dev = None
        self._slots = [None] * self.max_slots
        self._tok[:] = 0
        self._pos[:] = self._idle_pos
        self._seed[:] = 0
        self._temp[:] = 0.0
        return out

    def run_until_idle(self, admit: bool = True) -> List[Completion]:
        """Step until queue and slots are empty.  ``admit=False`` finishes
        the in-flight slots only (the retire-a-worker drain)."""
        pending = [] if admit else self.take_queue()
        done: List[Completion] = []
        while self.busy:
            done.extend(self.step())
        self._queue.extend(pending)
        return done

    def serve(self, requests: List[Request]) -> List[Completion]:
        """Submit-and-drain convenience; completions in retire order."""
        for r in requests:
            self.submit(r)
        return self.run_until_idle()

    # -------------------------------------------------------------- oracle --
    def oracle_generate(self, req: Request) -> List[int]:
        """The single-request reference path: same compiled prefill, B=1
        decode over a fresh private page pool (paged mode) or cache tree
        (dense mode).  Continuous-batched greedy decoding must be
        token-identical to this (the engine's core correctness property)."""
        batch = {"tokens": jnp.asarray(req.tokens[None])}
        if req.extras:
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(np.asarray(v)[None])
        batch = self._put(batch)
        logits, caches = self._prefill(self.params, batch)
        prompt_tokens = len(req.tokens) + self._extra_tokens(req)
        table = None
        if self.paged:
            M = self.pages_per_slot
            pool = T.init_paged_cache(self.cfg, 1, self.max_seq,
                                      self.window_override,
                                      jnp.dtype(self.cfg.dtype),
                                      page_size=self.page_size,
                                      num_pages=M + 1)
            need = self._pages_needed(prompt_tokens, req.max_new_tokens)
            row = np.full((M,), -1, np.int32)
            row[:need] = np.arange(1, need + 1)
            table = self._put(jnp.asarray(row[None]))
            caches = self._insert(self._put(pool), caches, np.int32(0),
                                  np.int32(0), jnp.asarray(row))
        tok = _pick_tokens(logits,
                           jnp.asarray([prompt_tokens - 1], jnp.int32),
                           jnp.asarray([req.seed], jnp.int32),
                           jnp.asarray([req.temperature], jnp.float32))
        out = [int(tok[0])]
        pos = prompt_tokens
        seed = jnp.asarray([req.seed], jnp.int32)
        temp = jnp.asarray([req.temperature], jnp.float32)
        for _ in range(req.max_new_tokens - 1):
            if out[-1] == req.eos_id:
                break
            tok, caches = self._decode(
                self.params, caches, *self._put(
                    (tok.astype(jnp.int32),
                     jnp.asarray([pos], jnp.int32), seed, temp)), table)
            out.append(int(tok[0]))
            pos += 1
        return out


def _pick_tokens(logits, pos, seed, temp):
    """Next-token choice shared by prefill, decode, and the oracle.

    Greedy rows take argmax; sampled rows draw from
    ``categorical(fold_in(key(seed), pos), logits/temp)`` — the key depends
    only on (request seed, absolute position), never on batch composition,
    so sampling is continuous-batching stable too."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def draw(s, p, l, t):
        k = jax.random.fold_in(jax.random.key(s), p)
        return jax.random.categorical(k, l / jnp.maximum(t, 1e-6))

    sampled = jax.vmap(draw)(seed, pos, logits, temp).astype(jnp.int32)
    return jnp.where(temp > 0.0, sampled, greedy)
