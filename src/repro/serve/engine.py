"""Slot-based continuous-batching request engine (paper §3: one serving
GMI's execution loop).

The engine owns a fixed-slot decode batch over the existing
``transformer.prefill`` / ``transformer.decode_step`` cache machinery — KV
caches, sliding-window ring caches, mLSTM/sLSTM/Mamba2 recurrent states,
and zamba-style hybrid stacks all work because every stacked cache leaf
carries its batch dimension at axis 1, so one jitted *insert* splices a
single request's prefilled cache into its slot.

Request lifecycle (disaggregated; see ``repro.serve.disagg``)::

    submit -> planner: migrate or local?
      local   -> queue -> [admit: B=1 prefill -> cache splice -> first token]
      migrate -> prefill GMI (B=1 prefill) -> CachePayload -> channel ring
              -> submit_prefilled -> [admit: cache splice only]
    -> decode slot (one batched decode_step per engine step)
    -> retire (budget exhausted / eos) -> slot freed for the queue

The two admission paths converge on the same jitted splice, so a decode
batch fed by a migrated cache is token-identical to one that prefilled
locally — and both to :meth:`ServeEngine.oracle_generate`.

Design points:

* **No decode recompilation.**  The decode batch has a fixed slot count,
  so requests of different prompt lengths and generation budgets join and
  leave without retracing — ``decode_step`` already takes per-row absolute
  positions, which is all continuous batching needs.  Prefill traces once
  per distinct prompt length (B=1), never per batch composition.
* **Idle slots cost one row of compute.**  They decode token 0 at
  position 0 against an empty cache (``slot_pos == -1`` masks everything;
  the softmax degrades to uniform, not NaN) and their garbage is fully
  overwritten by the next cache splice.
* **Single-request oracle.**  :meth:`ServeEngine.oracle_generate` runs the
  same compiled functions at B=1; greedy decoding in the batch is
  token-identical to it (pinned in ``tests/test_serve_engine.py`` across
  attention, SSM, and hybrid cache families).  Sampling uses per-request
  keys (``fold_in(key(seed), position)`` vmapped per row) so it is also
  batch-composition independent.  The one known exception is MoE configs
  with a finite ``moe_capacity_factor``: expert capacity is shared across
  the batch, so a dropped token can depend on who else is in the batch.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serve.telemetry import ServingTelemetry

_REQUEST_IDS = itertools.count()


@dataclass
class Request:
    """One generation request.  ``tokens`` is the prompt (1-D int array);
    ``max_new_tokens`` counts every generated token, including the one the
    prefill emits.  ``extras`` carries additional prompt modalities (e.g.
    ``{"patches": (num_patches, feat)}`` for vision frontends); each entry
    gets a leading batch dim at admission.  ``deadline_s`` is a TTL from
    submit time: a request still queued past it completes with status
    ``"timeout"`` instead of occupying a decode slot."""
    tokens: Any
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0
    eos_id: Optional[int] = None
    extras: Optional[Dict[str, Any]] = None
    deadline_s: Optional[float] = None
    rid: int = field(default_factory=lambda: next(_REQUEST_IDS))

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclass
class Completion:
    """A retired request: ``tokens`` are the generated ids (prefill token
    first), ``latency_s`` is submit-to-retire wall time.  ``status`` is
    ``"ok"`` for a normal retire, ``"timeout"`` for a deadline-expired
    queued request (empty ``tokens``), ``"failed"`` for a request whose
    engine died mid-decode with retries exhausted."""
    request: Request
    tokens: List[int]
    prompt_tokens: int
    latency_s: float
    status: str = "ok"

    @property
    def rid(self) -> int:
        return self.request.rid


@dataclass
class _Slot:
    req: Request
    pos: int                     # absolute position of the token being fed
    remaining: int               # decode steps left (budget - prefill token)
    generated: List[int]
    submit_t: float


class ServeEngine:
    """Continuous-batching engine over one model replica.

    Parameters
    ----------
    cfg, params : the model (any non-encoder-only architecture).
    max_slots   : decode batch width — the fixed slot count.
    max_seq     : cache depth; every request needs
                  ``len(prompt) + max_new_tokens <= max_seq``.
    window_override : sliding-window serving variant (ring caches).
    mesh        : optional ``jax.sharding.Mesh`` (a GMI submesh) — params
                  and all per-step inputs are committed to it, so the
                  engine's compiled programs run inside the instance's
                  MIG-style isolation boundary.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 4,
                 max_seq: int = 128, window_override: Optional[int] = None,
                 mesh=None, telemetry: Optional[ServingTelemetry] = None,
                 name: str = "engine"):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name}: encoder-only model has no decode "
                             "step — nothing to serve")
        self.cfg = cfg
        self.max_slots = int(max_slots)
        self.max_seq = int(max_seq)
        self.window_override = window_override
        self.mesh = mesh
        self.name = name
        self.telemetry = telemetry or ServingTelemetry(self.max_slots)
        # fault-injection seam (repro.fault): called with this engine at
        # the top of step(); raising InjectedFault there kills the engine
        # mid-decode (``dead`` flips, slots are forfeit, queue survives)
        self.fault_hook = None
        self.dead = False
        self.timeouts = 0
        self._sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            self._sharding = NamedSharding(mesh, PartitionSpec())
            params = jax.device_put(params, self._sharding)
        self.params = params

        self._queue: Deque[Request] = deque()
        # prefilled-elsewhere payloads awaiting a slot (cache splice only,
        # no local prefill compute) — admitted ahead of the raw queue
        # because their prefill cost is already sunk on another GMI
        self._prefilled: Deque[Any] = deque()
        self._slots: List[Optional[_Slot]] = [None] * self.max_slots
        dt = jnp.dtype(cfg.dtype)
        caches = T.init_cache(cfg, self.max_slots, self.max_seq,
                              window_override, dt)
        self._caches = self._put(caches)
        self._cache_bytes = sum(
            x.size * x.dtype.itemsize for x in jax.tree.leaves(caches)
            if hasattr(x, "dtype"))
        # host-side mirrors of the decode-batch inputs; idle rows feed
        # (token=0, pos=0, temp=0) and are ignored on the way out
        self._tok = np.zeros((self.max_slots,), np.int32)
        self._pos = np.zeros((self.max_slots,), np.int32)
        self._seed = np.zeros((self.max_slots,), np.int32)
        self._temp = np.zeros((self.max_slots,), np.float32)

        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, self.max_seq, window_override))
        # the cache pytree is rebound to the jit output on every call:
        # donate it so decode and splice update in place instead of
        # copying the full multi-slot cache per token
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))
        self._insert = jax.jit(self._insert_fn, donate_argnums=(0,))

    # ------------------------------------------------------- jitted bodies --
    def _decode_fn(self, params, caches, tok, pos, seed, temp):
        logits, caches = T.decode_step(params, self.cfg, tok, pos, caches,
                                       self.window_override)
        return _pick_tokens(logits, pos, seed, temp), caches

    @staticmethod
    def _insert_fn(full, one, slot):
        # every stacked cache leaf is (layers_or_super, batch, ...): splice
        # the single-request cache (batch dim 1) into its decode slot
        return jax.tree.map(
            lambda f, o: jax.lax.dynamic_update_index_in_dim(
                f, o[:, 0], slot, 1), full, one)

    def _put(self, tree):
        if self._sharding is None:
            return tree
        return jax.device_put(tree, self._sharding)

    # ------------------------------------------------------------- queries --
    @property
    def active_count(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    @property
    def free_slots(self) -> int:
        return self.max_slots - self.active_count

    @property
    def queue_len(self) -> int:
        return len(self._queue) + len(self._prefilled)

    @property
    def load(self) -> int:
        """Outstanding work — the router's queue-depth routing key."""
        return self.active_count + self.queue_len

    @property
    def busy(self) -> bool:
        return self.load > 0

    @property
    def cache_bytes(self) -> int:
        return self._cache_bytes

    # ----------------------------------------------------------- lifecycle --
    def submit(self, req: Request) -> int:
        """Queue a request; returns its id.  Admission happens at the next
        :meth:`step` when a slot frees up."""
        total = len(req.tokens) + self._extra_tokens(req) + req.max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+budget {total} exceeds engine "
                f"max_seq {self.max_seq}")
        self.telemetry.on_submit(req.rid)
        self._queue.append(req)
        return req.rid

    def submit_prefilled(self, payload) -> int:
        """Queue a prefilled-elsewhere cache payload (duck-typed: ``req``,
        ``cache``, ``first_id``, ``prompt_tokens``, ``submit_t``) for
        splice-only admission — the decode half of prefill/decode
        disaggregation.  The cache must come from the same model family
        (cfg/params/max_seq/window) for the splice to be well-formed."""
        req = payload.req
        total = payload.prompt_tokens + req.max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+budget {total} exceeds engine "
                f"max_seq {self.max_seq}")
        self.telemetry.on_submit(req.rid,
                                 t=getattr(payload, "submit_t", None))
        self._prefilled.append(payload)
        return req.rid

    def take_prefilled(self) -> List[Any]:
        """Remove and return the not-yet-spliced prefilled payloads (they
        are engine-independent — a survivor can splice them as-is)."""
        out = list(self._prefilled)
        self._prefilled.clear()
        return out

    def _extra_tokens(self, req: Request) -> int:
        if self.cfg.frontend == "vision" and req.extras \
                and "patches" in req.extras:
            return int(req.extras["patches"].shape[0])
        return 0

    def _admit(self) -> List[Completion]:
        done: List[Completion] = []
        # migrated payloads first: their prefill is already sunk on a
        # prefill GMI, so admission is the jitted splice alone — the same
        # `_insert` the local path uses, which is what makes migrated and
        # local admissions token-identical downstream
        while self._prefilled and self.free_slots > 0:
            pl = self._prefilled.popleft()
            req = pl.req
            t0 = time.perf_counter()
            slot = self._slots.index(None)
            self._caches = self._insert(self._caches, self._put(pl.cache),
                                        np.int32(slot))
            splice_s = time.perf_counter() - t0
            self.telemetry.on_admit(req.rid, pl.prompt_tokens, splice_s)
            st = _Slot(req=req, pos=pl.prompt_tokens,
                       remaining=req.max_new_tokens - 1,
                       generated=[pl.first_id],
                       submit_t=self.telemetry.submit_time(req.rid, t0))
            if st.remaining == 0 or pl.first_id == req.eos_id:
                done.append(self._finish(st))
                continue
            self._slots[slot] = st
            self._tok[slot] = pl.first_id
            self._pos[slot] = st.pos
            self._seed[slot] = req.seed
            self._temp[slot] = req.temperature
        while self._queue and self.free_slots > 0:
            req = self._queue.popleft()
            t0 = time.perf_counter()
            t_sub = self.telemetry.submit_time(req.rid, t0)
            if req.deadline_s is not None and t0 - t_sub > req.deadline_s:
                # TTL expired while queued: complete as a timeout instead
                # of spending a slot + prefill on a request nobody wants
                self.telemetry.on_finish(req.rid, t0)
                self.timeouts += 1
                done.append(Completion(
                    request=req, tokens=[], prompt_tokens=len(req.tokens),
                    latency_s=t0 - t_sub, status="timeout"))
                continue
            slot = self._slots.index(None)
            batch = {"tokens": jnp.asarray(req.tokens[None])}
            if req.extras:
                for k, v in req.extras.items():
                    batch[k] = jnp.asarray(np.asarray(v)[None])
            batch = self._put(batch)
            logits, cache = self._prefill(self.params, batch)
            prompt_tokens = len(req.tokens) + self._extra_tokens(req)
            first = _pick_tokens(logits,
                                 jnp.asarray([prompt_tokens - 1], jnp.int32),
                                 jnp.asarray([req.seed], jnp.int32),
                                 jnp.asarray([req.temperature], jnp.float32))
            self._caches = self._insert(self._caches, cache,
                                        np.int32(slot))
            first_id = int(jax.block_until_ready(first)[0])
            prefill_s = time.perf_counter() - t0
            self.telemetry.on_admit(req.rid, prompt_tokens, prefill_s)
            st = _Slot(req=req, pos=prompt_tokens,
                       remaining=req.max_new_tokens - 1,
                       generated=[first_id],
                       submit_t=self.telemetry.submit_time(req.rid, t0))
            if st.remaining == 0 or first_id == req.eos_id:
                done.append(self._finish(st))
                continue
            self._slots[slot] = st
            self._tok[slot] = first_id
            self._pos[slot] = st.pos
            self._seed[slot] = req.seed
            self._temp[slot] = req.temperature
        return done

    def _finish(self, st: _Slot) -> Completion:
        t = time.perf_counter()
        self.telemetry.on_finish(st.req.rid, t)
        # pos always trails the generated count by prompt_tokens - 1
        return Completion(request=st.req, tokens=st.generated,
                          prompt_tokens=st.pos - len(st.generated) + 1,
                          latency_s=t - st.submit_t)

    def step(self) -> List[Completion]:
        """Admit from the queue, run ONE batched decode step, retire
        finished requests.  Returns this step's completions."""
        if self.fault_hook is not None:
            try:
                self.fault_hook(self)
            except Exception as exc:
                # mid-decode death: slots (KV caches and all) are forfeit,
                # the queue survives at the admission front; tag the
                # exception with the corpse so the router can target it
                self.dead = True
                if getattr(exc, "engine", None) is None:
                    exc.engine = self
                raise
        if self.dead:
            raise RuntimeError(f"{self.name}: engine is dead")
        done = self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return done
        t0 = time.perf_counter()
        tok, self._caches = self._decode(
            self.params, self._caches, *self._put(
                (jnp.asarray(self._tok), jnp.asarray(self._pos),
                 jnp.asarray(self._seed), jnp.asarray(self._temp))))
        tok_host = np.asarray(jax.block_until_ready(tok))
        dt = time.perf_counter() - t0
        emitted = 0
        for i in active:
            st = self._slots[i]
            tid = int(tok_host[i])
            st.generated.append(tid)
            st.pos += 1
            st.remaining -= 1
            emitted += 1
            if st.remaining == 0 or tid == st.req.eos_id:
                self._slots[i] = None
                self._tok[i] = 0
                self._pos[i] = 0
                self._seed[i] = 0
                self._temp[i] = 0.0
                done.append(self._finish(st))
            else:
                self._tok[i] = tid
                self._pos[i] = st.pos
        self.telemetry.on_step(dt, len(active), len(self._queue), emitted)
        return done

    def take_queue(self) -> List[Request]:
        """Remove and return every not-yet-admitted request (used by the
        router when draining a worker before retiring it)."""
        out = list(self._queue)
        self._queue.clear()
        return out

    def take_inflight(self) -> List[Request]:
        """Remove and return the requests currently holding decode slots,
        abandoning their generation progress (the caches are forfeit on a
        dead engine) — the router's restart-elsewhere path."""
        out = [s.req for s in self._slots if s is not None]
        self._slots = [None] * self.max_slots
        self._tok[:] = 0
        self._pos[:] = 0
        self._seed[:] = 0
        self._temp[:] = 0.0
        return out

    def run_until_idle(self, admit: bool = True) -> List[Completion]:
        """Step until queue and slots are empty.  ``admit=False`` finishes
        the in-flight slots only (the retire-a-worker drain)."""
        pending = [] if admit else self.take_queue()
        done: List[Completion] = []
        while self.busy:
            done.extend(self.step())
        self._queue.extend(pending)
        return done

    def serve(self, requests: List[Request]) -> List[Completion]:
        """Submit-and-drain convenience; completions in retire order."""
        for r in requests:
            self.submit(r)
        return self.run_until_idle()

    # -------------------------------------------------------------- oracle --
    def oracle_generate(self, req: Request) -> List[int]:
        """The single-request reference path: same compiled prefill, B=1
        decode.  Continuous-batched greedy decoding must be token-identical
        to this (the engine's core correctness property)."""
        batch = {"tokens": jnp.asarray(req.tokens[None])}
        if req.extras:
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(np.asarray(v)[None])
        batch = self._put(batch)
        logits, caches = self._prefill(self.params, batch)
        prompt_tokens = len(req.tokens) + self._extra_tokens(req)
        tok = _pick_tokens(logits,
                           jnp.asarray([prompt_tokens - 1], jnp.int32),
                           jnp.asarray([req.seed], jnp.int32),
                           jnp.asarray([req.temperature], jnp.float32))
        out = [int(tok[0])]
        pos = prompt_tokens
        seed = jnp.asarray([req.seed], jnp.int32)
        temp = jnp.asarray([req.temperature], jnp.float32)
        for _ in range(req.max_new_tokens - 1):
            if out[-1] == req.eos_id:
                break
            tok, caches = self._decode(
                self.params, caches, *self._put(
                    (tok.astype(jnp.int32),
                     jnp.asarray([pos], jnp.int32), seed, temp)))
            out.append(int(tok[0]))
            pos += 1
        return out


def _pick_tokens(logits, pos, seed, temp):
    """Next-token choice shared by prefill, decode, and the oracle.

    Greedy rows take argmax; sampled rows draw from
    ``categorical(fold_in(key(seed), pos), logits/temp)`` — the key depends
    only on (request seed, absolute position), never on batch composition,
    so sampling is continuous-batching stable too."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def draw(s, p, l, t):
        k = jax.random.fold_in(jax.random.key(s), p)
        return jax.random.categorical(k, l / jnp.maximum(t, 1e-6))

    sampled = jax.vmap(draw)(seed, pos, logits, temp).astype(jnp.int32)
    return jnp.where(temp > 0.0, sampled, greedy)
