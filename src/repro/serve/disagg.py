"""Prefill/decode disaggregation across GMIs (ROADMAP item 2; JigsawRL
arXiv:2604.23838's request-level scheduling argument applied to the
GMI-DRL serving pool).

Aggregated serving runs every request's whole-prompt B=1 prefill on the
decode GMI that will host it, stalling that engine's decode batch for
the prefill duration.  Disaggregated serving splits the roles:

* :class:`PrefillEngine` — a prefill-specialist GMI.  Runs the SAME
  compiled ``transformer.prefill`` + token pick the decode engines use
  (identical cfg/params/max_seq/window), so the cache and first token it
  produces are bit-identical to what the decode engine would have
  computed locally.  Its product is a :class:`CachePayload`.
* :class:`~repro.core.channels.CacheChannel` — the migration link: the
  payload's cache pytree is packed into per-dtype contiguous buffers
  (``kernels.channel_pack.pack_cache_payload`` — one coarse move, the
  §4.2 anti-fine-grained-transfer discipline) and reassembled bit-exact
  on the decode side, with (seconds, bytes) samples feeding the same
  bandwidth calibrator as gradient reduces.
* :class:`MigrationPlanner` — per-request migrate-vs-local decision in
  Table-2 units (``core.cost_model.migration_beats_local``): migration
  costs ``latency + bytes/bandwidth`` against the measured local-prefill
  stall ``prompt_tokens / prefill_tok_s``, under the controller's own
  1.05x hysteresis.  Bandwidth preference order: measured channel
  samples (EMA) > the communicator's calibrated Table-2 fit > static
  default.  Short prompts stay local; long prompts migrate — the
  crossover is measured by ``benchmarks/bench_disagg.py``.
* :class:`DisaggFront` — the composed serving front.  Request lifecycle::

      submit -> planner: migrate or local?
        local   -> RequestRouter -> decode GMI [B=1 prefill + splice]
        migrate -> prefill GMI -> CachePayload -> CacheChannel
                -> decode GMI ``submit_prefilled`` [splice only]
      -> batched decode -> completion

Control plane: the front does NOT make scaling decisions.  It exposes
``take_epoch`` (router load + prefill telemetry: ``prefill_backlog``,
``migrations``) and ``apply_decision`` (resize the prefill set from
``Decision.prefill_gpus``, then delegate to the router), and the single
:class:`~repro.core.controller.OnlineGMIController` instance driven by
``AsyncRunner.round`` arbitrates trainers, rollout actors, prefill GMIs,
and decode GMIs together.

Fault story (extends PR 6's zero-request-loss invariant): a dead prefill
GMI forfeits its queued prompts and its still-staged channel transfers
(``CacheChannel.fail_source``); :meth:`DisaggFront.fail_prefill_engine`
re-prefills all of them on surviving prefill GMIs — or re-routes them to
the decode side's local-prefill path when no specialist survives.  Every
request completes either way.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.channels import CacheChannel
from repro.core.cost_model import migration_beats_local
from repro.kernels.channel_pack import truncate_cache_pages
from repro.serve.engine import (Completion, Request, ServeEngine,
                                _pick_tokens)
from repro.serve.router import RequestRouter
from repro.serve.telemetry import ServingLoad
from repro.models import transformer as T


@dataclass
class CachePayload:
    """A finished prefill, portable between GMIs: the cache pytree (batch
    dim 1 at axis 1 on every stacked leaf — the shape ``ServeEngine``'s
    jitted splice expects), the first generated token, and the request's
    original latency clock.

    For a paged decode destination the front prunes the tree to whole
    pages (``kernels.channel_pack.truncate_cache_pages``) before it hits
    the wire; ``head_pages`` > 0 additionally records that the leading
    prompt pages were STRIPPED because the chosen destination already
    holds them in its shared-prefix index — the payload is then only
    splice-complete on an engine that still has those pages (any other
    engine falls back to a full local prefill, losslessly)."""
    req: Request
    cache: Any
    first_id: int
    prompt_tokens: int
    submit_t: float = 0.0
    prefill_s: float = 0.0
    head_pages: int = 0


class PrefillEngine:
    """Prefill-specialist GMI: whole-prompt B=1 prefill, no decode slots.

    Shares cfg/params/max_seq/window with the decode engines it feeds —
    the token-identity precondition.  One :meth:`step` prefills one
    queued request and returns its :class:`CachePayload` (or None when
    idle).  Carries the same fault seam as ``ServeEngine.step``: a
    ``fault_hook`` raising marks the engine dead and tags the exception
    with the corpse for the supervisor."""

    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 128,
                 window_override: Optional[int] = None, mesh=None,
                 name: str = "prefill"):
        if cfg.is_encoder_only:
            raise ValueError(f"{cfg.name}: encoder-only model has no "
                             "decode step — nothing to prefill for")
        self.cfg = cfg
        self.max_seq = int(max_seq)
        self.window_override = window_override
        self.name = name
        self.fault_hook = None
        self.dead = False
        self._sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            self._sharding = NamedSharding(mesh, PartitionSpec())
            params = jax.device_put(params, self._sharding)
        self.params = params
        self._queue: List[Request] = []
        self._submit_t = {}
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, self.max_seq, window_override))
        # epoch-scoped prefill telemetry (folded into the front's load)
        self._epoch_prefill_s = 0.0
        self._epoch_prefilled = 0
        self._epoch_prefill_tokens = 0
        self.total_prefilled = 0

    def _put(self, tree):
        if self._sharding is None:
            return tree
        return jax.device_put(tree, self._sharding)

    @property
    def load(self) -> int:
        return len(self._queue)

    @property
    def busy(self) -> bool:
        return self.load > 0

    def submit(self, req: Request, submit_t: Optional[float] = None) -> int:
        total = len(req.tokens) + req.max_new_tokens
        if total > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+budget {total} exceeds "
                f"prefill max_seq {self.max_seq}")
        self._submit_t.setdefault(
            req.rid, time.perf_counter() if submit_t is None else submit_t)
        self._queue.append(req)
        return req.rid

    def take_queue(self) -> List[Request]:
        """Remove every queued request (failover: a survivor re-prefills
        them; latency clocks ride on ``req._submit_t``)."""
        out, self._queue = self._queue, []
        for r in out:
            r._submit_t = self._submit_t.pop(r.rid, None)
        return out

    def step(self) -> Optional[CachePayload]:
        """Prefill the oldest queued request into a portable payload."""
        if self.fault_hook is not None:
            try:
                self.fault_hook(self)
            except Exception as exc:
                self.dead = True
                if getattr(exc, "engine", None) is None:
                    exc.engine = self
                raise
        if self.dead:
            raise RuntimeError(f"{self.name}: prefill engine is dead")
        if not self._queue:
            return None
        req = self._queue.pop(0)
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(req.tokens[None])}
        if req.extras:
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(np.asarray(v)[None])
        batch = self._put(batch)
        logits, cache = self._prefill(self.params, batch)
        prompt_tokens = len(req.tokens)
        first = _pick_tokens(logits,
                             jnp.asarray([prompt_tokens - 1], jnp.int32),
                             jnp.asarray([req.seed], jnp.int32),
                             jnp.asarray([req.temperature], jnp.float32))
        first_id = int(jax.block_until_ready(first)[0])
        prefill_s = time.perf_counter() - t0
        self._epoch_prefill_s += prefill_s
        self._epoch_prefilled += 1
        self._epoch_prefill_tokens += prompt_tokens
        self.total_prefilled += 1
        return CachePayload(
            req=req, cache=cache, first_id=first_id,
            prompt_tokens=prompt_tokens,
            submit_t=self._submit_t.pop(req.rid, t0),
            prefill_s=prefill_s)

    def take_epoch(self) -> tuple:
        """(prefill seconds, prompts, prompt tokens) this epoch; resets."""
        out = (self._epoch_prefill_s, self._epoch_prefilled,
               self._epoch_prefill_tokens)
        self._epoch_prefill_s = 0.0
        self._epoch_prefilled = 0
        self._epoch_prefill_tokens = 0
        return out


class MigrationPlanner:
    """Per-request migrate-vs-local decision in Table-2 cost-model units.

    Seeds with static defaults, then follows measurements: channel
    (seconds, bytes) samples sharpen the bandwidth estimate (EMA), the
    decode engines' measured prefill throughput sharpens the local-stall
    estimate, and an attached communicator's calibrated Table-2 fit
    supplies bandwidth while the channel is still unmeasured."""

    def __init__(self, *, bandwidth: Optional[float] = None,
                 communicator=None, latency_s: float = 100e-6,
                 min_gain: float = 1.05,
                 prefill_tok_s: float = 2e3, ema: float = 0.3):
        self.communicator = communicator
        self.static_bandwidth = bandwidth
        self.latency_s = float(latency_s)
        self.min_gain = float(min_gain)
        self._prefill_tok_s = float(prefill_tok_s)
        self._bw_measured: Optional[float] = None
        self.ema = float(ema)
        self.migrated = 0
        self.kept_local = 0

    @property
    def bandwidth(self) -> float:
        if self._bw_measured is not None:
            return self._bw_measured
        if self.static_bandwidth is not None:
            return self.static_bandwidth
        if self.communicator is not None:
            cm = self.communicator.effective_cost_model
            if callable(cm):        # property on Communicator, fn on fakes
                cm = cm()
            return float(cm.bw_gpu)     # B2: the cross-GPU interconnect
        return 5e9

    @property
    def prefill_tok_s(self) -> float:
        return self._prefill_tok_s

    def observe_transfer(self, seconds: float, nbytes: int) -> None:
        if seconds <= 0.0 or nbytes <= 0:
            return
        bw = nbytes / seconds
        self._bw_measured = bw if self._bw_measured is None else \
            (1 - self.ema) * self._bw_measured + self.ema * bw
        if self.communicator is not None:
            # migration timings are channel-transfer evidence for the
            # same Table-2 calibration that prices gradient reduces
            self.communicator.observe_transfer(seconds, nbytes)

    def observe_prefill(self, tokens: int, seconds: float) -> None:
        if seconds <= 0.0 or tokens <= 0:
            return
        rate = tokens / seconds
        self._prefill_tok_s = \
            (1 - self.ema) * self._prefill_tok_s + self.ema * rate

    def should_migrate(self, nbytes: float, prompt_tokens: int) -> bool:
        take = migration_beats_local(
            nbytes, prompt_tokens, self.bandwidth, self._prefill_tok_s,
            self.latency_s, self.min_gain)
        if take:
            self.migrated += 1
        else:
            self.kept_local += 1
        return take


class DisaggFront:
    """The disaggregated serving front: prefill specialists + a decode
    :class:`RequestRouter`, joined by a :class:`CacheChannel`, with the
    :class:`MigrationPlanner` choosing per request.

    Duck-types the router surface the control plane consumes (``submit``
    / ``step`` / ``drain`` / ``take_epoch`` / ``apply_decision`` /
    ``busy`` / ``completions``), so ``AsyncRunner`` and the
    ``FleetSupervisor`` drive aggregated and disaggregated fleets through
    one code path."""

    def __init__(self, router: RequestRouter,
                 prefill_engines: List[PrefillEngine], *,
                 channel: Optional[CacheChannel] = None,
                 planner: Optional[MigrationPlanner] = None,
                 prefill_factory: Optional[
                     Callable[[int], PrefillEngine]] = None):
        if not prefill_engines and prefill_factory is None:
            raise ValueError("need prefill engines or a prefill_factory")
        self.router = router
        self.prefill_engines = list(prefill_engines)
        self._prefill_factory = prefill_factory
        self._spawned = len(self.prefill_engines)
        if not self.prefill_engines:
            self.prefill_engines = [prefill_factory(0)]
            self._spawned = 1
        self.channel = channel or CacheChannel()
        self.planner = planner or MigrationPlanner()
        # per-slot payload wire size, measured off the first migration;
        # estimated from the decode engines' cache footprint until then
        self._payload_bytes: Optional[float] = None
        # measured wire bytes per page (paged decode engines), for the
        # planner's per-request page pricing
        self._page_bytes: Optional[float] = None
        self._epoch_migrations = 0
        # cumulative pages NOT shipped thanks to shared-prefix dedup
        self.prefix_pages_saved = 0
        self.failed_prefill_engines = 0

    # ------------------------------------------------------------ routing --
    @property
    def engines(self) -> List[ServeEngine]:
        return self.router.engines

    @property
    def completions(self) -> List[Completion]:
        return self.router.completions

    @property
    def busy(self) -> bool:
        return (any(e.busy for e in self.prefill_engines)
                or self.channel.in_flight > 0 or self.router.busy)

    @property
    def payload_bytes(self) -> float:
        if self._payload_bytes is not None:
            return self._payload_bytes
        eng = self.router.engines[0]
        return eng.cache_bytes / max(eng.max_slots, 1)

    def request_bytes(self, prompt_tokens: int) -> float:
        """Estimated wire bytes for THIS prompt's payload.  Paged decode
        engines ship ceil(prompt/page) pages, so the estimate scales with
        the prompt instead of charging every request the full per-slot
        footprint (which made short prompts look costlier to migrate than
        they are)."""
        eng = self.router.engines[0]
        P = int(getattr(eng, "page_size", 0) or 0)
        if not getattr(eng, "paged", False) or P <= 0:
            return self.payload_bytes
        pages = -(-max(int(prompt_tokens), 1) // P)
        if self._page_bytes is not None:
            return self._page_bytes * pages
        # pro-rate the per-slot estimate by prompt coverage until measured
        total = max(getattr(eng, "pages_per_slot", 1), 1)
        return self.payload_bytes * min(pages / total, 1.0)

    def submit(self, req: Request) -> int:
        """Route one request: the planner prices shipping its finished
        cache (page-wise for paged decode engines) against stalling a
        decode batch on local prefill."""
        if self.prefill_engines and self.planner.should_migrate(
                self.request_bytes(len(req.tokens)), len(req.tokens)):
            eng = min(self.prefill_engines, key=lambda e: e.load)
            return eng.submit(req)
        return self.router.submit(req)

    # ------------------------------------------------------------ stepping --
    def _stage_payload(self, payload: CachePayload):
        """Pick the payload's decode destination NOW (least-loaded), prune
        the cache to whole pages for it, and strip the leading pages its
        shared-prefix index already holds.  Returns (wire tree, dst)."""
        dst = min(self.router.engines, key=lambda e: e.load)
        cache = payload.cache
        P = int(getattr(dst, "page_size", 0) or 0)
        if getattr(dst, "paged", False) and P > 0:
            head = 0
            if not payload.req.extras \
                    and hasattr(dst, "shared_head_pages"):
                head = int(dst.shared_head_pages(payload.req.tokens))
            cache = truncate_cache_pages(cache, payload.prompt_tokens, P,
                                         head_skip=head)
            payload.head_pages = head
            self.prefix_pages_saved += head
        payload._dst = dst
        return cache, dst

    def step(self) -> List[Completion]:
        """One front tick: each prefill GMI prefills one prompt into the
        channel, the channel delivers finished payloads to their chosen
        decode GMIs, and every busy decode engine takes one batched
        decode step."""
        for eng in self.prefill_engines:
            if not eng.busy:
                continue
            payload = eng.step()
            if payload is not None:
                cache, dst = self._stage_payload(payload)
                nbytes = float(self.channel.send(payload, cache, source=eng))
                self._payload_bytes = nbytes
                P = int(getattr(dst, "page_size", 0) or 0)
                if getattr(dst, "paged", False) and P > 0:
                    shipped = max(
                        -(-payload.prompt_tokens // P) - payload.head_pages,
                        1)
                    self._page_bytes = nbytes / shipped
        for payload, cache in self.channel.deliver():
            payload.cache = cache      # the reassembled, bit-exact tree
            dst = getattr(payload, "_dst", None)
            if dst is None or dst not in self.router.engines:
                # chosen engine retired/died mid-flight: any survivor can
                # take it — a head-stripped payload that lands on an
                # engine missing the prefix re-queues for a full local
                # prefill there (ServeEngine.prefix_fallbacks), lossless
                dst = min(self.router.engines, key=lambda e: e.load)
            dst.submit_prefilled(payload)
            self._epoch_migrations += 1
        for sec, nbytes in self.channel.take_transfer_samples():
            self.planner.observe_transfer(sec, nbytes)
        return self.router.step()

    def drain(self) -> List[Completion]:
        done: List[Completion] = []
        while self.busy:
            done.extend(self.step())
        return done

    def serve(self, requests: List[Request]) -> List[Completion]:
        for r in requests:
            self.submit(r)
        return self.drain()

    # ----------------------------------------------------------- telemetry --
    def take_epoch(self) -> ServingLoad:
        """Router-level load with the disagg extensions: decode-side
        measured prefill throughput feeds the planner, prefill-side work
        folds into ``prefill_s``, and ``prefill_backlog``/``migrations``
        carry the signals the controller's prefill arbitration reads."""
        load = self.router.take_epoch()
        pf_s = 0.0
        for eng in self.prefill_engines:
            s, _, ptoks = eng.take_epoch()
            pf_s += s
            if s > 0.0 and ptoks > 0:
                # measured prompt-tokens/s off the specialists — the same
                # compiled prefill the decode engines run, so this IS the
                # planner's local-stall rate
                self.planner.observe_prefill(ptoks, s)
        backlog = sum(e.load for e in self.prefill_engines) \
            + self.channel.in_flight
        migrations, self._epoch_migrations = self._epoch_migrations, 0
        return ServingLoad(
            dt=max(load.dt, pf_s), tokens=load.tokens,
            requests=load.requests,
            queue_depth_mean=load.queue_depth_mean,
            queue_depth_max=load.queue_depth_max,
            occupancy_mean=load.occupancy_mean, backlog=load.backlog,
            p50_s=load.p50_s, p95_s=load.p95_s, slots=load.slots,
            prefill_s=load.prefill_s + pf_s, decode_s=load.decode_s,
            mem_bytes=load.mem_bytes,
            prefill_backlog=backlog, migrations=migrations,
            free_pages=load.free_pages, total_pages=load.total_pages)

    # ------------------------------------------------------- control plane --
    def apply_decision(self, decision, *, controller=None,
                       engines_per_gpu: Optional[int] = None) -> bool:
        """The front's thin apply hook: resize the prefill-specialist set
        from ``Decision.prefill_gpus`` (same ``engines_per_gpu``
        granularity as the decode side), then delegate the decode-side
        split/slots to :meth:`RequestRouter.apply_decision` — which owns
        the staleness and single-application guards."""
        if decision is None or not decision.layout_changed:
            return False
        if engines_per_gpu is None:
            engines_per_gpu = max(int(getattr(controller,
                                              "gmi_per_gpu", 1)), 1)
        changed = self.router.apply_decision(
            decision, controller=controller,
            engines_per_gpu=engines_per_gpu)
        want = getattr(decision, "prefill_gpus", None)
        # the router's guards decide acceptance: a stale or already-
        # applied decision must not move the prefill set either
        accepted = controller is None \
            or decision is self.router._last_applied
        if want is not None and accepted:
            # a front always keeps >= 1 specialist: prefill_gpus == 0
            # means the controller wants pure local prefill, which the
            # planner implements per-request; one engine stays warm
            n = max(int(want) * engines_per_gpu, 1)
            changed = self._scale_prefill(n) or changed
            if controller is not None and want > 0:
                # reconcile a front that could not follow (no factory)
                achieved = max(len(self.prefill_engines)
                               // engines_per_gpu, 1)
                if achieved != controller.prefill_gpus:
                    controller.prefill_gpus = achieved
        return changed

    def maybe_replan(self, controller, *,
                     engines_per_gpu: Optional[int] = None) -> bool:
        """Standalone observe-then-apply (no runner); the runner-driven
        path calls ``observe_serving`` + :meth:`apply_decision` itself."""
        decision = controller.observe_serving(self.take_epoch())
        return self.apply_decision(decision, controller=controller,
                                   engines_per_gpu=engines_per_gpu)

    def _scale_prefill(self, n: int) -> bool:
        n = max(int(n), 1)
        before = len(self.prefill_engines)
        while len(self.prefill_engines) < n:
            if self._prefill_factory is None:
                break
            self.prefill_engines.append(
                self._prefill_factory(self._spawned))
            self._spawned += 1
        while len(self.prefill_engines) > n:
            retiree = self.prefill_engines.pop()
            for req in retiree.take_queue():
                self._requeue(req)
        return len(self.prefill_engines) != before

    # ---------------------------------------------------------------- fault --
    def _requeue(self, req: Request) -> None:
        """Re-route a request whose prefill never finished: a surviving
        specialist re-prefills it, or it falls back to the decode side's
        local-prefill path.  Latency clocks ride ``req._submit_t``."""
        if self.prefill_engines:
            eng = min(self.prefill_engines, key=lambda e: e.load)
            eng.submit(req, submit_t=getattr(req, "_submit_t", None))
        else:
            self.router._resubmit(req)

    def fail_prefill_engine(self, engine: PrefillEngine) -> int:
        """Remove a DEAD prefill specialist losslessly: its queued
        prompts re-route (:meth:`_requeue`) and its in-flight cache
        transfers — payloads staged in the channel whose device buffers
        died with the source — are re-prefilled from the original
        request.  Zero requests lost, extending PR 6's invariant to the
        prefill role.  Returns the number of re-routed requests."""
        if engine not in self.prefill_engines:
            return 0
        self.prefill_engines.remove(engine)
        self.failed_prefill_engines += 1
        queued = engine.take_queue()
        lost = self.channel.fail_source(engine)
        if not self.prefill_engines and self._prefill_factory is not None:
            self.prefill_engines.append(self._prefill_factory(self._spawned))
            self._spawned += 1
        for req in queued:
            self._requeue(req)
        for payload in lost:
            req = payload.req
            req._submit_t = payload.submit_t
            self._requeue(req)
        return len(queued) + len(lost)
