"""Small shared utilities (pytree helpers, rng, dtype policy)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def count_params(params) -> int:
    return tree_size(params)


def assert_finite(tree, name: str = "tree"):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if not bool(jnp.all(jnp.isfinite(leaf))):
            raise AssertionError(f"non-finite values in {name}{jax.tree_util.keystr(path)}")


def he_init(key, shape, dtype=jnp.float32, fan_in=None):
    fan = fan_in or shape[0]
    return jax.random.normal(key, shape, dtype) * (2.0 / fan) ** 0.5


def lecun_init(key, shape, dtype=jnp.float32, fan_in=None):
    fan = fan_in or shape[0]
    return jax.random.normal(key, shape, dtype) * (1.0 / fan) ** 0.5
