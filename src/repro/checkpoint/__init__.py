from repro.checkpoint.ckpt import latest_step, load, save  # noqa: F401
