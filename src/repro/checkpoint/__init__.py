from repro.checkpoint.ckpt import (latest_step, load, load_manifest,  # noqa: F401
                                   save, steps)
