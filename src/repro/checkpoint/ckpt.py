"""Pytree checkpointing: flattened-keypath .npz + JSON treedef manifest.

Sharding-aware restore: pass a sharding pytree and leaves are device_put
shard-by-shard (host-side slicing would be needed for true multi-host; on a
single controller device_put with a NamedSharding suffices).

Crash safety (the preemption-tolerance contract the fault subsystem
builds on):

* Writes are atomic and ORDERED: the ``.npz`` is written to a temp file
  and ``os.replace``d into place BEFORE the ``.json`` manifest (itself
  temp+replace).  A crash at any point therefore leaves either (a) the
  previous checkpoint pair intact, or (b) a new ``.npz`` with no
  manifest — never a manifest pointing at a missing or torn array file.
* ``latest_step``/``steps`` skip manifests whose ``.npz`` is absent
  (externally deleted, or written by a pre-hardening saver).
* ``load`` raises a clear error — never returns garbage — on a missing
  or torn (truncated/unreadable) array file, so callers can fall back to
  the previous step (see ``AsyncRunner.restore``).

``fault_hook`` is the deterministic-injection seam used by
``repro.fault``: it is called with a stage name at every durability
boundary and may raise to simulate a crash exactly there.
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

# stages at which a crash can be injected, in write order
SAVE_STAGES = ("before_npz", "before_npz_replace", "before_manifest",
               "before_manifest_replace")


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def _fire(fault_hook: Optional[Callable[[str], None]], stage: str) -> None:
    if fault_hook is not None:
        fault_hook(stage)


def save(path: str, tree, step: Optional[int] = None,
         extra: Optional[Dict[str, Any]] = None,
         fault_hook: Optional[Callable[[str], None]] = None):
    """Atomically write ``path``.npz (arrays) then ``path``.json
    (manifest).  ``extra`` is a JSON-serializable dict stored in the
    manifest (e.g. controller tables, counters); read it back with
    :func:`load_manifest`."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays = {f"arr_{i}": np.asarray(jax.device_get(v))
              for i, v in enumerate(flat.values())}
    manifest: Dict[str, Any] = {"keys": list(flat.keys()), "step": step}
    if extra is not None:
        manifest["extra"] = extra
    tmp_npz = path + ".tmp.npz"
    tmp_json = path + ".json.tmp"
    _fire(fault_hook, "before_npz")
    np.savez(tmp_npz, **arrays)
    # the array file must be durable BEFORE any manifest names it: a crash
    # between the two replaces leaves an orphan .npz (harmless), never a
    # manifest pointing at a missing/torn array file
    _fire(fault_hook, "before_npz_replace")
    os.replace(tmp_npz, path + ".npz")
    _fire(fault_hook, "before_manifest")
    with open(tmp_json, "w") as f:
        json.dump(manifest, f)
    _fire(fault_hook, "before_manifest_replace")
    os.replace(tmp_json, path + ".json")


def load_manifest(path: str) -> Dict[str, Any]:
    with open(path + ".json") as f:
        return json.load(f)


def load(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree template).

    Raises ``FileNotFoundError`` when the manifest's array file is
    absent and ``ValueError`` when it is torn/unreadable — callers that
    keep a checkpoint history can fall back to the previous step."""
    manifest = load_manifest(path)
    npz = path + ".npz"
    if not os.path.exists(npz):
        raise FileNotFoundError(
            f"checkpoint {path}: manifest present but array file {npz} "
            "is missing (torn pair)")
    try:
        data = np.load(npz)
    except Exception as e:
        raise ValueError(
            f"checkpoint {path}: array file unreadable (torn write?): "
            f"{e!r}") from e
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    by_key = {jax.tree_util.keystr(p): i for i, (p, _) in
              enumerate(flat_like)}
    leaves = [None] * len(flat_like)
    for i, key in enumerate(manifest["keys"]):
        if key not in by_key:
            raise KeyError(f"checkpoint key {key} not in template")
        try:
            leaves[by_key[key]] = data[f"arr_{i}"]
        except Exception as e:
            raise ValueError(
                f"checkpoint {path}: array {i} ({key}) unreadable "
                f"(torn write?): {e!r}") from e
    if any(x is None for x in leaves):
        missing = [k for k, i in by_key.items() if leaves[i] is None]
        raise KeyError(f"template keys missing from checkpoint: {missing}")
    tmpl_leaves = [l for _, l in flat_like]
    leaves = [np.asarray(x, dtype=t.dtype) for x, t in
              zip(leaves, tmpl_leaves)]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: hasattr(x, "device_set"))[0]
        leaves = [jax.device_put(x, s) for x, s in zip(leaves, sh_leaves)]
    else:
        leaves = [jnp.asarray(x) for x in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def steps(directory: str) -> List[int]:
    """Checkpoint steps present in ``directory``, ascending.  A manifest
    whose ``.npz`` is absent (torn pair) is skipped — it can never load."""
    found = []
    if not os.path.isdir(directory):
        return []
    for name in os.listdir(directory):
        if name.startswith("ckpt_") and name.endswith(".json"):
            try:
                s = int(name[5:-5])
            except ValueError:
                continue
            if os.path.exists(os.path.join(directory, f"ckpt_{s}.npz")):
                found.append(s)
    return sorted(found)


def latest_step(directory: str) -> Optional[int]:
    all_steps = steps(directory)
    return all_steps[-1] if all_steps else None
