"""Pytree checkpointing: flattened-keypath .npz + JSON treedef manifest.

Sharding-aware restore: pass a sharding pytree and leaves are device_put
shard-by-shard (host-side slicing would be needed for true multi-host; on a
single controller device_put with a NamedSharding suffices).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(path: str, tree, step: Optional[int] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays = {f"arr_{i}": np.asarray(jax.device_get(v))
              for i, v in enumerate(flat.values())}
    manifest = {"keys": list(flat.keys()), "step": step}
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def load(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree template)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    by_key = {jax.tree_util.keystr(p): i for i, (p, _) in
              enumerate(flat_like)}
    leaves = [None] * len(flat_like)
    for i, key in enumerate(manifest["keys"]):
        if key not in by_key:
            raise KeyError(f"checkpoint key {key} not in template")
        leaves[by_key[key]] = data[f"arr_{i}"]
    if any(x is None for x in leaves):
        missing = [k for k, i in by_key.items() if leaves[i] is None]
        raise KeyError(f"template keys missing from checkpoint: {missing}")
    tmpl_leaves = [l for _, l in flat_like]
    leaves = [np.asarray(x, dtype=t.dtype) for x, t in
              zip(leaves, tmpl_leaves)]
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: hasattr(x, "device_set"))[0]
        leaves = [jax.device_put(x, s) for x, s in zip(leaves, sh_leaves)]
    else:
        leaves = [jnp.asarray(x) for x in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def latest_step(directory: str) -> Optional[int]:
    steps = []
    if not os.path.isdir(directory):
        return None
    for name in os.listdir(directory):
        if name.startswith("ckpt_") and name.endswith(".json"):
            try:
                steps.append(int(name[5:-5]))
            except ValueError:
                pass
    return max(steps) if steps else None
