"""Articulated-chain rigid-body dynamics core.

A deliberately non-GEMM workload (transcendental-heavy, sequential substeps,
branchy contacts) mirroring the paper's observation that physics simulation
scales poorly on matrix-unit-centric accelerators: this is the component that
leaves the MXU idle and motivates spatial multiplexing.

Model: J torque-controlled joints in a kinematic chain attached to a floating
root.  Per substep (semi-implicit Euler):
  qdd_i = (tau_i - damping*qd_i - g*m_i*l_i*sin(q_i)
           + coupling*(q_{i-1} - 2 q_i + q_{i+1})) / I_i
with ground contact on the chain tip (one-sided spring-damper) and root
dynamics driven by net joint reaction.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ChainParams(NamedTuple):
    masses: jax.Array     # (J,)
    lengths: jax.Array    # (J,)
    damping: float
    coupling: float
    stiffness: float      # restoring spring toward q=0 (joint limits)
    max_qd: float
    gravity: float
    torque_scale: float
    ground_k: float       # contact spring
    ground_c: float       # contact damper


def default_params(num_joints: int, *, damping=0.5, coupling=0.6,
                   stiffness=2.0, max_qd=8.0, gravity=9.81, torque_scale=3.0,
                   ground_k=60.0, ground_c=2.0) -> ChainParams:
    idx = jnp.arange(num_joints, dtype=jnp.float32)
    masses = 1.0 + 0.15 * jnp.cos(idx)
    lengths = 0.35 + 0.05 * jnp.sin(1.7 * idx)
    return ChainParams(masses, lengths, damping, coupling, stiffness, max_qd,
                       gravity, torque_scale, ground_k, ground_c)


def tip_height(q, root_z, params: ChainParams):
    """Height of the chain tip (forward kinematics along the chain)."""
    angles = jnp.cumsum(q)
    return root_z + jnp.sum(params.lengths * jnp.cos(angles))


# --------------------------------------------------- counter-based PRNG ---
# Auto-reset used to thread a threefry key through every env state and pay
# a ``jax.random.split`` + ``normal`` per env per step whether or not the
# env was done.  Fresh states are instead a pure function of a per-env
# ``seed`` and a ``resets`` counter: an integer-hash (Murmur3 finalizer)
# feeding Box-Muller.  Every op below (xor/shift/mul on uint32, sqrt, log,
# cos) maps 1:1 onto Pallas-supported primitives, so the vmapped oracle
# reset and the megakernel's in-kernel reset produce identical values.

def hash_u32(x):
    """Murmur3 fmix32: a well-mixed uint32 -> uint32 bijection."""
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def counter_normal(seed, counter, idx):
    """Standard normals, one per (seed, counter, idx) triple.

    ``seed``/``counter``/``idx`` broadcast together; callers supply
    ``idx`` (e.g. ``jnp.arange(J, dtype=jnp.uint32)`` outside a kernel,
    ``broadcasted_iota`` inside one).  Deterministic and split-free: the
    same triple always yields the same draw, so a materialized reset and
    a predicated in-kernel reset agree bitwise."""
    s = jnp.asarray(seed, jnp.uint32)
    c = jnp.asarray(counter, jnp.uint32)
    i = jnp.asarray(idx, jnp.uint32)
    base = hash_u32(s ^ (c * jnp.uint32(0x9E3779B9)))
    h1 = hash_u32(base + i * jnp.uint32(2) + jnp.uint32(1))
    h2 = hash_u32(base + i * jnp.uint32(2) + jnp.uint32(2))
    # 24-bit mantissa uniforms; u1 offset into (0, 1] so log never sees 0
    u1 = (h1 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / 16777216.0) \
        + (0.5 / 16777216.0)
    u2 = (h2 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / 16777216.0)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos((2.0 * jnp.pi) * u2)


def substep(q, qd, root, tau, params: ChainParams, dt: float):
    J = q.shape[0]
    # neighbor coupling (tridiagonal spring network)
    q_pad = jnp.pad(q, (1, 1), mode="edge")
    lap = q_pad[:-2] - 2.0 * q + q_pad[2:]
    inertia = params.masses * jnp.square(params.lengths) + 1e-3
    grav = params.gravity * params.masses * params.lengths * jnp.sin(q)
    qdd = (params.torque_scale * tau - params.damping * qd
           - params.stiffness * q - grav + params.coupling * lap) / inertia
    qd = jnp.clip(qd + dt * qdd, -params.max_qd, params.max_qd)
    q = q + dt * qd

    # root: driven by mean joint reaction, with ground contact at tip
    tip_h = tip_height(q, root[2], params)
    pen = jnp.maximum(-tip_h, 0.0)
    contact_f = params.ground_k * pen - params.ground_c * jnp.minimum(
        root[5], 0.0) * (pen > 0)
    thrust = jnp.array([
        jnp.mean(jnp.sin(q) * tau) * params.torque_scale,   # forward
        0.1 * jnp.mean(jnp.cos(2 * q) * tau),               # lateral drift
        contact_f - params.gravity * 0.5,                   # vertical
    ])
    vel = root[3:] + dt * thrust
    vel = vel * (1.0 - 0.02)                                # air drag
    pos = root[:3] + dt * vel
    pos = pos.at[2].set(jnp.maximum(pos[2], 0.05))
    return q, qd, jnp.concatenate([pos, vel])


def rollout_substeps(q, qd, root, tau, params: ChainParams, dt: float,
                     substeps: int):
    def body(i, carry):
        q, qd, root = carry
        return substep(q, qd, root, tau, params, dt / substeps)
    return jax.lax.fori_loop(0, substeps, body, (q, qd, root))
