"""Vmapped multi-agent env family: K agents sharing one chain world.

A *world* is a single articulated chain of ``K * act_dim`` joints whose
root body every agent shares.  Agent ``k`` drives the contiguous joint
block ``[k*J, (k+1)*J)`` — the chain's neighbor-coupling term physically
links each agent's boundary joint to the next agent's, so actions
propagate across agents through the shared dynamics (no broadcast, no
message passing: it is one simulation).  Per-agent observation/reward
slices reuse the *single-agent* feature layout: agent ``k`` observes the
shared root plus its own joint block, so ``raw_dim`` (and therefore the
Table-6 sensor projection and policy dims) is identical to the
single-agent family — one policy serves both.

The point for the GMI controller: ``num_envs`` counts AGENTS, so every
single-agent num_env ladder rung ``n`` gains the rungs ``n * K`` for
every agent count ``K`` with zero controller changes —
``selection.explore`` and Algorithm 2 see just a bigger env count.
World auto-reset is counter-based exactly like ``envs/base.py``: a fresh
world is a pure function of ``(seed, resets + 1)``, and a world-level
``done`` (episode cap or root fall) resets ALL of the world's agents
together.

This family is vmap-path only (the megakernel rides the single-agent
family); ``with_megakernel(True)`` raises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import EnvState, derive_seeds
from repro.envs.physics import (counter_normal, default_params,
                                rollout_substeps, tip_height)
from repro.envs.suite import SPECS, _TASK, _sensor_matrix


class MultiAgentVectorEnv:
    """Duck-typed :class:`~repro.envs.base.VectorEnv`: same
    ``reset``/``step`` surface over (num_envs, ...) agent-major arrays,
    but agents come in groups of ``num_agents`` sharing a world."""

    megakernel = False

    def __init__(self, name: str, num_agents: int = 2):
        assert num_agents >= 1
        import numpy as np
        spec = SPECS[name]
        self.spec = spec
        self.num_agents = K = int(num_agents)
        J = spec.act_dim
        Jw = K * J
        params = default_params(Jw)
        w_fwd, w_up, w_ctrl, w_tgt, fall_z = _TASK[name]
        tgt = jnp.asarray(np.random.RandomState(7).uniform(
            -0.6, 0.6, size=(J,)).astype(np.float32))
        raw_dim = 6 + 4 * J + 3
        sensor = _sensor_matrix(name, raw_dim, spec.obs_dim)

        def reset_world(seed, resets) -> EnvState:
            q0 = 0.1 * counter_normal(seed, resets,
                                      jnp.arange(Jw, dtype=jnp.uint32))
            return EnvState(
                q=q0, qd=jnp.zeros((Jw,)),
                root=jnp.array([0., 0., 0.6, 0., 0., 0.]),
                prev_action=jnp.zeros((Jw,)),
                t=jnp.zeros((), jnp.int32),
                seed=jnp.asarray(seed, jnp.int32),
                resets=jnp.asarray(resets, jnp.int32))

        def obs_world(state: EnvState):
            """(K, obs_dim): shared root + per-agent joint block through
            the single-agent sensor projection."""
            qk = state.q.reshape(K, J)
            qdk = state.qd.reshape(K, J)
            pak = state.prev_action.reshape(K, J)
            tip = tip_height(state.q, state.root[2], params)
            ones = jnp.ones((K,))
            raw = jnp.concatenate([
                jnp.tile(state.root, (K, 1)),
                jnp.sin(qk), jnp.cos(qk), qdk, pak,
                jnp.stack([tip * ones, (state.root[2] - 0.6) * ones,
                           jnp.mean(jnp.abs(qdk), axis=1)], axis=1),
            ], axis=1)
            return jnp.tanh(raw @ sensor)

        def step_world(state: EnvState, action):
            """action (K*J,) -> (state, reward (K,), done scalar)."""
            a = jnp.clip(action, -1.0, 1.0)
            q, qd, root = rollout_substeps(state.q, state.qd, state.root,
                                           a, params, spec.dt,
                                           spec.substeps)
            qk = q.reshape(K, J)
            ak = a.reshape(K, J)
            reward = (w_fwd * root[3]
                      + w_up * jnp.cos(jnp.mean(qk, axis=1))
                      - w_ctrl * jnp.sum(jnp.square(ak), axis=1)
                      - w_tgt * jnp.mean(jnp.square(qk - tgt), axis=1)
                      + 0.5)
            t = state.t + 1
            done = (t >= spec.max_episode_len) | (root[2] < fall_z)
            new_state = EnvState(q=q, qd=qd, root=root, prev_action=a, t=t,
                                 seed=state.seed, resets=state.resets)
            fresh = reset_world(new_state.seed, new_state.resets + 1)
            out = jax.tree.map(lambda x, y: jnp.where(done, y, x),
                               new_state, fresh)
            return out, reward, done

        self._reset_world = reset_world
        self._reset = jax.vmap(reset_world)
        self._step = jax.vmap(step_world)
        self._obs = jax.vmap(obs_world)

    def _check(self, num_envs: int) -> int:
        if num_envs % self.num_agents:
            raise ValueError(
                f"num_envs={num_envs} must be a multiple of "
                f"num_agents={self.num_agents} (agents share worlds)")
        return num_envs // self.num_agents

    def with_megakernel(self, flag: bool = True) -> "MultiAgentVectorEnv":
        if flag:
            raise ValueError("the multi-agent family is vmap-only; the "
                             "megakernel path rides the single-agent "
                             "suite (envs.make_env(megakernel=True))")
        return self

    def reset(self, key, num_envs: int):
        W = self._check(num_envs)
        seeds = derive_seeds(key, W)
        state = self._reset(seeds, jnp.zeros((W,), jnp.int32))
        obs = self._obs(state)                         # (W, K, obs_dim)
        return state, obs.reshape(num_envs, -1)

    def step(self, state, action):
        """action (num_envs, act_dim) agent-major -> (state, obs, reward,
        done), the per-agent views of the shared-world transition (done
        is the world's, broadcast to its K agents)."""
        W = state.q.shape[0]
        K = self.num_agents
        state, reward, done = self._step(
            state, action.reshape(W, K * self.spec.act_dim))
        obs = self._obs(state).reshape(W * K, -1)
        return (state, obs, reward.reshape(-1),
                jnp.repeat(done, K))


def make_multi_agent_env(name: str, num_agents: int = 2) \
        -> MultiAgentVectorEnv:
    """K-agent shared-world variant of ``suite.make_env(name)``."""
    return MultiAgentVectorEnv(name, num_agents)
