"""The paper's six DRL benchmarks (Table 6) as vectorized JAX environments.

| name          | abbr | type | obs | policy (Table 6)        | act |
| Ant           | AT   | L    |  60 | 60:256:128:64:8         |  8  |
| Anymal        | AY   | L    |  48 | 48:256:128:64:12        | 12  |
| BallBalance   | BB   | L    |  24 | 24:256:128:64:3         |  3  |
| FrankaCabinet | FC   | F    |  23 | 23:256:128:64:9         |  9  |
| Humanoid      | HM   | L    | 108 | 108:200:400:100:21      | 21  |
| ShadowHand    | SH   | R    | 211 | 211:512:512:512:256:20  | 20  |

Each env drives the articulated-chain core with task-specific parameters,
reward shaping, and a fixed orthonormal "sensor mixing" projection that maps
raw physical features to exactly the published observation dimension.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.envs.base import EnvSpec, EnvState, MegaConsts, VectorEnv
from repro.envs.physics import (counter_normal, default_params,
                                rollout_substeps, tip_height)

SPECS = {
    "Ant":           EnvSpec("Ant", "AT", 60, 8, "L", (60, 256, 128, 64, 8)),
    "Anymal":        EnvSpec("Anymal", "AY", 48, 12, "L", (48, 256, 128, 64, 12)),
    "BallBalance":   EnvSpec("BallBalance", "BB", 24, 3, "L", (24, 256, 128, 64, 3)),
    "FrankaCabinet": EnvSpec("FrankaCabinet", "FC", 23, 9, "F", (23, 256, 128, 64, 9)),
    "Humanoid":      EnvSpec("Humanoid", "HM", 108, 21, "L", (108, 200, 400, 100, 21)),
    "ShadowHand":    EnvSpec("ShadowHand", "SH", 211, 20, "R", (211, 512, 512, 512, 256, 20)),
}

_TASK = {
    # (w_forward, w_upright, w_ctrl, w_target, fall_z)
    "Ant":           (1.0, 0.2, 0.005, 0.0, 0.12),
    "Anymal":        (1.0, 0.4, 0.01, 0.0, 0.15),
    "BallBalance":   (0.0, 0.0, 0.002, 1.0, -1.0),
    "FrankaCabinet": (0.0, 0.0, 0.005, 1.5, -1.0),
    "Humanoid":      (1.2, 0.6, 0.01, 0.0, 0.25),
    "ShadowHand":    (0.0, 0.0, 0.002, 2.0, -1.0),
}


def _sensor_matrix(name: str, raw_dim: int, obs_dim: int) -> jnp.ndarray:
    """Fixed orthonormal-ish projection raw -> obs (deterministic per env)."""
    seed = abs(hash(name)) % (2 ** 31)
    rng = np.random.RandomState(seed)
    m = rng.randn(raw_dim, obs_dim).astype(np.float32)
    # orthonormalize columns where possible for a well-conditioned sensor map
    q, _ = np.linalg.qr(m) if raw_dim >= obs_dim else np.linalg.qr(m.T)
    out = q[:, :obs_dim] if raw_dim >= obs_dim else q[:, :raw_dim].T
    return jnp.asarray(out * np.sqrt(2.0))


def make_env(name: str, megakernel: bool = False) -> VectorEnv:
    spec = SPECS[name]
    J = spec.act_dim
    params = default_params(J)
    w_fwd, w_up, w_ctrl, w_tgt, fall_z = _TASK[name]
    # task target configuration (manipulation tasks track it)
    tgt = jnp.asarray(np.random.RandomState(7).uniform(
        -0.6, 0.6, size=(J,)).astype(np.float32))
    raw_dim = 6 + 4 * J + 3          # root + sinq/cosq/qd/prev_act + extras
    sensor = _sensor_matrix(name, raw_dim, spec.obs_dim)

    def reset_fn(seed, resets) -> EnvState:
        # fresh state as a pure function of (seed, resets): shared with the
        # megakernel's predicated in-kernel reset, draw for draw
        q0 = 0.1 * counter_normal(seed, resets,
                                  jnp.arange(J, dtype=jnp.uint32))
        return EnvState(
            q=q0,
            qd=jnp.zeros((J,)),
            root=jnp.array([0., 0., 0.6, 0., 0., 0.]),
            prev_action=jnp.zeros((J,)),
            t=jnp.zeros((), jnp.int32),
            seed=jnp.asarray(seed, jnp.int32),
            resets=jnp.asarray(resets, jnp.int32))

    def obs_fn(state: EnvState):
        tip = tip_height(state.q, state.root[2], params)
        raw = jnp.concatenate([
            state.root,
            jnp.sin(state.q), jnp.cos(state.q), state.qd,
            state.prev_action,
            jnp.array([tip, state.root[2] - 0.6,
                       jnp.mean(jnp.abs(state.qd))]),
        ])
        return jnp.tanh(raw @ sensor)

    def step_fn(state: EnvState, action):
        a = jnp.clip(action, -1.0, 1.0)
        q, qd, root = rollout_substeps(state.q, state.qd, state.root, a,
                                       params, spec.dt, spec.substeps)
        upright = jnp.cos(jnp.mean(q))
        reward = (w_fwd * root[3]
                  + w_up * upright
                  - w_ctrl * jnp.sum(jnp.square(a))
                  - w_tgt * jnp.mean(jnp.square(q - tgt))
                  + 0.5)                                     # alive bonus
        t = state.t + 1
        fell = root[2] < fall_z
        done = (t >= spec.max_episode_len) | fell
        new_state = EnvState(q=q, qd=qd, root=root, prev_action=a, t=t,
                             seed=state.seed, resets=state.resets)
        return new_state, reward, done

    mega = MegaConsts(
        sensor=sensor, tgt=tgt, masses=params.masses, lengths=params.lengths,
        chain=(params.damping, params.coupling, params.stiffness,
               params.max_qd, params.gravity, params.torque_scale,
               params.ground_k, params.ground_c),
        task=(w_fwd, w_up, w_ctrl, w_tgt, fall_z))
    return VectorEnv(spec, reset_fn, step_fn, obs_fn, mega=mega,
                     megakernel=megakernel)


def all_env_names():
    return list(SPECS.keys())
