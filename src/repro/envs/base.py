"""Vectorized environment API.

Environments are pure functions over explicit state pytrees so thousands of
instances run in parallel under ``vmap`` + ``jit`` — the JAX analogue of
Isaac Gym's massively-parallel GPU simulation (the paper's workload).

Env randomness is counter-based (``physics.counter_normal``): each env
carries an int32 ``seed`` plus a ``resets`` counter instead of a threefry
key, so a fresh post-``done`` state is a pure function of ``(seed,
resets + 1)`` — no per-step ``jax.random.split``, and the same fresh state
whether the reset is materialized every step (the vmap oracle path) or
computed only under a ``done`` predicate (the fused megakernel path,
``kernels/env_megakernel.py``).

Slot-write contract (megakernel -> channel ring)
------------------------------------------------
``VectorEnv(megakernel=True)`` steps through one fused program and, via
``rl.rollout.collect_ring``, produces experience directly into the
``ChannelRing`` slot layout owned by ``kernels/channel_pack.py``: step
``t`` of a rollout in ring slot ``s`` writes obs/action/reward/done for
env block ``[s*N, (s+1)*N)`` at row ``t`` — the producer-side zero-copy
path that retires the stage-a-Trajectory-then-``pack_channels`` double
copy.  ``MegaConsts`` carries the per-env-family constants (sensor
projection, task target, chain geometry, reward weights) the fused
kernels need alongside the state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class EnvState(NamedTuple):
    q: jax.Array          # (J,) joint angles
    qd: jax.Array         # (J,) joint velocities
    root: jax.Array       # (6,) x, y, z, vx, vy, vz
    prev_action: jax.Array
    t: jax.Array          # scalar int32 step counter
    seed: jax.Array       # scalar int32 per-env PRNG stream id
    resets: jax.Array     # scalar int32 auto-reset counter


@dataclass(frozen=True)
class EnvSpec:
    name: str
    abbr: str
    obs_dim: int
    act_dim: int
    env_type: str                 # L (locomotion) | F (franka) | R (robotic hand)
    policy_dims: tuple            # paper Table 6
    max_episode_len: int = 1000
    substeps: int = 4
    dt: float = 1.0 / 60.0


@dataclass(frozen=True)
class MegaConsts:
    """Constant operands of the fused env step (megakernel + oracle)."""
    sensor: jax.Array     # (raw_dim, obs_dim) fixed sensor projection
    tgt: jax.Array        # (J,) task target configuration
    masses: jax.Array     # (J,) chain link masses
    lengths: jax.Array    # (J,) chain link lengths
    chain: tuple          # (damping, coupling, stiffness, max_qd, gravity,
                          #  torque_scale, ground_k, ground_c) — static floats
    task: tuple           # (w_forward, w_upright, w_ctrl, w_target, fall_z)


def derive_seeds(key, num_envs: int):
    """Per-env int32 stream ids from one PRNG key (reset-time only)."""
    return jax.random.randint(key, (num_envs,), 0,
                              jnp.iinfo(jnp.int32).max, dtype=jnp.int32)


class VectorEnv:
    """Batched env: all methods operate on (N, ...) stacked states.

    ``megakernel=False`` (default): the oracle baseline — per-env
    ``step_fn`` under ``vmap`` with a *materialized* auto-reset (a fresh
    state is computed for every env every step and selected by
    ``jnp.where(done)``).

    ``megakernel=True``: ``step`` runs the fused batched program from
    ``kernels/env_megakernel.py`` — substep loop + reward + episode
    bookkeeping + *predicated* auto-reset (fresh states computed only
    when some env is done) + observation in one jitted dispatch.  Both
    paths share the counter-based reset, so trajectories agree to fp
    tolerance and post-``done`` states agree exactly.
    """

    def __init__(self, spec: EnvSpec, reset_fn: Callable, step_fn: Callable,
                 obs_fn: Callable, mega: Optional[MegaConsts] = None,
                 megakernel: bool = False):
        self.spec = spec
        self.mega = mega
        self.megakernel = bool(megakernel)
        if self.megakernel and mega is None:
            raise ValueError("megakernel=True needs MegaConsts (mega=...); "
                             "suite.make_env builds them")
        self._reset_fn = reset_fn
        self._step_fn = step_fn
        self._obs_fn = obs_fn
        self._reset = jax.vmap(reset_fn)
        self._obs = jax.vmap(obs_fn)

        def step_one(state, action):
            new_state, reward, done = step_fn(state, action)
            # materialized auto-reset: the fresh state is a pure function
            # of (seed, resets+1), computed unconditionally and selected
            fresh = reset_fn(new_state.seed, new_state.resets + 1)
            # scalar `done` broadcasts against every leaf shape
            out = jax.tree.map(lambda a, b: jnp.where(done, b, a),
                               new_state, fresh)
            return out, reward, done

        self._step = jax.vmap(step_one)

    def with_megakernel(self, flag: bool = True) -> "VectorEnv":
        """The same env family on the other step path (shared fns)."""
        return VectorEnv(self.spec, self._reset_fn, self._step_fn,
                         self._obs_fn, mega=self.mega, megakernel=flag)

    def reset(self, key, num_envs: int):
        seeds = derive_seeds(key, num_envs)
        state = self._reset(seeds, jnp.zeros((num_envs,), jnp.int32))
        return state, self._obs(state)

    def step(self, state, action):
        """-> (state, obs, reward, done)."""
        if self.megakernel:
            from repro.kernels.env_megakernel import mega_step
            mc = self.mega
            out = mega_step(*state, action, mc.sensor, mc.tgt, mc.masses,
                            mc.lengths, chain=mc.chain, task=mc.task,
                            substeps=self.spec.substeps, dt=self.spec.dt,
                            max_episode_len=self.spec.max_episode_len)
            q, qd, root, pa, t, seed, resets, obs, reward, done = out
            return (EnvState(q, qd, root, pa, t, seed, resets), obs,
                    reward, done)
        state, reward, done = self._step(state, action)
        return state, self._obs(state), reward, done
