"""Vectorized environment API.

Environments are pure functions over explicit state pytrees so thousands of
instances run in parallel under ``vmap`` + ``jit`` — the JAX analogue of
Isaac Gym's massively-parallel GPU simulation (the paper's workload).

Env keys are legacy uint32 PRNG vectors so states stay plain-array pytrees
(selectable with ``jnp.where`` during auto-reset).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class EnvState(NamedTuple):
    q: jax.Array          # (J,) joint angles
    qd: jax.Array         # (J,) joint velocities
    root: jax.Array       # (6,) x, y, z, vx, vy, vz
    prev_action: jax.Array
    t: jax.Array          # scalar int32 step counter
    key: jax.Array        # (2,) uint32 legacy PRNG key


@dataclass(frozen=True)
class EnvSpec:
    name: str
    abbr: str
    obs_dim: int
    act_dim: int
    env_type: str                 # L (locomotion) | F (franka) | R (robotic hand)
    policy_dims: tuple            # paper Table 6
    max_episode_len: int = 1000
    substeps: int = 4
    dt: float = 1.0 / 60.0


class VectorEnv:
    """Batched env: all methods operate on (N, ...) stacked states."""

    def __init__(self, spec: EnvSpec, reset_fn: Callable, step_fn: Callable,
                 obs_fn: Callable):
        self.spec = spec
        self._reset = jax.vmap(reset_fn)
        self._obs = jax.vmap(obs_fn)

        def step_one(state, action):
            new_state, reward, done = step_fn(state, action)
            rkey, nkey = jax.random.split(new_state.key)
            fresh = reset_fn(rkey)._replace(key=nkey)
            # scalar `done` broadcasts against every leaf shape
            out = jax.tree.map(lambda a, b: jnp.where(done, b, a),
                               new_state, fresh)
            return out, reward, done

        self._step = jax.vmap(step_one)

    def reset(self, key, num_envs: int):
        keys = jax.random.split(key, num_envs)
        state = self._reset(keys)
        return state, self._obs(state)

    def step(self, state, action):
        """-> (state, obs, reward, done)."""
        state, reward, done = self._step(state, action)
        return state, self._obs(state), reward, done
