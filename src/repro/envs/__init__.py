from repro.envs.base import (EnvSpec, EnvState, MegaConsts,  # noqa: F401
                             VectorEnv, derive_seeds)
from repro.envs.multi_agent import (MultiAgentVectorEnv,  # noqa: F401
                                    make_multi_agent_env)
from repro.envs.suite import SPECS, all_env_names, make_env  # noqa: F401
