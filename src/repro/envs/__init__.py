from repro.envs.base import EnvSpec, EnvState, VectorEnv  # noqa: F401
from repro.envs.suite import SPECS, all_env_names, make_env  # noqa: F401
