"""Multi-device distribution tests via subprocess (8 fake host devices).

A subprocess is mandatory: jax locks the device count at first init, and
the main pytest process must keep seeing ONE device (per the dry-run
contract)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_multidevice_suite():
    script = os.path.join(os.path.dirname(__file__), "_multidev_checks.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=900)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "MULTIDEV ALL OK" in proc.stdout
