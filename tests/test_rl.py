import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import make_env
from repro.rl.a3c import Experience, nstep_returns, staleness
from repro.rl.ppo import PPOConfig, init_train, make_train_step, ppo_loss
from repro.rl.rollout import collect, gae


def _naive_gae(rewards, values, dones, last_value, gamma, lam):
    T, N = rewards.shape
    advs = np.zeros((T, N), np.float32)
    adv = np.zeros(N, np.float32)
    v_next = np.asarray(last_value)
    for t in reversed(range(T)):
        nonterm = 1.0 - dones[t]
        delta = rewards[t] + gamma * v_next * nonterm - values[t]
        adv = delta + gamma * lam * nonterm * adv
        advs[t] = adv
        v_next = values[t]
    return advs


def test_gae_matches_naive_loop():
    key = jax.random.key(0)
    T, N = 12, 5
    ks = jax.random.split(key, 4)
    rewards = jax.random.normal(ks[0], (T, N))
    values = jax.random.normal(ks[1], (T, N))
    dones = (jax.random.uniform(ks[2], (T, N)) < 0.2).astype(jnp.float32)
    last_value = jax.random.normal(ks[3], (N,))
    advs, rets = gae(rewards, values, dones, last_value, 0.99, 0.95)
    want = _naive_gae(np.asarray(rewards), np.asarray(values),
                      np.asarray(dones), last_value, 0.99, 0.95)
    np.testing.assert_allclose(np.asarray(advs), want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rets), want + np.asarray(values),
                               rtol=1e-5, atol=1e-5)


def test_gae_lambda1_equals_mc_returns():
    T, N = 8, 3
    rewards = jnp.ones((T, N))
    values = jnp.zeros((T, N))
    dones = jnp.zeros((T, N))
    last_value = jnp.zeros((N,))
    advs, rets = gae(rewards, values, dones, last_value, gamma=1.0, lam=1.0)
    want = jnp.arange(T, 0, -1)[:, None] * jnp.ones((T, N))
    np.testing.assert_allclose(np.asarray(rets), np.asarray(want), rtol=1e-6)


def test_nstep_returns_bootstrap():
    rewards = jnp.zeros((3, 2))
    dones = jnp.zeros((3, 2))
    boot = jnp.array([1.0, 2.0])
    rets = nstep_returns(rewards, dones, boot, gamma=0.5)
    np.testing.assert_allclose(np.asarray(rets[0]), [0.125, 0.25], rtol=1e-6)


def test_ppo_improves_on_ballbalance():
    env = make_env("BallBalance")
    cfg = PPOConfig(num_steps=16, num_epochs=2, num_minibatches=2, lr=1e-3)
    params, opt, est, obs = init_train(jax.random.key(0), env,
                                       env.spec.policy_dims, num_envs=128)
    step = make_train_step(env, cfg)
    k = jax.random.PRNGKey(0)
    rewards = []
    for _ in range(25):
        params, opt, est, obs, k, m = step(params, opt, est, obs, k)
        rewards.append(float(m["reward_mean"]))
    assert all(np.isfinite(rewards))
    assert np.mean(rewards[-5:]) > np.mean(rewards[:5]), rewards


def test_ppo_fused_kernels_improve_and_match_metric_shapes():
    """use_fused_kernels=True must train (reward goes up) and produce the
    exact metric tree of the unfused path."""
    env = make_env("BallBalance")
    base = PPOConfig(num_steps=16, num_epochs=2, num_minibatches=2, lr=1e-3)
    fused = base._replace(use_fused_kernels=True)
    params, opt, est, obs = init_train(jax.random.key(0), env,
                                       env.spec.policy_dims, num_envs=128)
    step_f = make_train_step(env, fused)
    k = jax.random.PRNGKey(0)
    rewards = []
    for _ in range(25):
        params, opt, est, obs, k, mf = step_f(params, opt, est, obs, k)
        rewards.append(float(mf["reward_mean"]))
    assert all(np.isfinite(rewards))
    assert np.mean(rewards[-5:]) > np.mean(rewards[:5]), rewards

    p2, o2, e2, ob2 = init_train(jax.random.key(1), env,
                                 env.spec.policy_dims, num_envs=128)
    step_u = make_train_step(env, base)
    *_, mu = step_u(p2, o2, e2, ob2, jax.random.PRNGKey(1))
    assert set(mf) == set(mu)
    assert all(mf[k_].shape == mu[k_].shape and mf[k_].dtype == mu[k_].dtype
               for k_ in mf)


def test_async_runner_fused_nstep_trains():
    """use_fused_kernels routes the trainer's n-step returns through the
    fused Pallas scan; training must stay finite and lossless."""
    from repro.rl.a3c import AsyncRunner
    env = make_env("Ant")
    runner = AsyncRunner(env, [0, 1], [100, 101],
                         gmi_gpu={0: 0, 1: 1, 100: 0, 101: 1},
                         num_envs=16, num_steps=8, use_fused_kernels=True)
    losses = []
    for _ in range(3):
        ls, stale = runner.round()
        losses += ls
    assert losses and all(np.isfinite(losses))
    assert runner.trained_samples == runner.predictions


def test_async_runner_over_ring_pipeline():
    from repro.rl.a3c import AsyncRunner
    env = make_env("Ant")
    runner = AsyncRunner(env, [0, 1], [100, 101],
                         gmi_gpu={0: 0, 1: 1, 100: 0, 101: 1},
                         num_envs=16, num_steps=8)
    losses = []
    for _ in range(3):
        ls, stale = runner.round()
        losses += ls
        assert all(s >= 0 for s in stale)
    assert losses and all(np.isfinite(losses))
    assert runner.trained_samples == runner.predictions  # nothing dropped
    # per-group routing fed BOTH trainers each flush
    assert runner.pipe.migrator.load[100] == runner.pipe.migrator.load[101]


def test_collect_shapes_and_logprob_consistency():
    from repro.models.policy import init_policy, log_prob, policy_apply
    env = make_env("Ant")
    params = init_policy(jax.random.key(1), env.spec.policy_dims)
    est, obs = env.reset(jax.random.PRNGKey(0), num_envs=8)
    traj, est, obs2, last_v, _ = collect(params, env, est, obs,
                                         jax.random.PRNGKey(2), 6)
    assert traj.obs.shape == (6, 8, env.spec.obs_dim)
    assert traj.actions.shape == (6, 8, env.spec.act_dim)
    mu, log_std, v = policy_apply(params, traj.obs)
    lp = log_prob(mu, log_std, traj.actions)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(traj.log_probs),
                               rtol=1e-4, atol=1e-4)


def test_staleness_counter():
    exp = Experience(obs=jnp.zeros((1, 1, 2)), actions=jnp.zeros((1, 1, 1)),
                     rewards=jnp.zeros((1, 1)), dones=jnp.zeros((1, 1)),
                     bootstrap=jnp.zeros((1,)), actor_version=jnp.int32(3))
    assert int(staleness(jnp.int32(7), exp)) == 4
