"""The benchmark regression gate and repo-hygiene guards.

``benchmarks/run.py::_check_regressions`` used to skip rows new to the
baseline AND silently ignore baseline rows absent from the fresh run —
deleting or renaming a bench hid its regression forever (the rewrite
dropped the old row).  These tests pin the gate's behavior for an added,
a removed, and a regressed row, plus the strict mode that turns missing
rows into failures; and they pin that no ``__pycache__``/``.pyc``
artifact is ever tracked again (it has happened twice: 8436fa0 removed
six, bd262a9 re-committed them)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from benchmarks.run import (REGRESSION_FACTOR,  # noqa: E402
                            _check_regressions)
from repro.analysis import run_analysis  # noqa: E402
from repro.analysis.project import TrackedBytecodeRule  # noqa: E402


def _write_baseline(path, rows):
    with open(path, "w") as f:
        json.dump({"suite": "x", "rows": [
            {"name": n, "us_per_call": us, "derived": ""}
            for n, us in rows]}, f)


@pytest.fixture
def baseline(tmp_path):
    path = str(tmp_path / "BENCH_x.json")
    _write_baseline(path, [("steady", 100.0), ("regressor", 100.0),
                           ("removed", 100.0), ("ratio", 0.0)])
    return path


# fresh run: steady row fine, regressor 3x slower, "removed" gone,
# "added" new to this baseline, ratio row still a ratio row
FRESH = ["steady,110.0,ok",
         f"regressor,{100.0 * REGRESSION_FACTOR * 1.5},bad",
         "added,10.0,new",
         "ratio,0.0,still_a_ratio"]


def test_gate_regressed_row_flagged(baseline):
    regs, missing = _check_regressions(baseline, FRESH)
    assert len(regs) == 1 and regs[0].startswith("regressor:")
    assert "3.00x" in regs[0]


def test_gate_added_row_skipped(baseline):
    regs, missing = _check_regressions(baseline, FRESH)
    assert not any("added" in r for r in regs)
    assert "added" not in missing


def test_gate_removed_row_reported_not_fatal_by_default(baseline):
    regs, missing = _check_regressions(baseline, FRESH)
    assert missing == ["removed"]
    assert not any("removed" in r for r in regs)


def test_gate_removed_row_fails_under_strict(baseline):
    regs, missing = _check_regressions(baseline, FRESH, strict=True)
    assert missing == ["removed"]
    assert any(r.startswith("removed:") and "missing" in r for r in regs)
    # the genuine regression is still reported alongside
    assert any(r.startswith("regressor:") for r in regs)


def test_gate_no_baseline_is_clean(tmp_path):
    regs, missing = _check_regressions(str(tmp_path / "nope.json"), FRESH,
                                       strict=True)
    assert regs == [] and missing == []


def test_gate_within_factor_is_clean(baseline):
    rows = ["steady,199.0,ok", "regressor,150.0,ok", "removed,100.0,ok",
            "ratio,0.0,r"]
    regs, missing = _check_regressions(baseline, rows, strict=True)
    assert regs == [] and missing == []


# ------------------------------------------------------- repo hygiene ------
def _git_ls_files():
    try:
        proc = subprocess.run(["git", "ls-files"], cwd=ROOT,
                              capture_output=True, text=True, timeout=30)
    except (OSError, subprocess.SubprocessError):
        return None
    return proc.stdout.splitlines() if proc.returncode == 0 else None


def test_no_tracked_bytecode_artifacts():
    """`git ls-files` must contain no __pycache__/.pyc entries — the
    guard that keeps the bd262a9 re-commit from happening a third time
    (benchmarks/run.py refuses to run against such a tree too)."""
    files = _git_ls_files()
    if files is None:
        pytest.skip("git unavailable or not a work tree")
    bad = [f for f in files
           if f.endswith(".pyc") or "__pycache__" in f.split("/")]
    assert not bad, f"tracked bytecode artifacts: {bad}"
    # the analyzer rule run.py's pre-flight delegates to agrees
    assert run_analysis([], root=ROOT,
                        rules=[TrackedBytecodeRule()]) == []


def test_gitignore_covers_bytecode():
    with open(os.path.join(ROOT, ".gitignore")) as f:
        patterns = [ln.strip() for ln in f if ln.strip()
                    and not ln.startswith("#")]
    assert "__pycache__/" in patterns
    assert any(p in ("*.pyc", "*.py[cod]") for p in patterns)


# ---------------------------------------------- paged-row gate coverage ----
# the paged serving/disagg rows ride the same gate: pin that they are
# timing rows (us > 0 gates), that a strict run failing on their absence
# names the re-baseline escape hatch, and that BENCH_PAGED_BASELINE=1
# downgrades exactly those failures to warnings
PAGED_ROWS = [("serving_paged_tok_x", 100.0),
              ("serving_stall_whole_x", 300.0),
              ("serving_stall_chunked_x", 100.0),
              ("disagg_page_migrate_x", 50.0),
              ("serving_paged_admit_x", 0.0),      # ratio row: never gated
              ("disagg_prefix_saved_x", 0.0)]


@pytest.fixture
def paged_baseline(tmp_path):
    path = str(tmp_path / "BENCH_paged.json")
    _write_baseline(path, PAGED_ROWS)
    return path


def test_gate_paged_rows_regress_like_any_timing_row(paged_baseline):
    fresh = [f"serving_paged_tok_x,{100.0 * REGRESSION_FACTOR * 2},bad"] + \
        [f"{n},{us},ok" for n, us in PAGED_ROWS[1:]]
    regs, missing = _check_regressions(paged_baseline, fresh, strict=True)
    assert missing == []
    assert len(regs) == 1 and regs[0].startswith("serving_paged_tok_x:")


def test_gate_missing_paged_row_names_rebaseline_hatch(paged_baseline,
                                                       monkeypatch):
    monkeypatch.delenv("BENCH_PAGED_BASELINE", raising=False)
    fresh = [f"{n},{us},ok" for n, us in PAGED_ROWS[1:]]   # tok row gone
    regs, missing = _check_regressions(paged_baseline, fresh, strict=True)
    assert missing == ["serving_paged_tok_x"]
    assert len(regs) == 1 and regs[0].startswith("serving_paged_tok_x:")
    assert "missing" in regs[0] and "BENCH_PAGED_BASELINE" in regs[0]


def test_gate_paged_baseline_env_downgrades_strict_missing(paged_baseline,
                                                           monkeypatch):
    monkeypatch.setenv("BENCH_PAGED_BASELINE", "1")
    fresh = [f"{n},{us},ok" for n, us in PAGED_ROWS[1:]]
    regs, missing = _check_regressions(paged_baseline, fresh, strict=True)
    # still reported as missing (the warning path) but not a failure
    assert missing == ["serving_paged_tok_x"] and regs == []
