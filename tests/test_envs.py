import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import SPECS, all_env_names, make_env


@pytest.mark.parametrize("name", all_env_names())
def test_obs_action_dims_match_table6(name):
    env = make_env(name)
    spec = env.spec
    # paper Table 6
    expected = {"Ant": (60, 8), "Anymal": (48, 12), "BallBalance": (24, 3),
                "FrankaCabinet": (23, 9), "Humanoid": (108, 21),
                "ShadowHand": (211, 20)}[name]
    assert (spec.obs_dim, spec.act_dim) == expected
    assert spec.policy_dims[0] == spec.obs_dim
    assert spec.policy_dims[-1] == spec.act_dim
    state, obs = env.reset(jax.random.PRNGKey(0), num_envs=8)
    assert obs.shape == (8, spec.obs_dim)
    a = jnp.zeros((8, spec.act_dim))
    state, obs, rew, done = env.step(state, a)
    assert obs.shape == (8, spec.obs_dim)
    assert rew.shape == (8,) and done.shape == (8,)
    assert bool(jnp.all(jnp.isfinite(obs))) and bool(jnp.all(jnp.isfinite(rew)))


def test_determinism():
    env = make_env("Ant")
    s1, o1 = env.reset(jax.random.PRNGKey(7), num_envs=4)
    s2, o2 = env.reset(jax.random.PRNGKey(7), num_envs=4)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    a = jnp.full((4, env.spec.act_dim), 0.3)
    _, o1n, r1, _ = env.step(s1, a)
    _, o2n, r2, _ = env.step(s2, a)
    np.testing.assert_array_equal(np.asarray(o1n), np.asarray(o2n))
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))


def test_long_run_stability_and_autoreset():
    env = make_env("Humanoid")
    state, obs = env.reset(jax.random.PRNGKey(0), num_envs=16)
    key = jax.random.PRNGKey(1)
    dones = 0
    step = jax.jit(env.step)
    for i in range(200):
        key, k = jax.random.split(key)
        a = jax.random.uniform(k, (16, env.spec.act_dim), minval=-1,
                               maxval=1)
        state, obs, rew, done = step(state, a)
        dones += int(done.sum())
        assert bool(jnp.all(jnp.isfinite(obs))), f"step {i}"
    # t counter must never exceed the episode cap
    assert int(state.t.max()) <= env.spec.max_episode_len


def test_episode_cap_triggers_done():
    env = make_env("BallBalance")
    state, _ = env.reset(jax.random.PRNGKey(0), num_envs=2)
    state = state._replace(t=jnp.full((2,), env.spec.max_episode_len - 1,
                                      jnp.int32))
    a = jnp.zeros((2, env.spec.act_dim))
    state2, obs, rew, done = env.step(state, a)
    assert bool(done.all())
    # auto-reset: t back near zero
    assert int(state2.t.max()) <= 1
