"""End-to-end behaviour tests for the paper's system (sync PPO with GMI
layouts, async A3C over channels, workload-aware selection, LM training)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channels import MultiChannelPipeline
from repro.core.placement import plan_async, plan_tcg_ex_training
from repro.envs import make_env
from repro.rl.a3c import actor_collect, staleness, trainer_update
from repro.rl.ppo import PPOConfig, init_train, make_train_step


def test_sync_training_on_tcg_ex_layout():
    """Holistic GMIs (paper Fig 6a): N instances collect + train + sync."""
    layout = plan_tcg_ex_training(2, 2, devices=list(range(4)),
                                  devices_per_gpu=2)
    n_inst = len(layout.trainer_gmis)
    assert layout.reduction_strategy() == "mrr"
    env = make_env("BallBalance")
    cfg = PPOConfig(num_steps=8, num_epochs=1, num_minibatches=1, lr=1e-3)
    step = make_train_step(env, cfg)
    states = []
    for i in range(n_inst):
        p, o, es, ob = init_train(jax.random.key(i), env,
                                  env.spec.policy_dims, num_envs=32)
        states.append([p, o, es, ob, jax.random.PRNGKey(i)])
    for it in range(4):
        for s in states:
            s[0], s[1], s[2], s[3], s[4], m = step(*s)
            assert bool(jnp.isfinite(m["loss"]))
        # stage (iii) global policy synchronization
        mean_p = jax.tree.map(lambda *xs: sum(xs) / n_inst,
                              *[s[0] for s in states])
        for s in states:
            s[0] = mean_p
    # all instances hold identical parameters after sync
    for s in states[1:]:
        for a, b in zip(jax.tree.leaves(states[0][0]),
                        jax.tree.leaves(s[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_a3c_over_channel_pipeline():
    """Decoupled serving/training GMIs (Fig 6b) + MCC experience flow."""
    layout = plan_async(2, 1, 2, devices=list(range(4)), devices_per_gpu=2)
    env = make_env("Ant")
    from repro.models.policy import init_policy
    from repro.optim import adam_init
    params = init_policy(jax.random.key(0), env.spec.policy_dims)
    opt = adam_init(params)
    pipe = MultiChannelPipeline(layout.serving_gmis, layout.trainer_gmis)

    actors = {}
    for a in layout.serving_gmis:
        es, obs = env.reset(jax.random.PRNGKey(a), num_envs=16)
        actors[a] = [es, obs, jax.random.PRNGKey(100 + a)]

    version = jnp.int32(0)
    actor_params = params        # possibly-stale snapshot
    losses = []
    for round_ in range(3):
        for a in layout.serving_gmis:
            es, obs, k = actors[a]
            exp, es, obs, k = actor_collect(actor_params, version, env, es,
                                            obs, k, num_steps=8)
            actors[a] = [es, obs, k]
            pipe.push(a, exp)
        for dst, batches in pipe.flush().items():
            for exp in batches:
                assert int(staleness(version, exp)) >= 0
                params, opt, loss = trainer_update(params, opt, exp)
                losses.append(float(loss))
                version = version + 1
        actor_params = params    # model push (policy parameter sharing)
    assert len(losses) == 3 and all(np.isfinite(losses))
    assert pipe.stats.num_transfers > 0


def test_selection_with_real_profiler_tiny():
    """Algorithm 2 with the real PPO profiler on a tiny search space."""
    from repro.core.selection import explore, make_ppo_profiler
    profile = make_ppo_profiler(iters=1)
    trace = explore(profile, "BallBalance", num_gpu=1,
                    gmi_per_gpu_range=(2, 1), num_env_sweep=(128, 256))
    ne, gpg = trace.best_config
    assert ne in (128, 256) and gpg in (1, 2)
    assert trace.best_throughput > 0


def test_lm_training_loss_decreases():
    from repro.configs import get_reduced
    from repro.configs.base import InputShape
    from repro.data import make_batch
    from repro.models import transformer as T
    from repro.optim import adam_init, adam_update

    cfg = get_reduced("granite-moe-1b-a400m")
    shape = InputShape("t", 32, 4, "train")
    params = T.init_model(jax.random.key(0), cfg)
    opt = adam_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, remat=False))(params)
        params, opt = adam_update(grads, opt, params, lr=3e-3, grad_clip=1.0)
        return params, opt, loss

    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}
    losses = []
    for i in range(15):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_serve_prefill_decode_pipeline():
    from repro.configs import get_reduced
    from repro.models import transformer as T
    cfg = get_reduced("zamba2-7b")
    params = T.init_model(jax.random.key(0), cfg)
    B, P = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, P), 0, cfg.vocab_size)
    logits, caches = T.prefill(params, cfg, {"tokens": toks}, max_seq=P + 8)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(4):
        pos = jnp.full((B,), P + i, jnp.int32)
        logits, caches = T.decode_step(params, cfg, tok, pos, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert bool(jnp.all(jnp.isfinite(logits)))
