"""Good fixture: idiomatic key discipline; prng-reuse stays quiet."""
import jax


def split_first(key):
    ka, kb = jax.random.split(key)
    return jax.random.normal(ka, (2,)), jax.random.normal(kb, (2,))


def fold_in_loop(key):
    out = []
    for i in range(4):
        out.append(jax.random.uniform(jax.random.fold_in(key, i), (3,)))
    return out


def rebind_through_split(key):
    a_key, key = jax.random.split(key)
    a = jax.random.normal(a_key, (2,))
    b_key, key = jax.random.split(key)
    return a, jax.random.normal(b_key, (2,))


def exclusive_branches(key, flag):
    if flag:
        return jax.random.normal(key, (2,))
    return jax.random.uniform(key, (2,))


def fresh_keys():
    a = jax.random.normal(jax.random.key(0), (2,))
    b = jax.random.normal(jax.random.key(1), (2,))
    return a, b
