"""Public wrapper: the parity test names this, not the kernel entry
point — pairing resolves through the import alias."""
from kernels.k import env_block_step as _ebs


def env_block_step_op(ts, q, ring):
    return _ebs(ts, q, ring)
