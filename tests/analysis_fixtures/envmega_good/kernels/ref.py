"""Oracle for the env-block megakernel fixture."""


def env_block_step_ref(ts, q, ring):
    return q, ring
