"""Good fixture: env-megakernel idiom — scalar-prefetch grid over env
blocks, ring buffers aliased input -> output, index_maps taking the
grid index PLUS the prefetch operand."""
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def env_block_step(ts, q, ring):
    def body(ts_ref, q_ref, ring_i, q_o, ring_o):
        del ring_i
        i = pl.program_id(0)
        col = ts_ref[1] * ts_ref[2] + i * 8
        ring_o[pl.ds(ts_ref[0], 1), pl.ds(col, 8)] = q_ref[...][None]
        q_o[...] = q_ref[...]

    def blk(i, ts):
        return (i,)

    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(4,),
            in_specs=[pl.BlockSpec((8,), blk),
                      pl.BlockSpec(ring.shape, lambda i, ts: (0, 0))],
            out_specs=[pl.BlockSpec((8,), blk),
                       pl.BlockSpec(ring.shape, lambda i, ts: (0, 0))],
        ),
        out_shape=[jax.ShapeDtypeStruct(q.shape, q.dtype),
                   jax.ShapeDtypeStruct(ring.shape, ring.dtype)],
        input_output_aliases={2: 1},
    )(ts, q, ring)
