"""Parity test naming the ops wrapper and the ref oracle together."""


def test_env_block_parity():
    assert env_block_step_op is not None and env_block_step_ref is not None
