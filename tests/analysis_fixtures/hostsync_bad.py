"""Bad fixture: host syncs inside a # repro: hot function."""
import time

import jax
import numpy as np


# repro: hot
def decode_loop(xs):
    t0 = time.perf_counter()        # BAD: host timing in hot path
    host = np.asarray(xs)           # BAD: device->host copy
    xs.block_until_ready()          # BAD: blocks on the device
    jax.block_until_ready(xs)       # BAD: same, module form
    v = xs.item()                   # BAD: scalar readback
    f = float(xs)                   # BAD: scalar readback
    return host, v, f, time.perf_counter() - t0
