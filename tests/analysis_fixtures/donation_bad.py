"""Bad fixture: reads of buffers already donated to jitted calls."""
import functools

import jax

step = jax.jit(lambda params, caches: (params[0], caches),
               donate_argnums=(1,))


def read_after_donation(params, caches):
    tok, new_caches = step(params, caches)
    stale = caches.sum()            # BAD: caches was donated above
    return tok, new_caches, stale


@functools.partial(jax.jit, donate_argnums=(0,))
def consume(buf, x):
    return buf + x


def read_after_decorated_donation(buf, x):
    out = consume(buf, x)
    return out, buf.mean()          # BAD: buf was donated above
