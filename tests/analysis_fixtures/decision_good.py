"""Good fixture: every Decision field is consumed — one via attribute
access, one only through a getattr string (which must count)."""
from dataclasses import dataclass
from typing import Optional


@dataclass
class Decision:
    num_env: int
    maybe_slots: Optional[int] = None


def apply_decision(d):
    slots = getattr(d, "maybe_slots", None)
    return d.num_env, slots
