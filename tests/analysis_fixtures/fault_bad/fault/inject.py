"""Bad fixture: 'mystery_kind' has no supervisor branch."""
KINDS = ("kill_serving", "engine_fail", "mystery_kind")
