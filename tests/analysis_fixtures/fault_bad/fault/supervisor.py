"""Handles only two of the three declared kinds."""


def classify(kind):
    if kind == "kill_serving":
        return "requeue"
    if kind == "engine_fail":
        return "quarantine"
    return None
