"""Good fixture: every kind is classified by the supervisor."""
KINDS = ("kill_serving", "engine_fail")
