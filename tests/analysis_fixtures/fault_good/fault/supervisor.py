"""Handles every declared kind."""


def classify(kind):
    return {"kill_serving": "requeue", "engine_fail": "quarantine"}[kind]
