"""Bad fixture: env-block megakernel whose index_map forgets the
scalar-prefetch operand (arity = grid rank only), with no ref.py
oracle anywhere."""
import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def env_block_step(ts, q):
    def body(ts_ref, q_ref, q_o):
        q_o[...] = q_ref[...]

    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(4,),
            in_specs=[pl.BlockSpec((8,), lambda i: (i,))],  # drops ts
            out_specs=pl.BlockSpec((8,), lambda i, ts: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(ts, q)
