"""Good fixture: the same constructs are fine in unmarked functions,
and fine in hot functions when deliberately allowed."""
import time

import jax.numpy as jnp
import numpy as np


def cold_telemetry(xs):
    # not hot: syncs here are nobody's business
    t0 = time.perf_counter()
    host = np.asarray(xs)
    return host, time.perf_counter() - t0


# repro: hot
def hot_but_pure(xs):
    return jnp.tanh(xs) + 1.0, float(3.5)   # constant float() is fine


# repro: hot
def hot_with_deliberate_sync(xs):
    t0 = time.perf_counter()  # repro: allow(host-sync-in-hot-path)
    # repro: allow(host-sync-in-hot-path)
    host = np.asarray(xs)
    return host, t0
