"""Good fixture: kernels paired with ref.py oracles through ops.py
wrappers, index_map arities matching grid rank (+ scalar prefetch)."""
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def covered_kernel(x):
    def body(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    grid = (4,)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=x,
    )(x)


def prefetch_kernel(tbl, x):
    def body(tbl_ref, x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def row(i, j, tbl):
        return (i, 0)

    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(4, 2),
            in_specs=[pl.BlockSpec((1, 8), row)],
            out_specs=pl.BlockSpec((1, 8), lambda i, j, tbl: (i, 0)),
        ),
        out_shape=x,
    )(tbl, x)
