"""Oracles for the good kernel fixture."""


def covered_kernel_ref(x):
    return x


def prefetch_kernel_ref(tbl, x):
    return x
