"""Public wrappers: the parity test references these, not the kernel
entry points — pairing must resolve through the import aliases."""
from kernels.k import covered_kernel as _ck
from kernels.k import prefetch_kernel as _pk


def public_covered(x):
    return _ck(x)


def public_prefetch(tbl, x):
    return _pk(tbl, x)
