"""Parity tests naming the ops wrappers and the ref oracles (never the
kernel entry points directly — exercises alias resolution)."""


def test_covered_parity():
    assert public_covered is not None and covered_kernel_ref is not None


def test_prefetch_parity():
    assert public_prefetch is not None and prefetch_kernel_ref is not None
