"""An allow() naming a DIFFERENT rule must not suppress prng-reuse."""
import jax


def wrong_rule_allow(key):
    a = jax.random.normal(key, (2,))
    b = jax.random.normal(key, (2,))  # repro: allow(donation-reuse)
    return a, b
