"""Bad fixture: Decision carries a field nothing ever reads."""
from dataclasses import dataclass


@dataclass
class Decision:
    num_env: int
    vestigial_estimate: float = 0.0   # BAD: never read below


def apply_decision(d):
    return d.num_env
