"""Good fixture: donated buffers rebound in the same statement (the
serve engine's idiom) or simply never read again."""
import jax

step = jax.jit(lambda params, caches: (params[0], caches),
               donate_argnums=(1,))


def same_statement_rebind(params, caches):
    tok, caches = step(params, caches)
    return tok, caches.sum()        # fine: caches is the NEW buffer


def never_read_again(params, caches):
    tok, new_caches = step(params, caches)
    return tok, new_caches


def non_donated_position(params, caches):
    tok, new_caches = step(params, caches)
    return tok, new_caches, params  # params (arg 0) was not donated
