"""Bad fixture: a pallas_call kernel with no oracle pairing and an
index_map whose arity disagrees with the grid rank."""
from jax.experimental import pallas as pl


def orphan_kernel(x):
    def body(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    return pl.pallas_call(
        body,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],   # arity 1, rank 2
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        out_shape=x,
    )(x)
