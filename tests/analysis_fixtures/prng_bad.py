"""Bad fixture: every function here violates prng-reuse."""
import jax


def sequential_reuse(key):
    a = jax.random.normal(key, (2,))
    b = jax.random.normal(key, (2,))    # BAD: second consumption
    return a, b


def split_after_sampling(key):
    a = jax.random.normal(key, (2,))
    ks = jax.random.split(key, 2)       # BAD: split of an already-used key
    return a, ks


def loop_reuse(key):
    out = []
    for i in range(4):
        out.append(jax.random.uniform(key, (3,)))   # BAD: cross-iteration
    return out
