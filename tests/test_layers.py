import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.attention import attention, init_attention_params


def test_rmsnorm_unit_scale():
    p = L.init_rmsnorm(16)
    x = jax.random.normal(jax.random.key(0), (4, 16)) * 5.0
    y = L.rms_norm(p, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-5)


def test_layernorm_moments():
    p = L.init_layernorm(32)
    x = jax.random.normal(jax.random.key(1), (8, 32)) * 3 + 2
    y = L.layer_norm(p, x)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.std(y, -1), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative_position():
    key = jax.random.key(2)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos[None], theta=100.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.key(3), (1, 1, 1, 16))
    def dot_at(p, d):
        qr = L.apply_rope(q, jnp.array([[p]]))
        kr = L.apply_rope(k, jnp.array([[p + d]]))
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(0, 3) - dot_at(5, 3)) < 1e-4


def test_softcap_bounds_and_identity():
    x = jnp.linspace(-100, 100, 101)
    y = L.softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    assert L.softcap(x, None) is x
    np.testing.assert_allclose(L.softcap(x * 1e-3, 30.0), x * 1e-3,
                               rtol=1e-3)


def test_gqa_equals_mha_when_kv_heads_match():
    key = jax.random.key(4)
    D, H, hd = 32, 4, 8
    p = init_attention_params(key, D, H, H, hd)
    x = jax.random.normal(key, (2, 10, D))
    pos = jnp.arange(10)
    o_mha, _ = attention(p, x, num_heads=H, num_kv_heads=H, head_dim=hd,
                         positions=pos)
    # replicate kv weights into grouped layout: same result must hold when
    # groups == 1 trivially; here check determinism + shape
    assert o_mha.shape == (2, 10, D)
    o2, _ = attention(p, x, num_heads=H, num_kv_heads=H, head_dim=hd,
                      positions=pos)
    np.testing.assert_allclose(o_mha, o2)


def test_mlp_swiglu_vs_gelu_shapes():
    key = jax.random.key(5)
    for act in ("silu", "gelu"):
        p = L.init_mlp(key, 16, 32, act)
        x = jax.random.normal(key, (3, 16))
        assert L.mlp(p, x, act).shape == (3, 16)
