"""Online GMI controller (runtime Algorithm 2): decision rules, the
explore() feedback loop over measured profiles, and the AsyncRunner
re-plan integration."""
import numpy as np
import pytest

from repro.core.controller import (ControllerConfig, Decision,
                                   OnlineGMIController, RoundSample)
from repro.core.selection import ProfilePoint


def _sample(samples=1000, dt=0.1, occ=0.5, spills=0, mem=1e6):
    return RoundSample(samples=samples, dt=dt, occupancy=occ,
                       spills=spills, mem_bytes=mem)


def _ctrl(**kw):
    cfg_kw = kw.pop("cfg_kw", {})
    defaults = dict(num_gpu=4, serving_gpus=2, gmi_per_gpu=2, num_env=512)
    defaults.update(kw)
    return OnlineGMIController(cfg=ControllerConfig(**cfg_kw), **defaults)


def test_no_decision_before_epoch_boundary():
    c = _ctrl(cfg_kw=dict(epoch_rounds=3))
    assert c.record(_sample()) is None
    assert c.record(_sample()) is None  # boundary at 3, not 2


def test_ring_pressure_shifts_gpu_to_training():
    c = _ctrl(cfg_kw=dict(epoch_rounds=1, probe=False))
    d = c.record(_sample(occ=1.0, spills=2))
    assert isinstance(d, Decision)
    assert d.serving_gpus == 1 and c.serving_gpus == 1
    assert "ring pressure" in d.reason


def test_ring_pressure_never_drops_last_serving_gpu():
    c = _ctrl(serving_gpus=1, cfg_kw=dict(epoch_rounds=1, probe=False,
                                          occ_low=0.0))
    assert c.record(_sample(occ=1.0, spills=5)) is None
    assert c.serving_gpus == 1


def test_exactly_full_ring_without_spills_is_not_pressure():
    """A group-sized ring filled once per round reads occupancy 1.0 —
    the healthy interleaved pattern, not overflow.  Only spills move a
    GPU to the training side."""
    c = _ctrl(cfg_kw=dict(epoch_rounds=1, probe=False))
    assert c.record(_sample(occ=1.0, spills=0)) is None
    assert c.serving_gpus == 2


def test_trainer_starvation_shifts_gpu_to_serving():
    c = _ctrl(serving_gpus=1, cfg_kw=dict(epoch_rounds=1, probe=False))
    d = c.record(_sample(occ=0.05))
    assert d is not None and d.serving_gpus == 2
    assert "starvation" in d.reason


def test_probe_walks_num_env_ladder_then_stops_at_saturation():
    c = _ctrl(num_gpu=2, serving_gpus=1, cfg_kw=dict(epoch_rounds=1))
    d1 = c.record(_sample(samples=4000))         # (2, 512) measured
    assert d1 is not None and d1.num_env == 1024 and "probe" in d1.reason
    d2 = c.record(_sample(samples=2000, mem=2e6))  # 1024 measured WORSE
    assert d2 is not None and d2.num_env == 512    # falls back to optimum
    assert "measured optimum" in d2.reason
    # ladder turned down above us: no further probes, steady state
    assert c.record(_sample(samples=4000)) is None


def test_hysteresis_ignores_marginal_gains():
    c = _ctrl(num_gpu=2, serving_gpus=1,
              cfg_kw=dict(epoch_rounds=1, probe=False, min_gain=1.5))
    c.record(_sample(samples=4000))
    c.num_env = 1024                              # pretend we moved
    c.record(_sample(samples=4400, mem=2e6))      # 1.1x at 1024: < min_gain
    c.num_env = 512
    assert c.record(_sample(samples=4000)) is None


def test_recorded_profile_feeds_explore_not_runnable_elsewhere():
    c = _ctrl(cfg_kw=dict(epoch_rounds=1, probe=False))
    c.record(_sample(samples=4000))
    prof = c.recorded_profile()
    p = prof("live", 2, 512)
    assert p.runnable and p.throughput > 0
    assert not prof("live", 2, 1024).runnable     # never extrapolates
    assert not prof("live", 1, 512).runnable


def test_running_mean_over_epochs():
    c = _ctrl(cfg_kw=dict(epoch_rounds=1, probe=False, occ_low=0.0))
    c.record(_sample(samples=1000, dt=1.0))
    c.record(_sample(samples=3000, dt=1.0))
    rec = c._table[(2, 512)]
    assert rec.epochs == 2
    n_inst = 2 * 2
    np.testing.assert_allclose(rec.point.throughput, 2000.0 / n_inst)


def test_observe_pipeline_deltas_and_replan_mark_reset():
    from repro.core.channels import MultiChannelPipeline
    from repro.rl.a3c import Experience
    import jax.numpy as jnp

    def exp(v):
        return Experience(obs=jnp.zeros((2, 4, 3)),
                          actions=jnp.zeros((2, 4, 2)),
                          rewards=jnp.zeros((2, 4)), dones=jnp.zeros((2, 4)),
                          bootstrap=jnp.zeros((4,)),
                          actor_version=jnp.int32(v))

    c = _ctrl(cfg_kw=dict(epoch_rounds=10))       # never hits a boundary
    pipe = MultiChannelPipeline([0], [9], overlap=True)
    pipe.push(0, exp(0))
    pipe.push(0, exp(1))                          # spill (1-slot ring)
    pipe.flush()
    assert c.observe_pipeline(pipe, samples=8, dt=0.1) is None
    assert c._epoch[-1].spills == 1
    assert c._epoch[-1].occupancy == 1.0
    # a fresh pipeline (post-replan) must not produce negative deltas
    pipe2 = MultiChannelPipeline([0], [9], overlap=True)
    pipe2.push(0, exp(2))
    pipe2.flush()
    c.observe_pipeline(pipe2, samples=8, dt=0.1)
    assert c._epoch[-1].spills == 0


def test_plan_layout_respects_decision_state():
    c = _ctrl(cfg_kw=dict(epoch_rounds=1, probe=False))
    c.record(_sample(occ=1.0, spills=1))          # serving 2 -> 1
    layout = c.plan_layout(devices=list(range(8)), devices_per_gpu=2)
    assert layout.name == "async"
    assert len(layout.serving_gmis) == 1 * 2      # 1 serving GPU x 2 GMIs
    assert len(layout.trainer_gmis) == 3 * 2


def test_async_runner_probe_replans_and_stays_lossless():
    """The organic online-Alg.2 path in the round-interleaved runner:
    the first epoch measures the live config, the controller probes the
    next num_env up its ladder, the runner re-plans (env restart, model
    state kept), and accounting stays lossless across the re-plan."""
    from repro.core.placement import plan_async
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner

    layout = plan_async(4, 2, 2, devices=list(range(8)), devices_per_gpu=2)
    env = make_env("Ant")
    runner = make_async_runner(
        env, layout, overlap=True, online_controller=True,
        controller_cfg=ControllerConfig(epoch_rounds=2, occ_low=0.0,
                                        num_env_sweep=(8, 16)),
        num_envs=8, num_steps=4)
    losses = []
    for _ in range(6):
        ls, stale = runner.round()
        losses += ls
        assert all(s >= 0 for s in stale)
    ls, _ = runner.finish()
    losses += ls
    assert runner.replans >= 1
    # probed up the ladder; may legitimately fall back if 16 measured
    # worse on this host
    assert runner.num_envs in (8, 16)
    assert any("probe" in d.reason for d in runner.controller.decisions)
    assert runner.trained_samples == runner.predictions   # nothing dropped
    assert losses and all(np.isfinite(losses))
    assert (2, 16) in runner.controller._table            # probe measured


def test_replan_preserves_pipeline_configuration():
    """Regression: replan used to rebuild a default MultiChannelPipeline,
    silently dropping batch_mode/batch_envs/ring/backend settings."""
    from repro.core.channels import HostStagedPipeline, MultiChannelPipeline
    from repro.envs import make_env
    from repro.rl.a3c import AsyncRunner

    env = make_env("Ant")
    pipe = MultiChannelPipeline([0, 1], [100], batch_mode="slice",
                                batch_envs=4, ring_slots=3,
                                use_pallas=False, overlap=True)
    c = _ctrl(num_gpu=2, serving_gpus=1,
              cfg_kw=dict(epoch_rounds=1, probe=False))
    runner = AsyncRunner(env, [0, 1], [100], num_envs=8, num_steps=4,
                         overlap=True, pipeline=pipe, controller=c,
                         layout_builder=lambda d: c.plan_layout(
                             devices=list(range(4)), devices_per_gpu=2))
    runner.replan(Decision(num_env=8, gmi_per_gpu=2, serving_gpus=1,
                           reason="test"))
    new = runner.pipe
    assert new is not pipe
    b = next(iter(new.batchers.values()))
    assert (b.mode, b.batch_envs) == ("slice", 4)
    assert new.ring_slots == 3 and new.use_pallas is False
    assert new.overlap is True

    runner.pipe = HostStagedPipeline([0, 1], [100])
    with pytest.raises(TypeError, match="clone_for"):
        runner.replan(Decision(num_env=8, gmi_per_gpu=2, serving_gpus=1,
                               reason="test"))


def test_async_runner_overlap_without_controller_trains_round_behind():
    from repro.envs import make_env
    from repro.rl.a3c import AsyncRunner

    env = make_env("Ant")
    runner = AsyncRunner(env, [0, 1], [100, 101],
                         gmi_gpu={0: 0, 1: 1, 100: 0, 101: 1},
                         num_envs=8, num_steps=4, overlap=True)
    ls0, _ = runner.round()
    assert ls0 == []                       # first flush: nothing in flight
    ls1, stale1 = runner.round()
    # trains on the PREVIOUS round's data: two groups collected at version
    # 0, trained at versions 0 and 1 -> staleness climbs within the round
    assert ls1 and min(stale1) >= 0 and max(stale1) >= 1
    runner.finish()
    assert runner.trained_samples == runner.predictions
