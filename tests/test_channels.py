import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channels import (CHANNELS, Batcher, ChannelRing, Compressor,
                                 Dispenser, HostStagedPipeline, Migrator,
                                 MultiChannelPipeline, TransferStats,
                                 UniChannelPipeline)
from repro.rl.a3c import Experience


def _exp(T=4, N=6, obs=5, act=2, version=1, base=0.0):
    return Experience(
        obs=jnp.full((T, N, obs), base + 1.0),
        actions=jnp.full((T, N, act), base + 2.0),
        rewards=jnp.arange(T * N, dtype=jnp.float32).reshape(T, N) + base,
        dones=jnp.zeros((T, N)),
        bootstrap=jnp.full((N,), base + 3.0),
        actor_version=jnp.int32(version))


def test_roundtrip_preserves_content():
    exp = _exp()
    pipe = MultiChannelPipeline([0], [1])
    pipe.push(0, exp)
    out = pipe.flush()
    (dst, batches), = out.items()
    got = batches[0]
    np.testing.assert_array_equal(np.asarray(got.rewards),
                                  np.asarray(exp.rewards))
    np.testing.assert_array_equal(np.asarray(got.obs), np.asarray(exp.obs))
    np.testing.assert_array_equal(np.asarray(got.bootstrap),
                                  np.asarray(exp.bootstrap))


def test_compressor_concatenates_across_agents():
    e1, e2 = _exp(base=0.0), _exp(base=100.0)
    pipe = MultiChannelPipeline([0, 1], [2])
    pipe.push(0, e1)
    pipe.push(1, e2)
    out = pipe.flush()
    got = out[2][0]
    assert got.rewards.shape == (4, 12)
    np.testing.assert_array_equal(np.asarray(got.rewards[:, :6]),
                                  np.asarray(e1.rewards))
    np.testing.assert_array_equal(np.asarray(got.rewards[:, 6:]),
                                  np.asarray(e2.rewards))


def test_mcc_fewer_transfers_larger_granularity_than_ucc():
    n_agents, rounds = 4, 3
    mcc = MultiChannelPipeline(list(range(n_agents)), [10, 11])
    ucc = UniChannelPipeline([10, 11])
    for r in range(rounds):
        for a in range(n_agents):
            mcc.push(a, _exp())
            ucc.send(_exp())
        mcc.flush()
    assert mcc.stats.num_transfers < ucc.stats.num_transfers
    assert mcc.stats.bytes_per_transfer > ucc.stats.bytes_per_transfer
    # identical payload totals: MCC only re-batches, never drops
    assert mcc.stats.total_bytes == ucc.stats.total_bytes


def test_migrator_prefers_same_gpu_then_least_loaded():
    mig = Migrator([5, 6], gmi_gpu={5: 0, 6: 1})
    ch = {"rewards": jnp.zeros((4, 8))}
    assert mig.route(ch, agent_gpu=1) == 6
    assert mig.route(ch, agent_gpu=None) == 5       # least loaded
    mig.load[5] = 100
    assert mig.route(ch, agent_gpu=None) == 6


def test_batcher_slicing():
    b = Batcher(mode="slice", batch_envs=4)
    ch = {c: getattr(_exp(N=10), c) for c in CHANNELS}
    parts = b.prepare(ch)
    assert [p.rewards.shape[1] for p in parts] == [4, 4, 2]
    total = np.concatenate([np.asarray(p.rewards) for p in parts], axis=1)
    np.testing.assert_array_equal(total, np.asarray(ch["rewards"]))


def test_batcher_actor_version_always_scalar():
    # merged pushes reduce to the OLDEST version (conservative staleness)
    for v, want in ((jnp.int32(5), 5), (jnp.array([3, 5, 4], jnp.int32), 3)):
        ch = {c: getattr(_exp(), c) for c in CHANNELS}
        ch["actor_version"] = v
        for part in Batcher(mode="slice", batch_envs=4).prepare(ch):
            assert part.actor_version.ndim == 0
            assert int(part.actor_version) == want
        (whole,) = Batcher(mode="stack").prepare(ch)
        assert whole.actor_version.ndim == 0
        assert int(whole.actor_version) == want


# ------------------------------------------------------- ring-buffer MCC ---
def test_empty_flush_after_flush_is_noop():
    pipe = MultiChannelPipeline([0, 1], [9])
    pipe.push(0, _exp())
    pipe.push(1, _exp(base=10.0))
    assert pipe.flush()
    transfers = pipe.stats.num_transfers
    assert pipe.flush() == {}                  # drained: nothing to move
    assert pipe.stats.num_transfers == transfers


def test_bytes_per_transfer_zero_transfers():
    assert TransferStats().bytes_per_transfer == 0.0
    assert MultiChannelPipeline([0], [1]).stats.bytes_per_transfer == 0.0


def test_transfer_samples_track_delivering_flushes():
    """Each delivering flush leaves one (seconds, bytes) sample for the
    bandwidth calibrator; empty flushes leave none; take drains."""
    pipe = MultiChannelPipeline([0, 1], [9])
    pipe.push(0, _exp())
    pipe.push(1, _exp(base=10.0))
    assert pipe.flush()
    assert pipe.flush() == {}                  # drained: no second sample
    samples = pipe.take_transfer_samples()
    assert len(samples) == 1
    sec, nbytes = samples[0]
    assert sec > 0.0 and nbytes == pipe.stats.total_bytes
    assert pipe.take_transfer_samples() == []  # drained the telemetry too
    # overlap mode: the swap flush delivers one round late but still
    # yields exactly one sample per DELIVERING flush
    over = MultiChannelPipeline([0, 1], [9], overlap=True)
    over.push(0, _exp())
    assert over.flush() == {}                  # first flush: swap only
    assert over.take_transfer_samples() == []
    over.push(0, _exp(base=5.0))
    assert over.flush()                        # delivers round 1's swap
    assert len(over.take_transfer_samples()) == 1


def test_pipeline_uneven_batch_envs_slicing():
    pipe = MultiChannelPipeline([0, 1], [7], batch_mode="slice",
                                batch_envs=5)
    e1, e2 = _exp(N=6), _exp(N=6, base=50.0)
    pipe.push(0, e1)
    pipe.push(1, e2)
    ((dst, parts),) = pipe.flush().items()
    assert [p.rewards.shape[1] for p in parts] == [5, 5, 2]  # ragged tail
    merged = np.concatenate([np.asarray(p.rewards) for p in parts], axis=1)
    want = np.concatenate([np.asarray(e1.rewards), np.asarray(e2.rewards)],
                          axis=1)
    np.testing.assert_array_equal(merged, want)


def test_ring_wraparound_keeps_newest_in_order():
    ring = ChannelRing(slots=2)
    exps = [_exp(base=100.0 * i, version=i) for i in range(3)]
    for e in exps:
        ring.append(e)                 # 3 pushes into 2 slots: e0 evicted
    ch = ring.snapshot()
    assert ch["rewards"].shape == (4, 12)
    np.testing.assert_array_equal(np.asarray(ch["rewards"][:, :6]),
                                  np.asarray(exps[1].rewards))
    np.testing.assert_array_equal(np.asarray(ch["rewards"][:, 6:]),
                                  np.asarray(exps[2].rewards))
    np.testing.assert_array_equal(np.asarray(ch["actor_version"]), [1, 2])
    assert ring.count == 0             # snapshot drains


def test_ring_partial_flush_then_refill():
    ring = ChannelRing(slots=4)
    ring.append(_exp(base=1.0))
    ch = ring.snapshot()
    assert ch["rewards"].shape == (4, 6)
    np.testing.assert_array_equal(np.asarray(ch["obs"]),
                                  np.asarray(_exp(base=1.0).obs))
    ring.append(_exp(base=2.0))        # ring reusable after partial flush
    ch2 = ring.snapshot()
    np.testing.assert_array_equal(np.asarray(ch2["obs"]),
                                  np.asarray(_exp(base=2.0).obs))


def test_ring_pallas_path_matches_xla_path():
    a = ChannelRing(slots=3, use_pallas=True, interpret=True)
    b = ChannelRing(slots=3, use_pallas=False)
    for i in range(5):                 # crosses the wrap boundary
        e = _exp(base=float(i), version=i)
        a.append(e)
        b.append(e)
    ca, cb = a.snapshot(), b.snapshot()
    for c in CHANNELS:
        np.testing.assert_array_equal(np.asarray(ca[c]), np.asarray(cb[c]))


def test_flush_routes_per_agent_group_balancing_trainers():
    """Agents on two GPUs must land on BOTH co-located trainers in ONE
    flush (seed behavior funneled every flush to a single trainer)."""
    gmi_gpu = {0: 0, 1: 0, 2: 1, 3: 1, 100: 0, 101: 1}
    pipe = MultiChannelPipeline([0, 1, 2, 3], [100, 101], gmi_gpu=gmi_gpu)
    for a, base in zip(range(4), (0.0, 10.0, 20.0, 30.0)):
        pipe.push(a, _exp(base=base))
    out = pipe.flush()
    assert set(out) == {100, 101}          # both trainers fed per flush
    assert pipe.migrator.load[100] == pipe.migrator.load[101] == 12
    # direct forward: GPU-0 agents (bases 0, 10) went to the GPU-0 trainer
    got = np.asarray(out[100][0].obs)
    np.testing.assert_array_equal(got[:, :6], np.asarray(_exp(base=0.0).obs))
    np.testing.assert_array_equal(got[:, 6:],
                                  np.asarray(_exp(base=10.0).obs))


def test_pipeline_lossless_when_pushes_outrun_flushes():
    """A full ring spills (coarse-grained) instead of evicting: the
    pipeline delivers every push even when an agent pushes more often
    than the consumer flushes — seed-equivalent losslessness."""
    pipe = MultiChannelPipeline([0], [9])     # group size 1 -> 1 ring slot
    e1, e2, e3 = (_exp(base=b, version=i)
                  for i, b in enumerate((0.0, 10.0, 20.0)))
    pipe.push(0, e1)
    pipe.push(0, e2)
    pipe.push(0, e3)
    ((dst, batches),) = pipe.flush().items()
    got = np.concatenate([np.asarray(b.rewards) for b in batches], axis=1)
    want = np.concatenate([np.asarray(e.rewards) for e in (e1, e2, e3)],
                          axis=1)
    np.testing.assert_array_equal(got, want)
    assert pipe.flush() == {}                 # fully drained


# ------------------------------------------------- double-buffered rings ---
def _bases_of(batches, N=6):
    """Recover the per-push base ids from delivered batches, in delivery
    order (push base b writes rewards[0, 0] == b in its column block)."""
    out = []
    for b in batches:
        r = np.asarray(b.rewards)
        for j in range(r.shape[1] // N):
            out.append(float(r[0, j * N]))
    return out


def _deliver(out):
    return [b for _, bs in sorted(out.items()) for b in bs]


def test_double_ring_swap_then_push_does_not_corrupt_snapshot():
    """Pushes after a swap land in the other buffer half: the swapped-out
    snapshot must stay intact even after the ring wraps again."""
    ring = ChannelRing(slots=2, double_buffered=True)
    ring.append(_exp(base=1.0, version=1))
    ring.append(_exp(base=2.0, version=2))
    snap = ring.snapshot()                 # swap: back half = pushes 1, 2
    for i, base in enumerate((3.0, 4.0, 5.0)):   # front half + wrap
        if ring.count == ring.slots:
            ring.snapshot()                # swap back onto the first half
        ring.append(_exp(base=base, version=3 + i))
    np.testing.assert_array_equal(np.asarray(snap["rewards"][:, :6]),
                                  np.asarray(_exp(base=1.0).rewards))
    np.testing.assert_array_equal(np.asarray(snap["rewards"][:, 6:]),
                                  np.asarray(_exp(base=2.0).rewards))
    np.testing.assert_array_equal(np.asarray(snap["actor_version"]), [1, 2])


def test_double_ring_pallas_interpret_matches_xla():
    a = ChannelRing(slots=2, double_buffered=True, use_pallas=True,
                    interpret=True)
    b = ChannelRing(slots=2, double_buffered=True, use_pallas=False)
    snaps_a, snaps_b = [], []
    for i in range(6):                     # crosses swaps and wraps
        e = _exp(base=float(i), version=i)
        a.append(e)
        b.append(e)
        if i % 2 == 1:
            snaps_a.append(a.snapshot())
            snaps_b.append(b.snapshot())
    for ca, cb in zip(snaps_a, snaps_b):
        for c in CHANNELS:
            np.testing.assert_array_equal(np.asarray(ca[c]),
                                          np.asarray(cb[c]))


def test_overlap_flush_is_one_round_delayed_and_drain_recovers_tail():
    pipe = MultiChannelPipeline([0], [9], overlap=True)
    pipe.push(0, _exp(base=1.0, version=1))
    assert pipe.flush() == {}              # swap parked, nothing in flight
    pipe.push(0, _exp(base=2.0, version=2))
    out = pipe.flush()                     # delivers round 1
    assert _bases_of(_deliver(out)) == [1.0]
    tail = pipe.drain()                    # delivers round 2
    assert _bases_of(_deliver(tail)) == [2.0]
    assert pipe.drain() == {}              # fully drained


def test_overlap_spill_ordering_preserved_across_swap():
    """1-slot ring: three pushes in one round spill twice; the spills must
    be delivered before the swapped buffer, in push order."""
    pipe = MultiChannelPipeline([0], [9], overlap=True)
    for i, base in enumerate((1.0, 2.0, 3.0)):
        pipe.push(0, _exp(base=base, version=i + 1))
    assert pipe.spill_count == 2
    assert pipe.flush() == {}              # everything parked in flight
    out = pipe.drain()
    assert _bases_of(_deliver(out)) == [1.0, 2.0, 3.0]


def test_overlap_interleaved_schedules_no_loss_no_dup():
    """Pushes landing mid-consume are never lost or duplicated under an
    interleaved push/flush schedule (skipped flushes, bursts > ring
    capacity, trailing pushes)."""
    schedule = [1, 0, 3, 2, 0, 0, 5, 1]    # pushes per round (2-slot ring)
    blocking = MultiChannelPipeline([0, 1], [9])
    overlap = MultiChannelPipeline([0, 1], [9], overlap=True)
    base = 0.0
    pushed, got_b, got_o = [], [], []
    for r, n in enumerate(schedule):
        for i in range(n):
            base += 1.0
            e = _exp(base=base, version=int(base))
            pushed.append(base)
            blocking.push(i % 2, e)
            overlap.push(i % 2, e)
        if r % 3 != 2:                     # flush most rounds, not all
            got_b += _bases_of(_deliver(blocking.flush()))
            got_o += _bases_of(_deliver(overlap.flush()))
    got_b += _bases_of(_deliver(blocking.drain()))
    got_o += _bases_of(_deliver(overlap.drain()))
    assert sorted(got_o) == sorted(pushed)          # no loss, no dup
    assert got_o == got_b                           # same delivery stream
    assert overlap.delivered_samples == blocking.delivered_samples


def test_overlap_matches_host_staged_sample_stream():
    """HostStagedPipeline and the double-buffered ring deliver identical
    per-push payloads (content, not just ids) over interleaved rounds."""
    host = HostStagedPipeline([0, 1], [5])
    over = MultiChannelPipeline([0, 1], [5], overlap=True)
    N = 6
    pushed = {}
    v = 0
    host_stream, over_stream = [], []

    def split(batches):
        out = []
        for b in batches:
            r = np.asarray(b.rewards)
            for j in range(r.shape[1] // N):
                sl = slice(j * N, (j + 1) * N)
                out.append((float(r[0, j * N]),
                            r[:, sl], np.asarray(b.obs)[:, sl]))
        return out

    for r in range(4):
        for a in range(2):
            v += 1
            e = _exp(base=float(v), version=v)
            pushed[float(v)] = (np.asarray(e.rewards), np.asarray(e.obs))
            host.push(a, e)
            over.push(a, e)
        host_stream += split(_deliver(host.flush()))
        over_stream += split(_deliver(over.flush()))
    host_stream += split(_deliver(host.drain()))
    over_stream += split(_deliver(over.drain()))
    assert [b for b, *_ in over_stream] == [b for b, *_ in host_stream]
    for b, rew, obs in over_stream:
        np.testing.assert_array_equal(rew, pushed[b][0])
        np.testing.assert_array_equal(obs, pushed[b][1])


def test_occupancy_high_water_and_spill_counters():
    pipe = MultiChannelPipeline([0, 1], [9], overlap=True)  # 2-slot ring
    pipe.push(0, _exp(base=1.0))
    assert pipe.ring_occupancy() == 0.5
    pipe.push(1, _exp(base=2.0))
    pipe.push(0, _exp(base=3.0))                      # spill + repush
    assert pipe.spill_count == 1
    assert pipe.take_occupancy_high_water() == 1.0
    assert pipe.occupancy_high_water == 0.0           # mark reset
    pipe.flush()
    assert pipe.ring_occupancy() == 0.0               # swapped out


def test_ring_mcc_matches_host_staged_payloads():
    """Device-resident and host-staged MCC must deliver identical bytes
    and identical TransferStats."""
    ring = MultiChannelPipeline([0, 1], [5])
    host = HostStagedPipeline([0, 1], [5])
    for r in range(3):
        for a in range(2):
            e = _exp(base=r * 10.0 + a, version=r * 2 + a)
            ring.push(a, e)
            host.push(a, e)
        (rb,), (hb,) = ring.flush().values(), host.flush().values()
        for field in ("obs", "actions", "rewards", "dones", "bootstrap"):
            np.testing.assert_array_equal(
                np.asarray(getattr(rb[0], field)),
                np.asarray(getattr(hb[0], field)))
        assert int(rb[0].actor_version) == int(hb[0].actor_version)
    assert ring.stats.num_transfers == host.stats.num_transfers
    assert ring.stats.total_bytes == host.stats.total_bytes
