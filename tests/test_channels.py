import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channels import (CHANNELS, Batcher, Compressor, Dispenser,
                                 Migrator, MultiChannelPipeline,
                                 UniChannelPipeline)
from repro.rl.a3c import Experience


def _exp(T=4, N=6, obs=5, act=2, version=1, base=0.0):
    return Experience(
        obs=jnp.full((T, N, obs), base + 1.0),
        actions=jnp.full((T, N, act), base + 2.0),
        rewards=jnp.arange(T * N, dtype=jnp.float32).reshape(T, N) + base,
        dones=jnp.zeros((T, N)),
        bootstrap=jnp.full((N,), base + 3.0),
        actor_version=jnp.int32(version))


def test_roundtrip_preserves_content():
    exp = _exp()
    pipe = MultiChannelPipeline([0], [1])
    pipe.push(0, exp)
    out = pipe.flush()
    (dst, batches), = out.items()
    got = batches[0]
    np.testing.assert_array_equal(np.asarray(got.rewards),
                                  np.asarray(exp.rewards))
    np.testing.assert_array_equal(np.asarray(got.obs), np.asarray(exp.obs))
    np.testing.assert_array_equal(np.asarray(got.bootstrap),
                                  np.asarray(exp.bootstrap))


def test_compressor_concatenates_across_agents():
    e1, e2 = _exp(base=0.0), _exp(base=100.0)
    pipe = MultiChannelPipeline([0, 1], [2])
    pipe.push(0, e1)
    pipe.push(1, e2)
    out = pipe.flush()
    got = out[2][0]
    assert got.rewards.shape == (4, 12)
    np.testing.assert_array_equal(np.asarray(got.rewards[:, :6]),
                                  np.asarray(e1.rewards))
    np.testing.assert_array_equal(np.asarray(got.rewards[:, 6:]),
                                  np.asarray(e2.rewards))


def test_mcc_fewer_transfers_larger_granularity_than_ucc():
    n_agents, rounds = 4, 3
    mcc = MultiChannelPipeline(list(range(n_agents)), [10, 11])
    ucc = UniChannelPipeline([10, 11])
    for r in range(rounds):
        for a in range(n_agents):
            mcc.push(a, _exp())
            ucc.send(_exp())
        mcc.flush()
    assert mcc.stats.num_transfers < ucc.stats.num_transfers
    assert mcc.stats.bytes_per_transfer > ucc.stats.bytes_per_transfer
    # identical payload totals: MCC only re-batches, never drops
    assert mcc.stats.total_bytes == ucc.stats.total_bytes


def test_migrator_prefers_same_gpu_then_least_loaded():
    mig = Migrator([5, 6], gmi_gpu={5: 0, 6: 1})
    ch = {"rewards": jnp.zeros((4, 8))}
    assert mig.route(ch, agent_gpu=1) == 6
    assert mig.route(ch, agent_gpu=None) == 5       # least loaded
    mig.load[5] = 100
    assert mig.route(ch, agent_gpu=None) == 6


def test_batcher_slicing():
    b = Batcher(mode="slice", batch_envs=4)
    ch = {c: getattr(_exp(N=10), c) for c in CHANNELS}
    parts = b.prepare(ch)
    assert [p.rewards.shape[1] for p in parts] == [4, 4, 2]
    total = np.concatenate([np.asarray(p.rewards) for p in parts], axis=1)
    np.testing.assert_array_equal(total, np.asarray(ch["rewards"]))
