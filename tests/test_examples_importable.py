"""Tier-1 guard: every example stays importable.

The example zoo has been silently broken by refactors before (a renamed
symbol only surfaces when someone actually runs the script).  Importing
executes the module top level — all ``repro`` imports resolve, every
``def`` compiles — without running ``main()`` (all examples are
``__main__``-guarded)."""
import importlib.util
import pathlib

import pytest

EXAMPLES = sorted(
    (pathlib.Path(__file__).resolve().parent.parent / "examples")
    .glob("*.py"))


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.stem)
def test_example_imports(path):
    spec = importlib.util.spec_from_file_location(
        f"_example_{path.stem}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)           # raises on any broken import
    assert hasattr(mod, "main"), f"{path.name} has no main()"


def test_example_zoo_not_empty():
    assert len(EXAMPLES) >= 5
