"""Multi-device checks, run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (never in the main
pytest process — smoke tests must see one device)."""
import os
import sys

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "run via test_dist_multidev.py"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.comm import lgr_allreduce, mpr_host  # noqa: E402


def check_lgr_equivalence():
    devs = np.array(jax.devices()).reshape(2, 4)
    mesh = Mesh(devs, ("gpu", "inst"))
    key = jax.random.key(0)
    grads = {"w": jax.random.normal(key, (2, 4, 33, 7)),   # odd sizes: pad path
             "b": jax.random.normal(key, (2, 4, 11))}
    expect = jax.tree.map(lambda g: np.broadcast_to(
        np.asarray(g).mean(axis=(0, 1)), g.shape), grads)
    for strat in ("mrr", "har", "mpr"):
        out = lgr_allreduce(grads, mesh, strat)
        for k in grads:
            np.testing.assert_allclose(np.asarray(out[k]), expect[k],
                                       rtol=1e-5, atol=1e-5)
    print("lgr equivalence ok")


def check_har_equals_mrr_2x2():
    """Regression (ISSUE 1): HAR and MRR must agree numerically on a 2x2
    mesh — the smallest layout where the hierarchical schedule's
    scatter/psum/gather path differs from the flat ring."""
    devs = np.array(jax.devices()[:4]).reshape(2, 2)
    mesh = Mesh(devs, ("gpu", "inst"))
    key = jax.random.key(7)
    grads = {"w": jax.random.normal(key, (2, 2, 17, 5)),   # pad path (17)
             "b": jax.random.normal(key, (2, 2, 8))}       # exact path
    har = lgr_allreduce(grads, mesh, "har")
    mrr = lgr_allreduce(grads, mesh, "mrr")
    for k in grads:
        np.testing.assert_allclose(np.asarray(har[k]), np.asarray(mrr[k]),
                                   rtol=1e-6, atol=1e-6)
    print("har == mrr on 2x2 ok")


def check_comm_schedule_parity_vs_host_oracle():
    """Every schedule (2-level and 3-level) must match the mpr_host host
    oracle on 2x2 and 2x2x2 device grids, for both average and raw-sum
    semantics (ISSUE 3 satellite: single average switch)."""
    key = jax.random.key(11)
    grids = [((2, 2), ("gpu", "inst"), ("mrr", "har", "mpr")),
             ((2, 2, 2), ("gpu", "inst", "dev"),
              ("mrr", "har", "har3", "mpr"))]
    for shape, axes, strategies in grids:
        n = int(np.prod(shape))
        devs = np.array(jax.devices()[:n]).reshape(shape)
        mesh = Mesh(devs, axes)
        grads = {"w": jax.random.normal(key, shape + (33, 7)),  # pad path
                 "b": jax.random.normal(key, shape + (8,))}     # exact path
        idx = list(np.ndindex(*shape))
        per_inst = [jax.tree.map(lambda x, i=i: x[i], grads) for i in idx]
        want_mean = mpr_host(per_inst)
        want_sum = mpr_host(per_inst, average=False)
        for strat in strategies:
            out = lgr_allreduce(grads, mesh, strat)
            out_sum = lgr_allreduce(grads, mesh, strat, average=False)
            for k in grads:
                got = np.asarray(out[k])[(0,) * len(shape)]
                np.testing.assert_allclose(got, want_mean[k],
                                           rtol=1e-5, atol=1e-5)
                # every replica must agree
                np.testing.assert_allclose(
                    np.asarray(out[k]),
                    np.broadcast_to(want_mean[k], out[k].shape),
                    rtol=1e-5, atol=1e-5)
                got_sum = np.asarray(out_sum[k])[(0,) * len(shape)]
                np.testing.assert_allclose(got_sum, want_sum[k],
                                           rtol=1e-5, atol=1e-5)
        print(f"comm parity ok on {shape}")


def check_multi_device_gmi_end_to_end():
    """Acceptance (ISSUE 3): the (gpu, inst, dev) mesh that
    GMIManager.instance_mesh builds for multi-device GMIs reduces
    correctly through the layout's Communicator — no ValueError, parity
    with the mpr_host oracle to <=1e-5."""
    from repro.comm import Communicator, ReduceCostModel
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout

    mgr = GMIManager(devices=jax.devices(), devices_per_gpu=4)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)     # 2 chips per GMI
        mgr.set_gpu(gid, gpu)
    layout = Layout("multidev", mgr, [], [0, 1, 2, 3])
    comm = Communicator.from_layout(layout, cost_model=ReduceCostModel(),
                                    with_mesh=True)
    assert comm.strategy == "har3", comm     # cost model picks 3-level
    assert comm.mesh.axis_names == ("gpu", "inst", "dev")
    key = jax.random.key(5)
    grads = {"w": jax.random.normal(key, (2, 2, 2, 17, 3)),
             "b": jax.random.normal(key, (2, 2, 2, 5))}
    out = comm.allreduce(grads)
    per_inst = [jax.tree.map(lambda x, i=i: x[i], grads)
                for i in np.ndindex(2, 2, 2)]
    want = comm.reduce_host(per_inst)
    for k in grads:
        np.testing.assert_allclose(
            np.asarray(out[k]),
            np.broadcast_to(want[k], out[k].shape), rtol=1e-5, atol=1e-5)
    # online strategy switch keeps reducing correctly on the same mesh
    for strat in ("mpr", "har"):
        out2 = comm.switch(strat).allreduce(grads)
        np.testing.assert_allclose(np.asarray(out2["w"]),
                                   np.broadcast_to(want["w"],
                                                   out2["w"].shape),
                                   rtol=1e-5, atol=1e-5)
    print("multi-device GMI communicator ok")


def check_mpr_host():
    key = jax.random.key(1)
    gs = [{"w": jax.random.normal(jax.random.fold_in(key, i), (5, 3))}
          for i in range(6)]
    red = mpr_host(gs)
    want = np.mean([np.asarray(g["w"]) for g in gs], axis=0)
    np.testing.assert_allclose(red["w"], want, rtol=1e-6)
    print("mpr host ok")


def check_sharded_train_step():
    """A reduced-arch train step under pjit on a 4x2 mesh must produce the
    same loss as the single-device step."""
    from repro.configs import get_reduced
    from repro.configs.base import InputShape, TrainConfig
    from repro.data import make_batch
    from repro.launch.steps import make_train_step
    from repro.models import transformer as T
    from repro.optim import adam_init

    cfg = get_reduced("internlm2-1.8b")
    shape = InputShape("t", 64, 8, "train")
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    with mesh:
        fn, _ = make_train_step(cfg, mesh, shape, TrainConfig(), lgr="har")
        params = T.init_model(jax.random.key(0), cfg)
        opt = adam_init(params)
        batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}
        p2, o2, metrics = fn(params, opt, batch)
    T.set_activation_sharding(None)
    params = T.init_model(jax.random.key(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape).items()}
    ref_loss = T.loss_fn(params, cfg, batch, remat=True)
    np.testing.assert_allclose(float(metrics["loss"]), float(ref_loss),
                               rtol=2e-4, atol=2e-4)
    print("sharded train step ok, loss", float(metrics["loss"]))


def check_checkpoint_restore_with_shardings():
    """Crash-resume on a real mesh (ISSUE 6): a checkpoint written from
    sharded arrays must restore bit-identically AND land on the given
    NamedShardings (device_put shard-by-shard on the 8-device mesh)."""
    import tempfile

    from repro.checkpoint import load, save

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    sh_w = NamedSharding(mesh, P("data", "model"))
    sh_b = NamedSharding(mesh, P())
    key = jax.random.key(3)
    tree = {"w": jax.device_put(jax.random.normal(key, (8, 6)), sh_w),
            "b": jax.device_put(jnp.arange(5, dtype=jnp.int32), sh_b)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt_1")
        save(path, tree, step=1)
        like = {"w": jnp.zeros((8, 6)), "b": jnp.zeros(5, jnp.int32)}
        back = load(path, like, shardings={"w": sh_w, "b": sh_b})
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
        assert back[k].sharding == tree[k].sharding, \
            (k, back[k].sharding, tree[k].sharding)
    assert len(back["w"].sharding.device_set) == 8
    print("sharded checkpoint restore ok")


def check_gmi_instance_mesh():
    from repro.core.gmi import GMIManager
    mgr = GMIManager(devices=jax.devices(), devices_per_gpu=4)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)
        mgr.set_gpu(gid, gpu)
    mesh = mgr.instance_mesh("trainer")
    # 2-device GMIs contribute BOTH chips along the trailing "dev" axis
    # (the old mesh silently kept only device_ids[0] of each instance)
    assert mesh.axis_names == ("gpu", "inst", "dev")
    assert mesh.devices.shape == (2, 2, 2)
    assert len({d.id for d in mesh.devices.reshape(-1)}) == 8
    sub = mgr.submesh(0)
    assert sub.devices.size == 2
    print("gmi meshes ok")


if __name__ == "__main__":
    check_lgr_equivalence()
    check_har_equals_mrr_2x2()
    check_comm_schedule_parity_vs_host_oracle()
    check_multi_device_gmi_end_to_end()
    check_mpr_host()
    check_sharded_train_step()
    check_checkpoint_restore_with_shardings()
    check_gmi_instance_mesh()
    print("MULTIDEV ALL OK")
