"""Continuous-batching engine: request lifecycle (admit -> prefill ->
decode slots -> retire), slot reuse after completion, and the core
correctness property — batched decode is TOKEN-IDENTICAL to the
single-request oracle path across attention, SSM, and hybrid cache
families."""
import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.serve import Request, ServeEngine

V = 64
CASES = {
    "attention": ModelConfig(name="d", num_layers=2, d_model=64, num_heads=4,
                             num_kv_heads=2, d_ff=128, vocab_size=V),
    "ssm": ModelConfig(name="x", d_model=64, num_heads=4, num_kv_heads=4,
                       d_ff=0, vocab_size=V,
                       block_pattern=("mlstm",) * 3 + ("slstm",),
                       num_super=2),
    "hybrid": ModelConfig(name="z", d_model=64, num_heads=4, num_kv_heads=4,
                          d_ff=128, vocab_size=V, ssm_state_dim=16,
                          block_pattern=("mamba2",) * 2 + ("attn_shared",),
                          num_super=2),
    # batch-composition-independent finite-capacity routing
    # (moe_route_block) makes MoE a PINNED identity case, not an exception
    "moe": ModelConfig(name="m", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=V,
                       num_experts=4, experts_per_token=2,
                       moe_route_block=4),
}

_PARAMS = {}


def make_engine(case: str, **kw) -> ServeEngine:
    cfg = CASES[case]
    if case not in _PARAMS:
        _PARAMS[case] = T.init_model(jax.random.key(3), cfg)
    kw.setdefault("max_slots", 3)
    kw.setdefault("max_seq", 40)
    return ServeEngine(cfg, _PARAMS[case], **kw)


def reqs_mixed(n=5, seed=1, budgets=(4, 7, 3, 6, 5), **kw):
    rng = np.random.default_rng(seed)
    return [Request(tokens=rng.integers(0, V, int(rng.integers(3, 10))),
                    max_new_tokens=budgets[i % len(budgets)], **kw)
            for i in range(n)]


# ---------------------------------------------------------------- lifecycle --
def test_request_lifecycle_admit_decode_retire():
    eng = make_engine("attention", max_slots=2)
    reqs = reqs_mixed(5)
    for r in reqs:
        eng.submit(r)
    assert eng.queue_len == 5 and eng.active_count == 0
    done = []
    seen_active = []
    while eng.busy:
        done.extend(eng.step())
        seen_active.append(eng.active_count)
    # the fixed-slot batch never exceeds its width, and it was actually used
    assert max(seen_active, default=0) <= 2
    assert 2 in seen_active
    assert len(done) == 5 and eng.queue_len == 0 and eng.active_count == 0
    by_rid = {c.rid: c for c in done}
    for r in reqs:
        c = by_rid[r.rid]
        assert len(c.tokens) == r.max_new_tokens
        assert c.prompt_tokens == len(r.tokens)
        assert c.latency_s >= 0.0
        assert all(0 <= t < V for t in c.tokens)


def test_slot_reuse_after_completion():
    eng = make_engine("attention", max_slots=2)
    first = eng.serve(reqs_mixed(2, seed=2))
    assert len(first) == 2 and eng.free_slots == 2
    # a second wave reuses the freed slots (same engine, same caches)
    second = eng.serve(reqs_mixed(3, seed=3))
    assert len(second) == 3
    assert {len(c.tokens) for c in second} == \
        {r.max_new_tokens for r in reqs_mixed(3, seed=3)}


def test_budget_one_retires_at_prefill():
    eng = make_engine("attention")
    done = eng.serve([Request(tokens=np.arange(5), max_new_tokens=1)])
    assert len(done) == 1 and len(done[0].tokens) == 1
    assert eng.telemetry.total_decode_steps == 0


def test_submit_rejects_overlong_request():
    eng = make_engine("attention", max_seq=16)
    with pytest.raises(ValueError, match="exceeds engine max_seq"):
        eng.submit(Request(tokens=np.arange(10), max_new_tokens=10))


def test_encoder_only_rejected():
    cfg = CASES["attention"].replace(causal=False)   # encoder-only
    with pytest.raises(ValueError, match="encoder-only"):
        ServeEngine(cfg, _PARAMS.get("attention") or
                    T.init_model(jax.random.key(3), CASES["attention"]))


def test_eos_early_retire_matches_oracle():
    eng = make_engine("attention")
    probe = reqs_mixed(1, seed=5, budgets=(8,))[0]
    oracle = eng.oracle_generate(probe)
    eos = oracle[2]
    req = Request(tokens=probe.tokens, max_new_tokens=8, eos_id=int(eos))
    done = eng.serve([reqs_mixed(1, seed=6)[0], req])  # batched with another
    c = next(c for c in done if c.rid == req.rid)
    assert c.tokens == oracle[:3]           # stops AT the first eos


# -------------------------------------------------------------- equivalence --
@pytest.mark.parametrize("case", list(CASES))
def test_batched_decode_token_identical_to_oracle(case):
    """The acceptance property: requests of different prompt lengths and
    budgets, joining and leaving the decode batch at different times,
    produce EXACTLY the oracle's tokens — KV, SSM, and hybrid caches."""
    eng = make_engine(case, max_slots=2)
    reqs = reqs_mixed(4, seed=11, budgets=(5, 8, 3, 6))
    oracle = {r.rid: eng.oracle_generate(r) for r in reqs}
    # staggered arrivals: two up front, the rest joining mid-decode
    for r in reqs[:2]:
        eng.submit(r)
    done = []
    done.extend(eng.step())
    done.extend(eng.step())
    for r in reqs[2:]:
        eng.submit(r)
    done.extend(eng.run_until_idle())
    assert len(done) == len(reqs)
    for c in done:
        assert c.tokens == oracle[c.rid], \
            f"{case}: slot tokens diverged from single-request oracle"


def test_sampling_is_batch_composition_independent():
    """Per-request keys fold (seed, position) — a sampled request draws
    the same tokens alone or batched with strangers."""
    eng = make_engine("attention", max_slots=3)
    req = Request(tokens=np.arange(6), max_new_tokens=6,
                  temperature=0.8, seed=42)
    oracle = eng.oracle_generate(req)
    others = reqs_mixed(2, seed=12)
    done = eng.serve([others[0], Request(tokens=req.tokens,
                                         max_new_tokens=6, temperature=0.8,
                                         seed=42), others[1]])
    c = [c for c in done if c.request.temperature > 0][0]
    assert c.tokens == oracle


def test_no_decode_recompilation_across_batch_composition():
    """The decode batch has a fixed slot count: mixed prompt lengths,
    budgets, admissions and retirements never retrace it."""
    eng = make_engine("attention", max_slots=2)
    eng.serve(reqs_mixed(5, seed=13))
    cache_size = getattr(eng._decode, "_cache_size", None)
    if cache_size is None:
        pytest.skip("jit cache introspection unavailable")
    assert cache_size() == 1
    # prefill traces once per distinct prompt length, not per request
    assert eng._prefill._cache_size() <= len(
        {len(r.tokens) for r in reqs_mixed(5, seed=13)})


# --------------------------------------------------------------- telemetry --
def test_telemetry_epoch_counts():
    eng = make_engine("attention", max_slots=2)
    reqs = reqs_mixed(3, seed=14, budgets=(4, 4, 4))
    eng.serve(reqs)
    load = eng.telemetry.take_epoch(eng.cache_bytes)
    assert load.tokens == 12 and load.requests == 3
    assert load.slots == 2 and 0.0 < load.occupancy_mean <= 1.0
    assert load.p95_s >= load.p50_s > 0.0
    assert load.mem_bytes == eng.cache_bytes > 0
    # epoch reset: a fresh epoch starts empty
    empty = eng.telemetry.take_epoch()
    assert empty.tokens == 0 and empty.requests == 0


# -------------------------------------------------------------- paged cache --
def _staggered_identity(eng, reqs):
    """Submit two up front, two mid-decode; assert tokens == oracle."""
    oracle = {r.rid: eng.oracle_generate(r) for r in reqs}
    for r in reqs[:2]:
        eng.submit(r)
    done = []
    done.extend(eng.step())
    done.extend(eng.step())
    for r in reqs[2:]:
        eng.submit(r)
    done.extend(eng.run_until_idle())
    assert len(done) == len(reqs)
    for c in done:
        assert c.tokens == oracle[c.rid], \
            f"{eng.name}: tokens diverged from single-request oracle"
    return done


@pytest.mark.parametrize("case", list(CASES))
def test_chunked_prefill_token_identical(case):
    """Chunked prefill (chunks interleaved with live decode steps) stays
    token-identical for every cache family — including MoE, where chunk
    boundaries snap to moe_route_block."""
    eng = make_engine(case, max_slots=2, chunk_prefill=4)
    _staggered_identity(eng, reqs_mixed(4, seed=11, budgets=(5, 8, 3, 6)))


def test_batch_prefill_off_token_identical():
    eng = make_engine("attention", max_slots=2, batch_prefill=False)
    _staggered_identity(eng, reqs_mixed(4, seed=17, budgets=(5, 8, 3, 6)))


def test_dense_legacy_engine_token_identical():
    """paged=False keeps the pre-paging monolithic-slot path pinned."""
    eng = make_engine("hybrid", max_slots=2, paged=False)
    assert eng.total_pages == 0 and eng.free_pages == 0
    _staggered_identity(eng, reqs_mixed(4, seed=18, budgets=(5, 8, 3, 6)))


def test_decode_kernel_token_identical_windowed():
    """The Pallas gather-decode kernel, driven through the engine with a
    sliding window, agrees with the (kernel-free) oracle path."""
    eng = make_engine("attention", max_slots=2, decode_kernel=True,
                      window_override=16, chunk_prefill=3)
    _staggered_identity(eng, reqs_mixed(4, seed=19, budgets=(6, 8, 3, 5)))


def test_page_table_rows_disjoint_across_writers():
    """Page-table invariant: with sharing off, no physical page is ever
    mapped by two slots at once, and draining returns every page."""
    eng = make_engine("attention", max_slots=3, share_prefix=False)
    for r in reqs_mixed(6, seed=20):
        eng.submit(r)
    while eng.busy:
        eng.step()
        owners = {}
        for row in range(eng.max_slots):
            for pid in eng._table[row]:
                if pid >= 0:
                    assert pid != 0, "trash page must never be mapped"
                    assert owners.setdefault(int(pid), row) == row, \
                        f"page {pid} mapped by two writers"
        for pid, _ in owners.items():
            assert eng._pool.ref[pid] == 1
    assert eng.free_pages == eng.total_pages     # all pages returned
    assert np.all(eng._table == -1)


def test_free_list_exhaustion_queues_not_crashes():
    """A request whose pages aren't available yet waits in the queue (no
    crash, no partial admission) and completes token-identically once the
    running request retires its pages."""
    # 9 usable pages: each request needs ceil((20+12-1)/8) = 4 pages, so
    # two fit but the third must wait for a retirement
    eng = make_engine("attention", max_slots=3, num_pages=10)
    rng = np.random.default_rng(23)
    reqs = [Request(tokens=rng.integers(0, V, 20), max_new_tokens=12)
            for _ in range(3)]
    oracle = {r.rid: eng.oracle_generate(r) for r in reqs}
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert eng.active_count == 2 and eng.queue_len == 1   # pages, not slots
    done = eng.run_until_idle()
    assert len(done) == 3
    for c in done:
        assert c.tokens == oracle[c.rid]
    assert eng.free_pages == eng.total_pages or eng._index.pages()


def test_submit_rejects_impossible_page_need():
    eng = make_engine("attention", max_slots=2, max_seq=40, num_pages=4)
    with pytest.raises(ValueError, match="can never be admitted"):
        eng.submit(Request(tokens=np.arange(25), max_new_tokens=8))


def test_shared_prefix_reuse_and_cow_divergence():
    """Two prompts sharing a 16-token head: the second admission reuses
    the promoted head pages (fewer fresh pages allocated), diverges by
    copy-on-write, and both match their oracles."""
    eng = make_engine("attention", max_slots=2, max_seq=48)
    rng = np.random.default_rng(31)
    head = rng.integers(0, V, 16)                 # two full 8-token blocks
    a = Request(tokens=np.concatenate([head, rng.integers(0, V, 5)]),
                max_new_tokens=4)
    b = Request(tokens=np.concatenate([head, rng.integers(0, V, 7)]),
                max_new_tokens=5)
    oracle = {r.rid: eng.oracle_generate(r) for r in (a, b)}
    assert eng.serve([a])[0].tokens == oracle[a.rid]
    # a's full head blocks stay behind in the prefix index
    assert eng.shared_head_pages(b.tokens) == 2
    held = eng.total_pages - eng.free_pages
    assert held >= 2 and set(eng._index.pages())
    free_before = eng.free_pages
    eng.submit(b)
    eng.step()
    # b mapped the two shared pages (ref > 1) instead of re-prefilling
    # them: fresh allocations cover only the tail + COW + budget
    shared = [pid for pid in eng._table[eng._slots.index(
        next(s for s in eng._slots if s is not None))]
        if pid >= 0 and eng._pool.ref[pid] > 1]
    assert shared, "second request did not map any shared head page"
    assert free_before - eng.free_pages < eng._pages_needed(
        len(b.tokens), b.max_new_tokens)
    done = eng.run_until_idle()
    assert done[0].tokens == oracle[b.rid]


def test_identical_prompts_share_maximally():
    """Same prompt twice in one batch: sharing never corrupts decode —
    each request still produces the oracle tokens independently."""
    eng = make_engine("attention", max_slots=2, max_seq=48)
    rng = np.random.default_rng(37)
    toks = rng.integers(0, V, 17)
    a = Request(tokens=toks, max_new_tokens=6)
    b = Request(tokens=toks.copy(), max_new_tokens=6)
    oracle = eng.oracle_generate(a)
    done = eng.serve([a, b])
    assert [c.tokens for c in done] == [oracle, oracle]


def test_index_pages_evicted_under_pressure():
    """Index-held (ref == index entries) pages are evicted when the free
    list can't cover an admission — the cache is a cache, not a leak."""
    eng = make_engine("attention", max_slots=2, max_seq=40, num_pages=11)
    rng = np.random.default_rng(41)
    done = eng.serve([Request(tokens=rng.integers(0, V, 16),
                              max_new_tokens=3)])
    assert len(done) == 1 and eng._index.pages()
    held = eng.total_pages - eng.free_pages
    assert held >= 2
    # a request needing more pages than the free list holds forces
    # eviction of the index-only pages, then completes
    big = Request(tokens=rng.integers(0, V, 30), max_new_tokens=9)
    oracle = eng.oracle_generate(big)
    out = eng.serve([big])
    assert out[0].tokens == oracle
