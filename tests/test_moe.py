import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import _route_group, init_moe, moe_apply


def test_router_respects_capacity():
    key = jax.random.key(0)
    S, E, k, cap = 32, 4, 2, 5
    x = jax.random.normal(key, (S, 8))
    logits = jax.random.normal(jax.random.key(1), (S, E))
    slot, gate, valid = _route_group(x, logits, k, cap, E)
    flat = np.asarray(slot.reshape(-1))
    kept = flat[flat < E * cap]
    # no slot used twice, and per-expert count <= capacity
    assert len(set(kept.tolist())) == len(kept)
    for e in range(E):
        used = ((kept >= e * cap) & (kept < (e + 1) * cap)).sum()
        assert used <= cap


def test_gates_sum_to_one():
    key = jax.random.key(2)
    x = jax.random.normal(key, (16, 8))
    logits = jax.random.normal(jax.random.key(3), (16, 4))
    _, gate, _ = _route_group(x, logits, 2, 100, 4)
    np.testing.assert_allclose(np.asarray(gate.sum(-1)), 1.0, rtol=1e-5)


def test_moe_no_drop_equals_dense_mixture():
    """With unlimited capacity, scatter-dispatch MoE must equal the dense
    'compute every expert and mix by gate' oracle."""
    key = jax.random.key(4)
    B, S, D, F, E, k = 2, 8, 16, 32, 4, 2
    p = init_moe(key, D, F, E)
    x = jax.random.normal(jax.random.key(5), (B, S, D))
    out, aux = moe_apply(p, x, num_experts=E, top_k=k, capacity_factor=100.0)

    # dense oracle
    logits = x @ p["router"]
    gate_all = jax.nn.softmax(logits, axis=-1)
    topg, tope = jax.lax.top_k(gate_all, k)
    topg = topg / topg.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->besf", x, p["wi"])
    g = jnp.einsum("bsd,edf->besf", x, p["wg"])
    y_e = jnp.einsum("besf,efd->besd", jax.nn.silu(g) * h, p["wo"])
    mix = jnp.zeros_like(x)
    for i in range(k):
        idx = tope[..., i][:, None, :, None]          # (B,1,S,1)
        sel = jnp.take_along_axis(y_e, idx, axis=1)[:, 0]   # (B,S,D)
        mix = mix + topg[..., i][..., None] * sel
    np.testing.assert_allclose(np.asarray(out), np.asarray(mix),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0.0


def test_aux_loss_uniform_router_is_minimal():
    """Load-balance loss is minimized (=coef) for a perfectly uniform
    router."""
    key = jax.random.key(6)
    B, S, D, F, E = 2, 64, 16, 16, 4
    p = init_moe(key, D, F, E)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(key, (B, S, D))
    _, aux = moe_apply(p, x, num_experts=E, top_k=2, aux_coef=1.0)
    # uniform probs: E * sum(f_i * 1/E) = 1 regardless of f
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-4)


def test_token_major_priority_drops_late_tokens():
    """When over capacity, earlier tokens keep their slots (the paper's
    batcher relies on deterministic priority)."""
    S, E, k, cap = 8, 2, 1, 2
    x = jnp.ones((S, 4))
    logits = jnp.stack([jnp.ones(S), jnp.zeros(S)], -1)  # all prefer e0
    slot, gate, valid = _route_group(x, logits, k, cap, E)
    v = np.asarray(valid[:, 0])
    assert v[:cap].all() and not v[cap:].any()
