import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunk_size_invariance(chunk):
    key = jax.random.key(0)
    B, S, D, H = 2, 16, 32, 4
    x = jax.random.normal(key, (B, S, D)) * 0.5
    p = ssm.init_mlstm(key, D, H)
    y_full, st_full = ssm.mlstm_apply(p, x, num_heads=H, chunk=16)
    y_c, st_c = ssm.mlstm_apply(p, x, num_heads=H, chunk=chunk)
    np.testing.assert_allclose(y_full, y_c, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(st_full.C, st_c.C, rtol=2e-4, atol=2e-5)


def test_mlstm_chunkwise_equals_recurrent():
    key = jax.random.key(1)
    B, S, D, H = 2, 12, 32, 4
    x = jax.random.normal(key, (B, S, D)) * 0.5
    p = ssm.init_mlstm(key, D, H)
    y1, _ = ssm.mlstm_apply(p, x, num_heads=H, chunk=4)
    st = ssm.mlstm_init_state(B, H, (D * 2) // H, D * 2)
    ys = []
    for t in range(S):
        yt, st = ssm.mlstm_decode_step(p, x[:, t:t + 1], st, num_heads=H)
        ys.append(yt)
    np.testing.assert_allclose(y1, jnp.concatenate(ys, 1), rtol=2e-5,
                               atol=2e-5)


def test_mamba2_chunkwise_equals_recurrent():
    key = jax.random.key(2)
    B, S, D, N = 2, 16, 32, 8
    x = jax.random.normal(key, (B, S, D)) * 0.5
    p = ssm.init_mamba2(key, D, N)
    y1, st1 = ssm.mamba2_apply(p, x, state_dim=N, chunk=4)
    st = ssm.mamba2_init_state(B, D * 2, N)
    ys = []
    for t in range(S):
        yt, st = ssm.mamba2_decode_step(p, x[:, t:t + 1], st, state_dim=N)
        ys.append(yt)
    np.testing.assert_allclose(y1, jnp.concatenate(ys, 1), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(st1.h, st.h, rtol=2e-4, atol=2e-5)


def test_slstm_sequential_state_consistency():
    key = jax.random.key(3)
    B, S, D, H = 2, 10, 32, 4
    x = jax.random.normal(key, (B, S, D)) * 0.5
    p = ssm.init_slstm(key, D, H)
    y_all, st_all = ssm.slstm_apply(p, x, num_heads=H)
    # split run: first 6, then 4 with carried state
    y_a, st_a = ssm.slstm_apply(p, x[:, :6], num_heads=H)
    y_b, st_b = ssm.slstm_apply(p, x[:, 6:], num_heads=H, state=st_a)
    np.testing.assert_allclose(y_all, jnp.concatenate([y_a, y_b], 1),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(st_all.c, st_b.c, rtol=2e-5, atol=2e-5)


def test_mlstm_long_range_stability():
    """Exponential gating with the log-space stabilizer must stay finite
    over long sequences."""
    key = jax.random.key(4)
    B, S, D, H = 1, 512, 16, 2
    x = jax.random.normal(key, (B, S, D)) * 2.0
    p = ssm.init_mlstm(key, D, H)
    y, st = ssm.mlstm_apply(p, x, num_heads=H, chunk=64)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(st.C)))


def test_causal_conv_state_equivalence():
    key = jax.random.key(5)
    p = ssm.init_conv1d(key, 8, 4)
    x = jax.random.normal(key, (2, 12, 8))
    y_all, st_all = ssm.causal_conv1d(p, x)
    y_a, st_a = ssm.causal_conv1d(p, x[:, :7])
    y_b, st_b = ssm.causal_conv1d(p, x[:, 7:], st_a)
    np.testing.assert_allclose(y_all, jnp.concatenate([y_a, y_b], 1),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(st_all, st_b, rtol=1e-6)
