"""The unified repro.comm subsystem: Algorithm-1 / cost-model strategy
selection, the Communicator object, single-switch average semantics, the
core.lgr deprecation shim, and the controller's reduction-strategy
re-plan loop.  (Numerical schedule parity on real multi-device grids
lives in tests/_multidev_checks.py — this file runs on one device.)"""
import importlib
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (Communicator, ReduceCostModel, STRATEGIES,
                        algorithm1, as_grad_sync, make_grad_sync, mpr_host,
                        select_reduction_strategy)
from repro.core.cost_model import (lgr_time_har, lgr_time_har3, lgr_time_mpr)
from repro.core.placement import (plan_async, plan_tcg_ex_training,
                                  plan_tcg_serving)


# ------------------------------------------------------------- selection ---
def test_algorithm1_verbatim_reexport():
    """placement.select_reduction_strategy is the comm one, and the
    Algorithm-1 shape logic is unchanged."""
    from repro.core import placement
    assert placement.select_reduction_strategy is select_reduction_strategy
    assert algorithm1([[0, 1, 2]]) == "mpr"
    assert algorithm1([[0], [1]]) == "mrr"
    assert algorithm1([[0, 1, 2], [3, 4]]) == "har"
    assert select_reduction_strategy([[0, 1], [2, 3]]) == "mrr"


def test_cost_model_candidates_and_feasibility():
    cm = ReduceCostModel(dev_per_inst=2)
    assert cm.candidates((2, 2, 2)) == ["mpr", "har", "har3"]   # t*d > g
    assert "mrr" in cm.candidates((4, 2, 1))                    # t <= g
    assert "har3" not in cm.candidates((4, 2, 1))               # no dev axis
    assert cm.candidates((1, 4, 1)) == ["mpr"]                  # single GPU
    with pytest.raises(ValueError, match="dev axis"):
        cm.time("har3", (2, 2, 1))


def test_cost_model_prefers_har3_on_fast_dev_links():
    """Table-2 ordering: with intra-instance links much faster than the
    instance-level domain, the 3-level schedule must win on a
    (gpu, inst, dev) grid — and the verbatim shape logic alone (which is
    dev-blind) would not have picked it."""
    M = 6e6
    B1, B2, B3 = 5e9, 200e9, 400e9
    assert lgr_time_har3(2, 2, 2, M, B1, B2, B3) \
        < lgr_time_har(2, 4, M, B1, B2) < lgr_time_mpr(2, 4, M, B1, B2)
    cm = ReduceCostModel(bw_intra=B1, bw_gpu=B2, bw_dev=B3,
                         bytes_per_round=M, dev_per_inst=2)
    mpl = [[0, 1], [2, 3]]
    assert select_reduction_strategy(mpl) == "mrr"              # shape only
    assert select_reduction_strategy(mpl, cm) == "har3"         # cost-aware
    # ragged layouts can't build an axis mesh: cost path stays in mpr/har
    assert select_reduction_strategy([[0, 1, 2], [3, 4]], cm) in ("mpr",
                                                                  "har")


def test_cost_model_degenerates_without_dev_axis():
    """On a plain (gpu, inst) grid the cost-scored choice agrees with the
    Table-2 best_lgr ordering (har beats mpr on fast interconnects)."""
    cm = ReduceCostModel(bytes_per_round=6e6, dev_per_inst=1)
    s = select_reduction_strategy([[0, 1, 2], [3, 4, 5]], cm)
    assert s == "har"                       # t=3 > g=2: mrr infeasible


# ---------------------------------------------------------- Communicator ---
def test_communicator_from_layouts():
    ex = plan_tcg_ex_training(2, 2, devices=list(range(4)),
                              devices_per_gpu=2)
    comm = ex.communicator()
    assert comm.strategy == ex.reduction_strategy() == "mrr"
    assert comm.grid == (2, 2)
    assert plan_tcg_serving(2, 2, devices=list(range(8)),
                            devices_per_gpu=4).communicator() is None


def test_communicator_multi_device_grid_carries_dev_axis():
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)     # 2 devices each
        mgr.set_gpu(gid, gpu)
    layout = Layout("t", mgr, [], [0, 1, 2, 3])
    comm = layout.communicator()
    assert comm.grid == (2, 2, 2)
    assert comm.cost_model.dev_per_inst == 2
    assert comm.num_instances == 8
    # Algorithm 1 is dev-blind and would say "mrr" here, but mrr breaks
    # the one-ring-endpoint-per-chip rule on this grid (t*d=4 > g=2):
    # construction must land on a FEASIBLE strategy, never a state its
    # own switch() would reject
    assert comm.strategy in comm.candidates()
    # cost-aware construction picks the 3-level schedule here
    comm3 = layout.communicator(cost_model=ReduceCostModel())
    assert comm3.strategy == "har3"


def test_communicator_ragged_layout_restricts_candidates():
    """A ragged layout (unequal GMIs per GPU) has no axis mesh, so the
    communicator's candidate set must stay in mpr/har — switch() to mrr
    must refuse even when the flattened grid shape would allow it."""
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    for gid, gpu, frac in [(0, 0, 0.25), (1, 1, 0.25), (2, 1, 0.25)]:
        mgr.add_gmi(gid, "trainer", frac)
        mgr.set_gpu(gid, gpu)
    layout = Layout("ragged", mgr, [], [0, 1, 2])
    comm = layout.communicator()
    assert comm.uniform is False
    assert set(comm.candidates()) == {"mpr", "har"}
    with pytest.raises(ValueError, match="not feasible"):
        comm.switch("mrr")


def test_communicator_rebind_tracks_new_layout():
    """AsyncRunner.replan rebinds the communicator to the re-planned
    layout: grid/dev axis refresh, stale measurements clear, and an
    infeasible current strategy is coerced to a feasible one."""
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("har3", grid=(2, 2, 2), cost_model=cm)
    comm.observe(1.0)
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=2)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)     # 1 chip each now
        mgr.set_gpu(gid, gpu)
    layout = Layout("replanned", mgr, [], [0, 1, 2, 3])
    comm.rebind(layout)
    assert comm.grid == (2, 2)
    assert comm.cost_model.dev_per_inst == 1
    assert comm.measured("har3") is None     # stale table cleared
    assert comm.strategy in comm.candidates()   # har3 no longer feasible


def test_communicator_from_layout_rejects_mixed_device_counts():
    """Planning as if every GMI were single-chip would silently drop the
    dev axis — mirror instance_mesh and refuse mixed sizes loudly."""
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    mgr.add_gmi(0, "trainer", 0.5)           # 2 devices
    mgr.set_gpu(0, 0)
    mgr.add_gmi(1, "trainer", 0.25)          # 1 device
    mgr.set_gpu(1, 1)
    layout = Layout("mixed", mgr, [], [0, 1])
    with pytest.raises(ValueError, match="mixed devices-per-GMI"):
        layout.communicator()


def test_communicator_duck_types_as_grad_sync():
    comm = Communicator("mrr", grid=(2, 2))
    fn = as_grad_sync(comm)
    g = {"w": jnp.ones((3,))}
    assert fn(g)["w"].shape == (3,)          # identity without a mesh
    assert as_grad_sync(None) is None
    plain = lambda x: x                                         # noqa: E731
    assert as_grad_sync(plain) is plain


def test_communicator_switch_is_pure_plumbing():
    comm = Communicator("mpr", grid=(2, 2, 2),
                        cost_model=ReduceCostModel(dev_per_inst=2))
    comm.observe(1.0, 6e6)
    comm.observe(0.1, 6e6, strategy="har3")
    assert comm.switch("har3") is comm
    assert comm.strategy == "har3"
    # stale measurements of non-active strategies are dropped (one bad
    # early sample must not outrank the model forever); the new active
    # strategy keeps its live record
    assert comm.measured("mpr") is None
    assert comm.measured("har3") == 0.1
    with pytest.raises(ValueError, match="not feasible"):
        comm.switch("mrr")                   # t*d > g on this grid
    with pytest.raises(ValueError, match="unknown"):
        comm.switch("ring-of-fire")


def test_make_drl_train_step_rejects_mesh_attached_communicator():
    """Same guard as AsyncRunner: the jitted per-instance PPO step cannot
    host an SPMD-only sync closure — fail clearly, not at trace time."""
    from repro.envs import make_env
    from repro.launch.steps import make_drl_train_step

    class _FakeMesh:
        axis_names = ("gpu", "inst")
    comm = Communicator("mrr", grid=(2, 2))
    comm.mesh = _FakeMesh()
    with pytest.raises(TypeError, match="SPMD-only"):
        make_drl_train_step(make_env("Ant"), communicator=comm)


def test_propose_switch_measured_hysteresis():
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=cm)
    assert comm.propose_switch() is None     # nothing measured yet
    comm.observe(1.0)                        # measured: mpr is slow
    assert comm.propose_switch(1.05) == "har3"
    # measured evidence on a candidate beats the model: once har3 has
    # actually measured WORSE than mpr it drops out, and the proposal
    # falls back to the next-best (model-scaled) candidate
    comm.observe(2.0, strategy="har3")
    assert comm.propose_switch(1.05) == "har"
    # marginal disagreement stays put (hysteresis)
    best = Communicator("har3", grid=(2, 2, 2), cost_model=cm)
    best.observe(1.0)
    assert best.propose_switch(1.05) is None


# ------------------------------------------------------ average semantics --
def test_mpr_host_single_average_switch():
    gs = [{"w": jnp.full((4,), float(i))} for i in range(1, 5)]
    mean = mpr_host(gs)
    total = mpr_host(gs, average=False)
    np.testing.assert_allclose(mean["w"], np.full(4, 2.5))
    np.testing.assert_allclose(total["w"], np.full(4, 10.0))


def test_make_grad_sync_rejects_bad_inputs():
    with pytest.raises(ValueError, match="unknown"):
        make_grad_sync("nccl", ("gpu", "inst"))
    with pytest.raises(ValueError, match="at least"):
        make_grad_sync("mrr", ("gpu",))


# -------------------------------------------------------------- lgr shim ---
def test_core_lgr_shim_deprecation_and_reexports():
    sys.modules.pop("repro.core.lgr", None)
    with pytest.warns(DeprecationWarning, match="repro.comm"):
        import repro.core.lgr as lgr
        importlib.reload(lgr)
    from repro.comm import schedules
    assert lgr.mpr_host is schedules.mpr_host
    assert lgr.flat_psum is schedules.flat_psum
    # the shim keeps the OLD calling conventions: lgr_allreduce accepts
    # the legacy axis-name kwargs, and make_grad_sync keeps the raw-sum
    # contract (callers of the deprecated surface divided by g*t
    # themselves)
    import inspect
    sig = inspect.signature(lgr.lgr_allreduce)
    assert "intra_axis" in sig.parameters and "inter_axis" in sig.parameters
    gs = [{"w": jnp.ones((3,))}]
    np.testing.assert_allclose(lgr.mpr_host(gs)["w"], np.ones(3))


# --------------------------------------- controller reduction re-planning --
def _slow_mpr_comm():
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=cm)
    comm.observe(1.0)                        # measured: current is slow
    return comm


def test_controller_emits_reduction_strategy_replan():
    from repro.core.controller import ControllerConfig, OnlineGMIController
    comm = _slow_mpr_comm()
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=1,
                                                 probe=False),
                            communicator=comm)
    from repro.core.controller import RoundSample
    d = c.record(RoundSample(samples=1000, dt=0.1, occupancy=0.5,
                             spills=0, mem_bytes=1e6))
    assert d is not None
    assert d.reduction_strategy == "har3"
    assert "reduce time" in d.reason
    # model state is not the controller's business: nothing else moved,
    # and the decision says so (runners switch in place, no rebuild)
    assert (d.num_env, d.gmi_per_gpu, d.serving_gpus) == (512, 2, 2)
    assert d.layout_changed is False


def test_controller_reduce_hysteresis_no_replan_when_best():
    from repro.core.controller import (ControllerConfig,
                                       OnlineGMIController, RoundSample)
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("har3", grid=(2, 2, 2), cost_model=cm)
    comm.observe(1.0)
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=1,
                                                 probe=False),
                            communicator=comm)
    assert c.record(RoundSample(samples=1000, dt=0.1, occupancy=0.5,
                                spills=0, mem_bytes=1e6)) is None


def test_controller_round_sample_reduce_s_feeds_communicator():
    from repro.core.controller import (ControllerConfig,
                                       OnlineGMIController, RoundSample)
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=cm)
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=2,
                                                 probe=False),
                            communicator=comm)
    c.record(RoundSample(samples=1000, dt=0.1, occupancy=0.5, spills=0,
                         mem_bytes=1e6, reduce_s=0.5))
    assert comm.measured("mpr") == 0.5       # flowed through record()


def test_async_runner_replan_switches_strategy_keeps_model_state():
    """Acceptance: a reduction-strategy re-plan applies through
    AsyncRunner.replan as communication plumbing only — parameters,
    optimizer state, and version survive bit-identically."""
    from repro.core.controller import Decision
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner
    env = make_env("Ant")
    # devices_per_gpu=4 with 2 GMIs/GPU -> 2 chips per GMI: the trainer
    # grid keeps its dev axis across the re-plan, so har3 stays feasible
    layout = plan_async(4, 2, 2, devices=list(range(16)),
                        devices_per_gpu=4)
    comm = _slow_mpr_comm()
    runner = make_async_runner(env, layout, overlap=True,
                               communicator=comm, num_envs=8, num_steps=4)
    runner.round()
    runner.round()
    runner.finish()                          # drain: nothing left in flight
    params_before = jax.tree.map(np.asarray, runner.params)
    opt_mu_before = jax.tree.map(np.asarray, runner.opt_state.mu)
    version_before = int(runner.version)
    runner.layout_builder = lambda d: plan_async(
        4, d.serving_gpus, d.gmi_per_gpu, devices=list(range(16)),
        devices_per_gpu=4)
    runner.replan(Decision(num_env=8, gmi_per_gpu=2, serving_gpus=2,
                           projected_throughput=0.0, reason="test",
                           reduction_strategy="har3"))
    assert runner.communicator.strategy == "har3"
    # the strategy switch is communication plumbing only: params,
    # optimizer state, and version survive bit-identically
    assert int(runner.version) == version_before
    for a, b in zip(jax.tree.leaves(params_before),
                    jax.tree.leaves(jax.tree.map(np.asarray,
                                                 runner.params))):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(opt_mu_before),
                    jax.tree.leaves(jax.tree.map(np.asarray,
                                                 runner.opt_state.mu))):
        np.testing.assert_array_equal(a, b)
    # rounds keep working under the switched schedule
    ls, stale = runner.round()
    ls2, _ = runner.round()
    assert all(np.isfinite(ls + ls2))
    runner.finish()
    assert runner.trained_samples == runner.predictions


def test_async_runner_communicator_contract():
    """The eager runner never times the mesh-less identity closure into
    the switch hysteresis (measured reduce seconds only enter through
    RoundSample.reduce_s / direct observe), and rejects mesh-attached
    communicators outright — their sync closure is SPMD-only."""
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner
    env = make_env("Ant")
    layout = plan_async(2, 1, 2, devices=list(range(4)), devices_per_gpu=2)
    comm = Communicator("mrr", grid=(2, 2))
    runner = make_async_runner(env, layout, communicator=comm,
                               num_envs=8, num_steps=4)
    runner.round()
    runner.round()
    assert comm.measured("mrr") is None      # no-op timings never recorded

    class _FakeMesh:
        axis_names = ("gpu", "inst")
    meshy = Communicator("mrr", grid=(2, 2))
    meshy.mesh = _FakeMesh()
    with pytest.raises(TypeError, match="SPMD-only"):
        make_async_runner(env, layout, communicator=meshy,
                          num_envs=8, num_steps=4)


def test_strategy_only_decision_switches_in_place_without_replan():
    """A decision that moves ONLY the reduction strategy must not pay the
    drain-and-rebuild re-plan: the runner switches the communicator in
    place mid-round-loop."""
    from repro.core.controller import ControllerConfig
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner
    env = make_env("Ant")
    layout = plan_async(4, 2, 2, devices=list(range(8)), devices_per_gpu=2)
    comm = _slow_mpr_comm()
    runner = make_async_runner(
        env, layout, overlap=True, online_controller=True,
        communicator=comm,
        controller_cfg=ControllerConfig(epoch_rounds=1, probe=False,
                                        occ_low=0.0),
        num_envs=8, num_steps=4)
    pipe_before = runner.pipe
    runner.round()                           # overlap: trains one behind
    runner.round()                           # epoch boundary: decision
    assert runner.controller.decisions, "expected a decision"
    d = runner.controller.decisions[0]
    assert d.reduction_strategy == "har3" and not d.layout_changed
    assert runner.communicator.strategy == "har3"
    assert runner.pipe is pipe_before        # no rebuild
    assert runner.replans == 0
    runner.finish()
    assert runner.trained_samples == runner.predictions
