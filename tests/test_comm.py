"""The unified repro.comm subsystem: Algorithm-1 / cost-model strategy
selection, the Communicator object, single-switch average semantics, the
core.lgr removal guard, and the controller's reduction-strategy
re-plan loop.  (Numerical schedule parity on real multi-device grids
lives in tests/_multidev_checks.py — this file runs on one device.)"""
import importlib
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (Communicator, ReduceCostModel, STRATEGIES,
                        algorithm1, as_grad_sync, make_grad_sync, mpr_host,
                        select_reduction_strategy)
from repro.core.cost_model import (lgr_time_har, lgr_time_har3, lgr_time_mpr)
from repro.core.placement import (plan_async, plan_tcg_ex_training,
                                  plan_tcg_serving)


# ------------------------------------------------------------- selection ---
def test_algorithm1_verbatim_reexport():
    """placement.select_reduction_strategy is the comm one, and the
    Algorithm-1 shape logic is unchanged."""
    from repro.core import placement
    assert placement.select_reduction_strategy is select_reduction_strategy
    assert algorithm1([[0, 1, 2]]) == "mpr"
    assert algorithm1([[0], [1]]) == "mrr"
    assert algorithm1([[0, 1, 2], [3, 4]]) == "har"
    assert select_reduction_strategy([[0, 1], [2, 3]]) == "mrr"


def test_cost_model_candidates_and_feasibility():
    cm = ReduceCostModel(dev_per_inst=2)
    assert cm.candidates((2, 2, 2)) == ["mpr", "har", "har3"]   # t*d > g
    assert "mrr" in cm.candidates((4, 2, 1))                    # t <= g
    assert "har3" not in cm.candidates((4, 2, 1))               # no dev axis
    assert cm.candidates((1, 4, 1)) == ["mpr"]                  # single GPU
    with pytest.raises(ValueError, match="dev axis"):
        cm.time("har3", (2, 2, 1))


def test_cost_model_prefers_har3_on_fast_dev_links():
    """Table-2 ordering: with intra-instance links much faster than the
    instance-level domain, the 3-level schedule must win on a
    (gpu, inst, dev) grid — and the verbatim shape logic alone (which is
    dev-blind) would not have picked it."""
    M = 6e6
    B1, B2, B3 = 5e9, 200e9, 400e9
    assert lgr_time_har3(2, 2, 2, M, B1, B2, B3) \
        < lgr_time_har(2, 4, M, B1, B2) < lgr_time_mpr(2, 4, M, B1, B2)
    cm = ReduceCostModel(bw_intra=B1, bw_gpu=B2, bw_dev=B3,
                         bytes_per_round=M, dev_per_inst=2)
    mpl = [[0, 1], [2, 3]]
    assert select_reduction_strategy(mpl) == "mrr"              # shape only
    assert select_reduction_strategy(mpl, cm) == "har3"         # cost-aware
    # ragged layouts can't build an axis mesh: cost path stays in mpr/har
    assert select_reduction_strategy([[0, 1, 2], [3, 4]], cm) in ("mpr",
                                                                  "har")


def test_cost_model_degenerates_without_dev_axis():
    """On a plain (gpu, inst) grid the cost-scored choice agrees with the
    Table-2 best_lgr ordering (har beats mpr on fast interconnects)."""
    cm = ReduceCostModel(bytes_per_round=6e6, dev_per_inst=1)
    s = select_reduction_strategy([[0, 1, 2], [3, 4, 5]], cm)
    assert s == "har"                       # t=3 > g=2: mrr infeasible


# ---------------------------------------------------------- Communicator ---
def test_communicator_from_layouts():
    ex = plan_tcg_ex_training(2, 2, devices=list(range(4)),
                              devices_per_gpu=2)
    comm = ex.communicator()
    assert comm.strategy == ex.reduction_strategy() == "mrr"
    assert comm.grid == (2, 2)
    assert plan_tcg_serving(2, 2, devices=list(range(8)),
                            devices_per_gpu=4).communicator() is None


def test_communicator_multi_device_grid_carries_dev_axis():
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)     # 2 devices each
        mgr.set_gpu(gid, gpu)
    layout = Layout("t", mgr, [], [0, 1, 2, 3])
    comm = layout.communicator()
    assert comm.grid == (2, 2, 2)
    assert comm.cost_model.dev_per_inst == 2
    assert comm.num_instances == 8
    # Algorithm 1 is dev-blind and would say "mrr" here, but mrr breaks
    # the one-ring-endpoint-per-chip rule on this grid (t*d=4 > g=2):
    # construction must land on a FEASIBLE strategy, never a state its
    # own switch() would reject
    assert comm.strategy in comm.candidates()
    # cost-aware construction picks the 3-level schedule here
    comm3 = layout.communicator(cost_model=ReduceCostModel())
    assert comm3.strategy == "har3"


def test_communicator_ragged_layout_restricts_candidates():
    """A ragged layout (unequal GMIs per GPU) has no axis mesh, so the
    communicator's candidate set must stay in mpr/har — switch() to mrr
    must refuse even when the flattened grid shape would allow it."""
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    for gid, gpu, frac in [(0, 0, 0.25), (1, 1, 0.25), (2, 1, 0.25)]:
        mgr.add_gmi(gid, "trainer", frac)
        mgr.set_gpu(gid, gpu)
    layout = Layout("ragged", mgr, [], [0, 1, 2])
    comm = layout.communicator()
    assert comm.uniform is False
    assert set(comm.candidates()) == {"mpr", "har"}
    with pytest.raises(ValueError, match="not feasible"):
        comm.switch("mrr")


def test_communicator_rebind_tracks_new_layout():
    """AsyncRunner.replan rebinds the communicator to the re-planned
    layout: grid/dev axis refresh, stale measurements clear, and an
    infeasible current strategy is coerced to a feasible one."""
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("har3", grid=(2, 2, 2), cost_model=cm)
    comm.observe(1.0)
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=2)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)     # 1 chip each now
        mgr.set_gpu(gid, gpu)
    layout = Layout("replanned", mgr, [], [0, 1, 2, 3])
    comm.rebind(layout)
    assert comm.grid == (2, 2)
    assert comm.cost_model.dev_per_inst == 1
    assert comm.measured("har3") is None     # stale table cleared
    assert comm.strategy in comm.candidates()   # har3 no longer feasible


def test_communicator_from_layout_rejects_mixed_device_counts():
    """Planning as if every GMI were single-chip would silently drop the
    dev axis — mirror instance_mesh and refuse mixed sizes loudly."""
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=4)
    mgr.add_gmi(0, "trainer", 0.5)           # 2 devices
    mgr.set_gpu(0, 0)
    mgr.add_gmi(1, "trainer", 0.25)          # 1 device
    mgr.set_gpu(1, 1)
    layout = Layout("mixed", mgr, [], [0, 1])
    with pytest.raises(ValueError, match="mixed devices-per-GMI"):
        layout.communicator()


def test_communicator_duck_types_as_grad_sync():
    comm = Communicator("mrr", grid=(2, 2))
    fn = as_grad_sync(comm)
    g = {"w": jnp.ones((3,))}
    assert fn(g)["w"].shape == (3,)          # identity without a mesh
    assert as_grad_sync(None) is None
    plain = lambda x: x                                         # noqa: E731
    assert as_grad_sync(plain) is plain


def test_communicator_switch_is_pure_plumbing():
    comm = Communicator("mpr", grid=(2, 2, 2),
                        cost_model=ReduceCostModel(dev_per_inst=2))
    comm.observe(1.0, 6e6)
    comm.observe(0.1, 6e6, strategy="har3")
    assert comm.switch("har3") is comm
    assert comm.strategy == "har3"
    # stale measurements of non-active strategies are dropped (one bad
    # early sample must not outrank the model forever); the new active
    # strategy keeps its live record
    assert comm.measured("mpr") is None
    assert comm.measured("har3") == 0.1
    with pytest.raises(ValueError, match="not feasible"):
        comm.switch("mrr")                   # t*d > g on this grid
    with pytest.raises(ValueError, match="unknown"):
        comm.switch("ring-of-fire")


def test_make_drl_train_step_rejects_mesh_attached_communicator():
    """Same guard as AsyncRunner: the jitted per-instance PPO step cannot
    host an SPMD-only sync closure — fail clearly, not at trace time."""
    from repro.envs import make_env
    from repro.launch.steps import make_drl_train_step

    class _FakeMesh:
        axis_names = ("gpu", "inst")
    comm = Communicator("mrr", grid=(2, 2))
    comm.mesh = _FakeMesh()
    with pytest.raises(TypeError, match="SPMD-only"):
        make_drl_train_step(make_env("Ant"), communicator=comm)


def test_propose_switch_measured_hysteresis():
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=cm)
    assert comm.propose_switch() is None     # nothing measured yet
    for _ in range(3):                       # persistent: mpr is slow
        comm.observe(1.0)
    assert comm.propose_switch(1.05) == "har3"
    # measured evidence on a candidate beats the model: once har3 has
    # actually measured WORSE than mpr (steady state, not a lone compile
    # round) it drops out, and the proposal falls back to the next-best
    # (model-scaled) candidate
    comm.observe(2.0, strategy="har3")
    comm.observe(2.0, strategy="har3")
    assert comm.propose_switch(1.05) == "har"
    # marginal disagreement stays put (hysteresis)
    best = Communicator("har3", grid=(2, 2, 2), cost_model=cm)
    for _ in range(3):
        best.observe(1.0)
    assert best.propose_switch(1.05) is None


def test_observe_discards_compile_round_first_sample():
    """Satellite bugfix: the per-strategy EMA used to be SEEDED with the
    first observation — on any jitted path the compile round, exactly
    the stale one-off sample switch() warns about.  A synthetic 100x
    slower first sample must vanish from the EMA at the second."""
    comm = Communicator("mpr", grid=(2, 2))
    comm.observe(100.0)                      # compile round: 100x slower
    assert comm.measured("mpr") == 100.0     # provisional until steady
    comm.observe(1.0)
    assert comm.measured("mpr") == 1.0       # reseeded, poison discarded
    comm.observe(1.0)
    assert comm.measured("mpr") == pytest.approx(1.0)
    # had the 100x sample stayed in a 0.5-EMA it would still be ~25x off
    # here; the steady-state table must not remember it at all
    sec, nbytes, count = comm.measurements()["mpr"]
    assert sec == pytest.approx(1.0) and count == 3


def test_propose_switch_needs_min_observation_count():
    """Satellite bugfix: propose_switch used to fire off a SINGLE
    observation of the current strategy — one GC pause could trigger a
    drain-free switch.  1-2 noisy samples never switch; persistent
    evidence still does."""
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=cm)
    comm.observe(50.0)                       # one GC-pause-sized outlier
    assert comm.propose_switch(1.05) is None
    comm.observe(1.0)
    assert comm.propose_switch(1.05) is None  # still below min_count
    comm.observe(1.0)
    assert comm.propose_switch(1.05) == "har3"   # persistent evidence
    # the knob is honest: a higher floor keeps refusing
    assert comm.propose_switch(1.05, min_count=10) is None


# ------------------------------------------------------ average semantics --
def test_mpr_host_single_average_switch():
    gs = [{"w": jnp.full((4,), float(i))} for i in range(1, 5)]
    mean = mpr_host(gs)
    total = mpr_host(gs, average=False)
    np.testing.assert_allclose(mean["w"], np.full(4, 2.5))
    np.testing.assert_allclose(total["w"], np.full(4, 10.0))


def test_make_grad_sync_rejects_bad_inputs():
    with pytest.raises(ValueError, match="unknown"):
        make_grad_sync("nccl", ("gpu", "inst"))
    with pytest.raises(ValueError, match="at least"):
        make_grad_sync("mrr", ("gpu",))


# -------------------------------------------------------------- lgr shim ---
def test_core_lgr_shim_removed():
    # the PR 3 deprecation shim is gone for good: importing it must fail
    # outright rather than silently resurrecting the old surface
    sys.modules.pop("repro.core.lgr", None)
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.core.lgr")
    import repro.core
    with pytest.raises(AttributeError):
        repro.core.lgr  # no lazy __getattr__ hook left either


# ------------------------------------------------- bandwidth calibration ---
def _planted_truth():
    """This-host-like ground truth: the host-staged instance-level domain
    is FAST and the cross-GPU interconnect slow — the regime where the
    static defaults mis-rank strategies (ROADMAP: mpr wins here while
    the Table-2 defaults say otherwise)."""
    return ReduceCostModel(bw_intra=400e9, bw_gpu=5e9, bw_dev=50e9,
                           bytes_per_round=6e6, dev_per_inst=2)


def _feed(comm_or_cal, truth, grid, strategies, n=3, noise=0.0, seed=0):
    rng = np.random.default_rng(seed)
    for s in strategies:
        for _ in range(n):
            sec = truth.time(s, grid) * (1 + noise * rng.standard_normal())
            if isinstance(comm_or_cal, Communicator):
                comm_or_cal.observe(sec, 6e6, strategy=s)
            else:
                comm_or_cal.add(s, grid, sec, 6e6)


def _feed_transfers(comm, truth, n=2, nbytes=1e6):
    """Channel-transfer telemetry consistent with the planted B1 — the
    redundant evidence the fit demands before trusting its residual."""
    for _ in range(n):
        comm.observe_transfer(nbytes / truth.bw_intra, nbytes)


def test_calibrator_recovers_planted_bandwidths_2x2():
    from repro.comm import BandwidthCalibrator
    truth = _planted_truth()
    cal = BandwidthCalibrator(base=ReduceCostModel(bytes_per_round=6e6))
    _feed(cal, truth, (2, 2), ("mpr", "mrr", "har"))
    fit = cal.fit()
    assert fit is not None
    assert fit.bw_intra == pytest.approx(400e9, rel=0.10)
    assert fit.bw_gpu == pytest.approx(5e9, rel=0.10)
    # no dev axis anywhere in the evidence: B3 stays the base default
    assert fit.solved == ("B1", "B2")
    assert fit.bw_dev == cal.base.bw_dev


def test_calibrator_recovers_planted_bandwidths_2x2x2_all_strategies():
    """Acceptance: all four strategy forms, both grids, noisy timings —
    every planted bandwidth recovered within 10%."""
    from repro.comm import BandwidthCalibrator
    truth = _planted_truth()
    cal = BandwidthCalibrator(base=ReduceCostModel(bytes_per_round=6e6,
                                                   dev_per_inst=2))
    _feed(cal, truth, (2, 2), ("mpr", "mrr", "har"), noise=0.02)
    _feed(cal, truth, (2, 2, 2), ("mpr", "har", "har3"), noise=0.02,
          seed=1)
    fit = cal.fit()
    assert fit is not None
    assert fit.solved == ("B1", "B2", "B3")
    assert sorted(fit.strategies) == ["har", "har3", "mpr", "mrr"]
    assert fit.bw_intra == pytest.approx(400e9, rel=0.10)
    assert fit.bw_gpu == pytest.approx(5e9, rel=0.10)
    assert fit.bw_dev == pytest.approx(50e9, rel=0.10)


def test_calibrator_refuses_ill_conditioned_input():
    """One strategy observed — however many samples — cannot separate
    the axes it mixes: no model is emitted.  Neither is one for an
    exactly-determined system (zero residual by construction, so noise
    would be accepted blindly)."""
    from repro.comm import BandwidthCalibrator
    cal = BandwidthCalibrator()
    for _ in range(20):
        cal.add("har", (2, 2), 1e-3, 6e6)
    assert cal.fit() is None
    assert cal.calibrated_model() is None
    # below the per-cell sample floor nothing fits either
    thin = BandwidthCalibrator(min_count=3)
    thin.add("mpr", (2, 2), 1e-3, 6e6)
    thin.add("har", (2, 2), 1e-3, 6e6)
    assert thin.fit() is None
    # two cells over two axes is square: refused until a redundant
    # equation lets the residual gate actually see disagreement
    truth = _planted_truth()
    square = BandwidthCalibrator(base=ReduceCostModel(bytes_per_round=6e6))
    _feed(square, truth, (2, 2), ("mpr", "har"))
    assert square.fit() is None
    _feed(square, truth, (4, 2), ("har",))
    assert square.fit() is not None


def test_calibrator_residual_gate_rejects_inconsistent_evidence():
    """A redundant system whose equations disagree wildly (timings that
    no bandwidth assignment explains) must not emit a model."""
    from repro.comm import BandwidthCalibrator
    truth = _planted_truth()
    cal = BandwidthCalibrator(base=ReduceCostModel(bytes_per_round=6e6))
    _feed(cal, truth, (2, 2), ("mpr", "mrr", "har"))
    assert cal.fit() is not None
    # an mpr cell on another grid claiming 50x the consistent B1 rate
    for _ in range(3):
        cal.add("mpr", (4, 2), truth.time("mpr", (4, 2)) * 50.0, 6e6)
    assert cal.fit() is None                 # residual gate refuses


def test_calibrator_transfer_timings_condition_b1():
    """Channel-transfer timings are B1 evidence: mrr alone only sees B2,
    but together with the pipeline's transfer stream the fit conditions."""
    from repro.comm import BandwidthCalibrator
    truth = _planted_truth()
    cal = BandwidthCalibrator(base=ReduceCostModel(bytes_per_round=6e6))
    _feed(cal, truth, (2, 2), ("mrr",))
    _feed(cal, truth, (4, 2), ("mrr",))      # second cell, still B2-only
    assert cal.fit() is None                 # ill-conditioned
    for _ in range(3):
        cal.add_transfer(1e6 / 400e9, 1e6)   # 1 MB over the planted B1
    fit = cal.fit()
    assert fit is not None
    assert fit.bw_intra == pytest.approx(400e9, rel=0.10)
    assert fit.bw_gpu == pytest.approx(5e9, rel=0.10)


def test_calibrated_communicator_flips_selection():
    """Acceptance: a Communicator under the calibrated model selects the
    planted-best strategy on a grid where the static defaults pick
    wrongly — and estimate()/candidates() re-score transparently."""
    base = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    truth = _planted_truth()
    comm = Communicator("har3", grid=(2, 2, 2), cost_model=base,
                        calibrate=True)
    assert base.best((2, 2, 2)) == "har3"        # static defaults: wrong
    assert truth.best((2, 2, 2)) == "mpr"        # planted reality
    assert comm.calibrated_cost_model() is None  # nothing measured yet
    _feed(comm, truth, (2, 2, 2), comm.candidates(), noise=0.02)
    _feed_transfers(comm, truth)                 # redundant B1 evidence
    cm = comm.calibrated_cost_model()
    assert cm is not None and comm.calibrated
    assert cm.best((2, 2, 2)) == "mpr"
    assert comm.effective_cost_model is cm
    # estimate() now answers with measured-bandwidth predictions
    assert comm.estimate("mpr") == pytest.approx(
        truth.time("mpr", (2, 2, 2)), rel=0.10)
    # and the live proposal agrees past the hysteresis
    assert comm.propose_switch(1.05) == "mpr"


def test_calibrated_flip_respects_hysteresis():
    """A calibrated model that disagrees with the default flips selection
    ONLY past the 1.05x hysteresis."""
    def comm_with(bw_gpu):
        truth = ReduceCostModel(bw_intra=100e9, bw_gpu=bw_gpu,
                                bytes_per_round=6e6)
        comm = Communicator("har", grid=(2, 2), calibrate=True,
                            cost_model=ReduceCostModel(bytes_per_round=6e6))
        _feed(comm, truth, (2, 2), ("har", "mrr"))
        _feed_transfers(comm, truth)
        assert comm.calibrated
        return comm
    # t_har/t_mpr = (x1+x2)/(1.5*x1): B2 = B1/0.545 -> ratio ~1.03 < 1.05
    assert comm_with(100e9 / 0.545).propose_switch(1.05) is None
    # B2 = B1/1.25 -> ratio 1.5 > 1.05: the flip to mpr goes through
    assert comm_with(100e9 / 1.25).propose_switch(1.05) == "mpr"


def test_communicator_propose_probe_conditions_the_fit():
    """While the fit lacks evidence the communicator names feasible
    strategies to measure; a probe in progress is left alone until its
    cell fills (one visit per candidate, never bounced and revisited);
    once every candidate is measured it stops."""
    truth = _planted_truth()
    base = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=base,
                        calibrate=True)
    assert comm.propose_probe() is None      # measure where we stand first
    _feed(comm, truth, (2, 2, 2), ("mpr",))
    probe = comm.propose_probe()
    assert probe in ("har", "har3")
    comm.switch(probe)                       # what the controller applies
    comm.observe(truth.time(probe, comm.grid))   # compile round: discarded
    comm.observe(truth.time(probe, comm.grid))   # first steady sample
    assert comm.propose_probe() is None      # probe still collecting: stay
    comm.observe(truth.time(probe, comm.grid))   # cell reaches min_count
    probe2 = comm.propose_probe()
    assert probe2 not in (None, probe, "mpr")
    _feed(comm, truth, (2, 2, 2), (probe2,))
    assert comm.propose_probe() is None      # every candidate measured
    _feed_transfers(comm, truth)             # redundancy -> fit conditions
    assert comm.calibrated
    # without calibration there is nothing to condition: never probes
    plain = Communicator("mpr", grid=(2, 2, 2), cost_model=base)
    _feed(plain, truth, (2, 2, 2), ("mpr",))
    assert plain.propose_probe() is None


def test_communicator_rebind_keeps_calibration_observations():
    """Measured bandwidths are machine properties: a layout re-plan
    clears the per-strategy EMA table but NOT the calibration evidence
    (each observation carries its grid)."""
    from repro.core.gmi import GMIManager
    from repro.core.placement import Layout
    truth = _planted_truth()
    comm = Communicator("mpr", grid=(2, 2, 2),
                        cost_model=ReduceCostModel(dev_per_inst=2,
                                                   bytes_per_round=6e6),
                        calibrate=True)
    _feed(comm, truth, (2, 2, 2), ("mpr", "har", "har3"))
    _feed_transfers(comm, truth)
    assert comm.calibrated
    mgr = GMIManager(devices=list(range(8)), devices_per_gpu=2)
    for gid, gpu in [(0, 0), (1, 0), (2, 1), (3, 1)]:
        mgr.add_gmi(gid, "trainer", 0.5)     # 1 chip each now
        mgr.set_gpu(gid, gpu)
    comm.rebind(Layout("replanned", mgr, [], [0, 1, 2, 3]))
    assert comm.grid == (2, 2)
    assert comm.measured("mpr") is None      # EMA table cleared...
    assert comm.calibrated                   # ...calibration survives
    # and the calibrated bandwidths keep steering the NEW grid, where
    # the planted truth again favors the host-staged baseline
    assert comm.effective_cost_model.best((2, 2)) == \
        truth.best((2, 2))


def test_make_async_runner_calibrate_wires_the_loop():
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner
    env = make_env("Ant")
    layout = plan_async(2, 1, 2, devices=list(range(4)), devices_per_gpu=2)
    runner = make_async_runner(env, layout, calibrate=True,
                               num_envs=8, num_steps=4)
    assert runner.communicator is not None
    assert runner.communicator.calibrator is not None
    # transfer telemetry flows: rounds produce pipeline transfer samples
    runner.round()
    assert runner.pipe.take_transfer_samples()
    runner.finish()


# --------------------------------------- controller reduction re-planning --
def _slow_mpr_comm():
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=cm)
    for _ in range(3):
        comm.observe(1.0)                    # persistent: current is slow
    return comm


def test_controller_emits_reduction_strategy_replan():
    from repro.core.controller import ControllerConfig, OnlineGMIController
    comm = _slow_mpr_comm()
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=1,
                                                 probe=False),
                            communicator=comm)
    from repro.core.controller import RoundSample
    d = c.record(RoundSample(samples=1000, dt=0.1, occupancy=0.5,
                             spills=0, mem_bytes=1e6))
    assert d is not None
    assert d.reduction_strategy == "har3"
    assert "reduce time" in d.reason
    # model state is not the controller's business: nothing else moved,
    # and the decision says so (runners switch in place, no rebuild)
    assert (d.num_env, d.gmi_per_gpu, d.serving_gpus) == (512, 2, 2)
    assert d.layout_changed is False


def test_controller_reduce_hysteresis_no_replan_when_best():
    from repro.core.controller import (ControllerConfig,
                                       OnlineGMIController, RoundSample)
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("har3", grid=(2, 2, 2), cost_model=cm)
    for _ in range(3):
        comm.observe(1.0)
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=1,
                                                 probe=False),
                            communicator=comm)
    assert c.record(RoundSample(samples=1000, dt=0.1, occupancy=0.5,
                                spills=0, mem_bytes=1e6)) is None


def test_controller_schedules_calibration_probe():
    """Algorithm-2 explore for communication: while the calibration fit
    lacks evidence the controller emits an in-place probe of an
    unmeasured strategy (layout untouched)."""
    from repro.core.controller import (ControllerConfig,
                                       OnlineGMIController, RoundSample)
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=cm,
                        calibrate=True)
    for _ in range(3):
        comm.observe(1.0)                    # current strategy measured
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=1,
                                                 min_gain=1e9,  # no switch
                                                 probe=True,
                                                 num_env_sweep=(512,)),
                            communicator=comm)
    d = c.record(RoundSample(samples=1000, dt=0.1, occupancy=0.5,
                             spills=0, mem_bytes=1e6))
    assert d is not None
    assert d.reduction_strategy in ("har", "har3")
    assert d.layout_changed is False
    assert "probe reduction strategy" in d.reason


def test_controller_cites_calibrated_bandwidths():
    """A switch decision taken under a conditioned fit says so — the
    re-plan cites calibrated, not default, bandwidths."""
    from repro.core.controller import (ControllerConfig,
                                       OnlineGMIController, RoundSample)
    truth = _planted_truth()
    comm = Communicator("har3", grid=(2, 2, 2),
                        cost_model=ReduceCostModel(dev_per_inst=2,
                                                   bytes_per_round=6e6),
                        calibrate=True)
    _feed(comm, truth, (2, 2, 2), comm.candidates())
    _feed_transfers(comm, truth)
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=1,
                                                 probe=False),
                            communicator=comm)
    d = c.record(RoundSample(samples=1000, dt=0.1, occupancy=0.5,
                             spills=0, mem_bytes=1e6))
    assert d is not None and d.reduction_strategy == "mpr"
    assert "calibrated Table-2 bandwidths" in d.reason


def test_controller_forwards_pipeline_transfer_timings():
    from repro.core.controller import ControllerConfig, OnlineGMIController

    class _Pipe:
        spill_count = 0

        class stats:
            total_bytes = 0

        def take_occupancy_high_water(self):
            return 0.5

        def take_transfer_samples(self):
            return [(0.001, 1_000_000)]

    comm = Communicator("mpr", grid=(2, 2), calibrate=True)
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=4,
                                                 probe=False),
                            communicator=comm)
    c.observe_pipeline(_Pipe(), samples=8, dt=0.1)
    assert comm.calibrator.transfer_count == 1


def test_controller_round_sample_reduce_s_feeds_communicator():
    from repro.core.controller import (ControllerConfig,
                                       OnlineGMIController, RoundSample)
    cm = ReduceCostModel(dev_per_inst=2, bytes_per_round=6e6)
    comm = Communicator("mpr", grid=(2, 2, 2), cost_model=cm)
    c = OnlineGMIController(num_gpu=4, serving_gpus=2, gmi_per_gpu=2,
                            num_env=512,
                            cfg=ControllerConfig(epoch_rounds=2,
                                                 probe=False),
                            communicator=comm)
    c.record(RoundSample(samples=1000, dt=0.1, occupancy=0.5, spills=0,
                         mem_bytes=1e6, reduce_s=0.5))
    assert comm.measured("mpr") == 0.5       # flowed through record()


def test_async_runner_replan_switches_strategy_keeps_model_state():
    """Acceptance: a reduction-strategy re-plan applies through
    AsyncRunner.replan as communication plumbing only — parameters,
    optimizer state, and version survive bit-identically."""
    from repro.core.controller import Decision
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner
    env = make_env("Ant")
    # devices_per_gpu=4 with 2 GMIs/GPU -> 2 chips per GMI: the trainer
    # grid keeps its dev axis across the re-plan, so har3 stays feasible
    layout = plan_async(4, 2, 2, devices=list(range(16)),
                        devices_per_gpu=4)
    comm = _slow_mpr_comm()
    runner = make_async_runner(env, layout, overlap=True,
                               communicator=comm, num_envs=8, num_steps=4)
    runner.round()
    runner.round()
    runner.finish()                          # drain: nothing left in flight
    params_before = jax.tree.map(np.asarray, runner.params)
    opt_mu_before = jax.tree.map(np.asarray, runner.opt_state.mu)
    version_before = int(runner.version)
    runner.layout_builder = lambda d: plan_async(
        4, d.serving_gpus, d.gmi_per_gpu, devices=list(range(16)),
        devices_per_gpu=4)
    runner.replan(Decision(num_env=8, gmi_per_gpu=2, serving_gpus=2,
                           reason="test", reduction_strategy="har3"))
    assert runner.communicator.strategy == "har3"
    # the strategy switch is communication plumbing only: params,
    # optimizer state, and version survive bit-identically
    assert int(runner.version) == version_before
    for a, b in zip(jax.tree.leaves(params_before),
                    jax.tree.leaves(jax.tree.map(np.asarray,
                                                 runner.params))):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(opt_mu_before),
                    jax.tree.leaves(jax.tree.map(np.asarray,
                                                 runner.opt_state.mu))):
        np.testing.assert_array_equal(a, b)
    # rounds keep working under the switched schedule
    ls, stale = runner.round()
    ls2, _ = runner.round()
    assert all(np.isfinite(ls + ls2))
    runner.finish()
    assert runner.trained_samples == runner.predictions


def test_async_runner_communicator_contract():
    """The eager runner never times the mesh-less identity closure into
    the switch hysteresis (measured reduce seconds only enter through
    RoundSample.reduce_s / direct observe), and rejects mesh-attached
    communicators outright — their sync closure is SPMD-only."""
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner
    env = make_env("Ant")
    layout = plan_async(2, 1, 2, devices=list(range(4)), devices_per_gpu=2)
    comm = Communicator("mrr", grid=(2, 2))
    runner = make_async_runner(env, layout, communicator=comm,
                               num_envs=8, num_steps=4)
    runner.round()
    runner.round()
    assert comm.measured("mrr") is None      # no-op timings never recorded

    class _FakeMesh:
        axis_names = ("gpu", "inst")
    meshy = Communicator("mrr", grid=(2, 2))
    meshy.mesh = _FakeMesh()
    with pytest.raises(TypeError, match="SPMD-only"):
        make_async_runner(env, layout, communicator=meshy,
                          num_envs=8, num_steps=4)


def test_strategy_only_decision_switches_in_place_without_replan():
    """A decision that moves ONLY the reduction strategy must not pay the
    drain-and-rebuild re-plan: the runner switches the communicator in
    place mid-round-loop."""
    from repro.core.controller import ControllerConfig
    from repro.envs import make_env
    from repro.launch.steps import make_async_runner
    env = make_env("Ant")
    layout = plan_async(4, 2, 2, devices=list(range(8)), devices_per_gpu=2)
    comm = _slow_mpr_comm()
    runner = make_async_runner(
        env, layout, overlap=True, online_controller=True,
        communicator=comm,
        controller_cfg=ControllerConfig(epoch_rounds=1, probe=False,
                                        occ_low=0.0),
        num_envs=8, num_steps=4)
    pipe_before = runner.pipe
    runner.round()                           # overlap: trains one behind
    runner.round()                           # epoch boundary: decision
    assert runner.controller.decisions, "expected a decision"
    d = runner.controller.decisions[0]
    assert d.reduction_strategy == "har3" and not d.layout_changed
    assert runner.communicator.strategy == "har3"
    assert runner.pipe is pipe_before        # no rebuild
    assert runner.replans == 0
    runner.finish()
    assert runner.trained_samples == runner.predictions
