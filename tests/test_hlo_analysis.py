"""The roofline parser must multiply while-loop bodies by trip counts."""
from repro.launch.hlo_analysis import analyze, _nbytes

SYNTH = """
HloModule test

%body.1 (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %g = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %ar = f32[128,128]{1,0} all-reduce(%g), channel_id=1, to_apply=%sum.1
  %d = f32[128,128]{1,0} dot(%ar, %g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %i = s32[] constant(0)
  ROOT %t = (s32[], f32[128,128]) tuple(%i, %d)
}

%cond.1 (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %init = (s32[], f32[128,128]) tuple(%i0, %a)
  %w = (s32[], f32[128,128]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"10"}}
  %ag = f32[256,128]{1,0} all-gather(%a), channel_id=2, dimensions={0}
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_nbytes():
    assert _nbytes("f32[128,128]") == 128 * 128 * 4
    assert _nbytes("(bf16[4,2], s32[3])") == 16 + 12
    assert _nbytes("pred[]") == 1


def test_loop_multiplication():
    res = analyze(SYNTH)
    ar_bytes = 128 * 128 * 4
    ag_bytes = 256 * 128 * 4
    # all-reduce inside the x10 loop + one all-gather outside
    assert res["collective_bytes"] == 10 * ar_bytes + ag_bytes
    assert res["coll_counts"]["all-reduce"] == 10
    assert res["coll_counts"]["all-gather"] == 1
    # dot: 2 * 128*128 * 128 per iteration, x10
    assert res["dot_flops"] == 10 * 2 * 128 * 128 * 128


def test_no_loops_plain_counting():
    plain = """
ENTRY %main (a: f32[64,32]) -> f32[64,64] {
  %a = f32[64,32]{1,0} parameter(0)
  ROOT %d = f32[64,64]{1,0} dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={1}
}
"""
    res = analyze(plain)
    assert res["dot_flops"] == 2 * 64 * 64 * 32
    assert res["collective_bytes"] == 0
