import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load, save


def test_roundtrip(tmp_path):
    key = jax.random.key(0)
    tree = {"a": {"w": jax.random.normal(key, (4, 3)),
                  "b": jnp.arange(5, dtype=jnp.int32)},
            "scale": jnp.float32(2.5)}
    path = str(tmp_path / "ckpt_10")
    save(path, tree, step=10)
    back = load(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_missing_key_raises(tmp_path):
    tree = {"w": jnp.ones((2,))}
    path = str(tmp_path / "ckpt_0")
    save(path, tree)
    with pytest.raises(KeyError):
        load(path, {"w": jnp.ones((2,)), "extra": jnp.ones((1,))})


def test_latest_step(tmp_path):
    for s in (3, 12, 7):
        save(str(tmp_path / f"ckpt_{s}"), {"x": jnp.ones(1)}, step=s)
    assert latest_step(str(tmp_path)) == 12
    assert latest_step(str(tmp_path / "missing")) is None


def test_model_checkpoint_roundtrip(tmp_path):
    from repro.configs import get_reduced
    from repro.models import transformer as T
    cfg = get_reduced("internlm2-1.8b")
    params = T.init_model(jax.random.key(1), cfg)
    path = str(tmp_path / "model")
    save(path, params)
    back = load(path, params)
    toks = jax.random.randint(jax.random.key(2), (1, 8), 0, cfg.vocab_size)
    l1, _ = T.forward(params, cfg, {"tokens": toks})
    l2, _ = T.forward(back, cfg, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
