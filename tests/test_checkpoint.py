import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load, load_manifest, save, steps


def test_roundtrip(tmp_path):
    key = jax.random.key(0)
    tree = {"a": {"w": jax.random.normal(key, (4, 3)),
                  "b": jnp.arange(5, dtype=jnp.int32)},
            "scale": jnp.float32(2.5)}
    path = str(tmp_path / "ckpt_10")
    save(path, tree, step=10)
    back = load(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_missing_key_raises(tmp_path):
    tree = {"w": jnp.ones((2,))}
    path = str(tmp_path / "ckpt_0")
    save(path, tree)
    with pytest.raises(KeyError):
        load(path, {"w": jnp.ones((2,)), "extra": jnp.ones((1,))})


def test_latest_step(tmp_path):
    for s in (3, 12, 7):
        save(str(tmp_path / f"ckpt_{s}"), {"x": jnp.ones(1)}, step=s)
    assert latest_step(str(tmp_path)) == 12
    assert latest_step(str(tmp_path / "missing")) is None


def test_extra_rides_in_manifest(tmp_path):
    path = str(tmp_path / "ckpt_1")
    save(path, {"x": jnp.ones(2)}, step=1,
         extra={"predictions": 42, "nested": {"k": [1, 2]}})
    m = load_manifest(path)
    assert m["step"] == 1
    assert m["extra"] == {"predictions": 42, "nested": {"k": [1, 2]}}


# ------------------------------------------------------- crash hardening --
def _tree():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.int32(7)}


def test_atomic_save_crash_at_every_stage_keeps_previous_pair(tmp_path):
    from repro.checkpoint.ckpt import SAVE_STAGES
    path = str(tmp_path / "ckpt_5")
    save(path, _tree(), step=5)
    bumped = {"w": _tree()["w"] + 100.0, "b": jnp.int32(8)}

    class Crash(RuntimeError):
        pass

    for stage in SAVE_STAGES:
        def hook(at, stage=stage):
            if at == stage:
                raise Crash(stage)
        with pytest.raises(Crash):
            save(path, bumped, step=5, fault_hook=hook)
        # whatever stage the "preemption" hit, the directory still holds
        # a loadable pair; only the manifest-replace boundary commits
        assert steps(str(tmp_path)) == [5]
        back = load(path, _tree())
        got = float(np.asarray(back["w"]).ravel()[0])
        assert got in (0.0, 100.0)     # old pair or fully-committed new


def test_torn_npz_load_raises_latest_step_skips(tmp_path):
    good = str(tmp_path / "ckpt_1")
    torn = str(tmp_path / "ckpt_2")
    save(good, _tree(), step=1)
    save(torn, _tree(), step=2)
    size = os.path.getsize(torn + ".npz")
    with open(torn + ".npz", "r+b") as f:
        f.truncate(size // 3)
    with pytest.raises(ValueError, match="torn"):
        load(torn, _tree())
    # a truncated-but-present npz still lists (it exists); the torn-PAIR
    # skip is for manifests whose npz is gone entirely
    os.remove(torn + ".npz")
    assert steps(str(tmp_path)) == [1]
    assert latest_step(str(tmp_path)) == 1
    with pytest.raises(FileNotFoundError):
        load(torn, _tree())
    back = load(good, _tree())
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(_tree()["w"]))


def test_template_key_mismatches_raise(tmp_path):
    path = str(tmp_path / "ckpt_0")
    save(path, {"w": jnp.ones(2), "b": jnp.ones(3)})
    # checkpoint key absent from the template
    with pytest.raises(KeyError, match="not in template"):
        load(path, {"w": jnp.ones(2)})
    # template key absent from the checkpoint
    with pytest.raises(KeyError, match="missing"):
        load(path, {"w": jnp.ones(2), "b": jnp.ones(3),
                    "extra": jnp.ones(1)})


def test_model_checkpoint_roundtrip(tmp_path):
    from repro.configs import get_reduced
    from repro.models import transformer as T
    cfg = get_reduced("internlm2-1.8b")
    params = T.init_model(jax.random.key(1), cfg)
    path = str(tmp_path / "model")
    save(path, params)
    back = load(path, params)
    toks = jax.random.randint(jax.random.key(2), (1, 8), 0, cfg.vocab_size)
    l1, _ = T.forward(params, cfg, {"tokens": toks})
    l2, _ = T.forward(back, cfg, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
