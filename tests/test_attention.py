import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (_chunked_attention, _direct_attention,
                                    attention, init_attention_params,
                                    make_cache)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 16])
def test_chunked_matches_direct(causal, window):
    key = jax.random.key(0)
    B, S, H, KH, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.key(1), (B, S, KH, hd))
    v = jax.random.normal(jax.random.key(2), (B, S, KH, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o1 = _direct_attention(q, k, v, pos, pos, causal, window, None, hd**-0.5)
    o2 = _chunked_attention(q, k, v, pos, pos, causal, window, None,
                            hd**-0.5, q_block=16, kv_block=16)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


def test_chunked_with_softcap_and_ragged_blocks():
    key = jax.random.key(3)
    B, S, H, hd = 1, 50, 2, 8      # 50 does not divide the block size
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.key(4), (B, S, H, hd))
    v = jax.random.normal(jax.random.key(5), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o1 = _direct_attention(q, k, v, pos, pos, True, None, 25.0, hd**-0.5)
    o2 = _chunked_attention(q, k, v, pos, pos, True, None, 25.0, hd**-0.5,
                            q_block=16, kv_block=16)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


def test_ring_cache_decode_matches_full_cache():
    """Sliding-window decode via ring buffer == full cache + window mask."""
    key = jax.random.key(6)
    D, H, KH, hd, W = 32, 4, 2, 8, 8
    p = init_attention_params(key, D, H, KH, hd)
    B, S = 2, 24
    xs = jax.random.normal(key, (B, S, D))
    ring = make_cache(B, S, KH, hd, window=W)
    full = make_cache(B, S, KH, hd, window=None)
    assert ring.k.shape[1] == W and full.k.shape[1] == S
    for t in range(S):
        pos = jnp.full((B, 1), t, jnp.int32)
        o_r, ring = attention(p, xs[:, t:t+1], num_heads=H, num_kv_heads=KH,
                              head_dim=hd, positions=pos, window=W,
                              cache=ring)
        o_f, full = attention(p, xs[:, t:t+1], num_heads=H, num_kv_heads=KH,
                              head_dim=hd, positions=pos, window=W,
                              cache=full)
        np.testing.assert_allclose(o_r, o_f, rtol=1e-5, atol=1e-5)


def test_prefill_writes_tail_into_ring():
    key = jax.random.key(7)
    D, H, KH, hd, W = 16, 2, 2, 8, 4
    p = init_attention_params(key, D, H, KH, hd)
    B, S = 1, 10
    x = jax.random.normal(key, (B, S, D))
    cache = make_cache(B, S, KH, hd, window=W)
    pos = jnp.arange(S)[None]
    _, cache = attention(p, x, num_heads=H, num_kv_heads=KH, head_dim=hd,
                         positions=pos, window=W, cache=cache)
    # slots must hold the last W absolute positions
    assert sorted(np.asarray(cache.slot_pos[0]).tolist()) == [6, 7, 8, 9]
