import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adam_init, adam_update, cosine_warmup, sgd_init,
                         sgd_update)


def _numpy_adam(params, grads, steps, lr, b1, b2, eps):
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v = {k: np.zeros_like(vv) for k, vv in params.items()}
    p = {k: vv.copy() for k, vv in params.items()}
    for t in range(1, steps + 1):
        for k in p:
            g = grads[k]
            m[k] = b1 * m[k] + (1 - b1) * g
            v[k] = b2 * v[k] + (1 - b2) * g * g
            mh = m[k] / (1 - b1 ** t)
            vh = v[k] / (1 - b2 ** t)
            p[k] -= lr * mh / (np.sqrt(vh) + eps)
    return p


def test_adam_matches_numpy_reference():
    key = jax.random.key(0)
    params = {"w": jax.random.normal(key, (4, 3)),
              "b": jax.random.normal(jax.random.key(1), (3,))}
    grads = {"w": jax.random.normal(jax.random.key(2), (4, 3)),
             "b": jax.random.normal(jax.random.key(3), (3,))}
    st = adam_init(params)
    p = params
    for _ in range(5):
        p, st = adam_update(grads, st, p, lr=1e-2, beta1=0.9, beta2=0.999)
    want = _numpy_adam({k: np.asarray(v) for k, v in params.items()},
                       {k: np.asarray(v) for k, v in grads.items()},
                       5, 1e-2, 0.9, 0.999, 1e-8)
    for k in p:
        np.testing.assert_allclose(np.asarray(p[k]), want[k], rtol=1e-5,
                                   atol=1e-6)


def test_grad_clip_limits_update():
    params = {"w": jnp.zeros((10,))}
    huge = {"w": jnp.full((10,), 1e6)}
    st = adam_init(params)
    p1, _ = adam_update(huge, st, params, lr=1.0, grad_clip=1e-3)
    # clipped: first-step adam update is lr * sign-ish, must be finite/small
    assert bool(jnp.all(jnp.isfinite(p1["w"])))


def test_weight_decay_shrinks_params():
    params = {"w": jnp.ones((4,))}
    zeros = {"w": jnp.zeros((4,))}
    st = adam_init(params)
    p1, _ = adam_update(zeros, st, params, lr=0.1, weight_decay=0.1)
    assert float(p1["w"][0]) < 1.0


def test_sgd_momentum():
    params = {"w": jnp.zeros((2,))}
    grads = {"w": jnp.ones((2,))}
    st = sgd_init(params)
    p, st = sgd_update(grads, st, params, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(np.asarray(p["w"]), -0.1, rtol=1e-6)
    p, st = sgd_update(grads, st, p, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(np.asarray(p["w"]), -0.1 - 0.19, rtol=1e-5)


def test_cosine_warmup_schedule():
    sched = cosine_warmup(1.0, warmup=10, total=110, floor=0.1)
    assert float(sched(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.int32(10))), 1.0, rtol=1e-5)
    assert float(sched(jnp.int32(110))) <= 0.11
    # monotone decay after warmup
    vals = [float(sched(jnp.int32(s))) for s in range(10, 111, 10)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))
